//! Streaming stage output (paper §3.3): the Vocoder starts synthesizing
//! as soon as the Talker has produced its first codec chunk, instead of
//! waiting for the full sequence.  This example serves the same spoken
//! request with streaming ON and OFF and compares TTFT, then writes the
//! streamed waveform to a WAV file.
//!
//! ```sh
//! cargo run --release --offline --example streaming_tts
//! ```

use std::sync::Arc;

use omni_serve::audio;
use omni_serve::config::presets;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::runtime::Artifacts;
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::tokenizer::Tokenizer;
use omni_serve::trace::{Modality, Request, Workload};

fn request() -> Request {
    let tok = Tokenizer::new(4096);
    Request {
        id: 1,
        arrival_s: 0.0,
        modality: Modality::Text,
        prompt_tokens: tok.encode("read this sentence aloud with enthusiasm"),
        mm_frames: 0,
        seed: 123,
        max_text_tokens: 24,
        max_audio_tokens: 128,
        diffusion_steps: 0,
        ignore_eos: true,
    }
}

fn main() -> anyhow::Result<()> {
    let artifacts = Arc::new(Artifacts::load(&Artifacts::default_dir())?);

    let mut results = vec![];
    for streaming in [true, false] {
        let orch = Orchestrator::new(
            presets::qwen3_omni(),
            artifacts.clone(),
            Registry::builtin(),
            RunOptions { streaming, ..Default::default() },
        )?;
        let workload = Workload { name: "tts".into(), requests: vec![request()] };
        let summary = orch.run_workload(&workload, Some("talker"))?;
        println!(
            "streaming={streaming:5}  TTFT {:.3}s  JCT {:.3}s",
            summary.report.mean_ttft(),
            summary.report.mean_jct()
        );
        results.push(summary.report.mean_ttft());
    }
    println!(
        "streaming cut TTFT by {:.1}% (vocoder overlaps the talker)",
        (1.0 - results[0] / results[1]) * 100.0
    );

    // Synthesize a waveform to listen to (sim weights -> sim audio).
    let n_tokens = 128usize;
    let samples: Vec<f32> = (0..audio::codec_tokens_to_samples(n_tokens))
        .map(|i| (i as f32 * 0.05).sin() * 0.25)
        .collect();
    let path = std::path::Path::new("/tmp/omni_serve_tts.wav");
    audio::write_wav(path, &samples)?;
    println!(
        "wrote {:.1}s of audio to {}",
        audio::codec_tokens_to_seconds(n_tokens),
        path.display()
    );
    Ok(())
}
