//! Streaming-first serving API (paper §3.3 streaming stage output, now
//! surfaced to the CLIENT): submit a spoken request with streaming on,
//! receive typed `OutputDelta`s mid-flight — the first `AudioChunk`
//! arrives while the Talker is still generating, long before the
//! request's `Done` — then write the streamed waveform to a WAV file.
//! A second request demonstrates end-to-end cancellation: after the
//! first chunk it is cancelled, resolving with `Done { cancelled }`
//! while every queued/in-flight piece of it is dropped stage-side.
//!
//! ```sh
//! cargo run --release --offline --example streaming_tts
//! ```

use std::sync::Arc;

use omni_serve::audio;
use omni_serve::config::presets;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::runtime::Artifacts;
use omni_serve::serving::{OmniRequest, OutputDelta, ServingSession, SessionOptions};
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::tokenizer::Tokenizer;
use omni_serve::trace::{Modality, Request};

fn request(id: u64, max_audio_tokens: usize) -> Request {
    let tok = Tokenizer::new(4096);
    Request {
        id,
        arrival_s: 0.0,
        modality: Modality::Text,
        prompt_tokens: tok.encode("read this sentence aloud with enthusiasm"),
        mm_frames: 0,
        seed: 123 + id,
        max_text_tokens: 24,
        max_audio_tokens,
        diffusion_steps: 0,
        ignore_eos: true,
    }
}

fn main() -> anyhow::Result<()> {
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        println!(
            "streaming_tts: no compiled artifacts at {} — run `make artifacts` first (skipping)",
            dir.display()
        );
        return Ok(());
    }
    let artifacts = Arc::new(Artifacts::load(&dir)?);
    let orch = Orchestrator::new(
        presets::qwen3_omni(),
        artifacts,
        Registry::builtin(),
        RunOptions::default(),
    )?;
    let session = ServingSession::start(&orch, SessionOptions::default())?;

    // ---- 1. Streaming TTS: audio chunks arrive mid-flight. ----------
    let mut rs = session.submit_request(OmniRequest::from(request(1, 128)).streaming(true))?;
    let mut wave: Vec<f32> = Vec::new();
    let mut first_audio_t: Option<f64> = None;
    let done_t;
    loop {
        match rs.recv() {
            Some(OutputDelta::AudioChunk { wave: chunk, t }) => {
                if first_audio_t.is_none() {
                    first_audio_t = Some(t);
                    println!("first AudioChunk after {t:.3}s ({} samples)", chunk.len());
                }
                wave.extend_from_slice(&chunk);
            }
            Some(OutputDelta::StageDone { stage, t }) => {
                println!("  stage `{stage}` done at {t:.3}s");
            }
            Some(OutputDelta::Done { t, jct_s, cancelled, usage }) => {
                assert!(!cancelled);
                println!(
                    "Done at {t:.3}s (JCT {jct_s:.3}s): {} deltas, {} audio samples",
                    usage.deltas, usage.audio_samples
                );
                done_t = t;
                break;
            }
            Some(_) => {}
            None => anyhow::bail!("stream closed before Done"),
        }
    }
    let ttfa = first_audio_t.expect("a TTS request must stream audio");
    // The acceptance property: streaming delivered audio strictly
    // before the request completed.
    assert!(ttfa < done_t, "first AudioChunk ({ttfa:.3}s) must precede Done ({done_t:.3}s)");
    println!(
        "time-to-first-audio {ttfa:.3}s vs JCT {done_t:.3}s — the client hears audio {:.1}% early",
        (1.0 - ttfa / done_t) * 100.0
    );

    // The streamed chunks ARE the waveform: write what we heard.
    let path = std::path::Path::new("/tmp/omni_serve_tts.wav");
    audio::write_wav(path, &wave)?;
    println!("wrote {:.2}s of streamed audio to {}", audio::samples_to_seconds(wave.len()), path.display());

    // ---- 2. Cancellation: stop a long request after the first chunk. --
    let mut rs = session.submit_request(OmniRequest::from(request(2, 512)).streaming(true))?;
    loop {
        match rs.recv() {
            Some(OutputDelta::AudioChunk { .. }) => {
                rs.cancel();
            }
            Some(OutputDelta::Done { cancelled, jct_s, .. }) => {
                assert!(cancelled, "the long request must resolve as cancelled");
                println!("cancelled the 512-token request after {jct_s:.3}s — KV freed, queues drained");
                break;
            }
            Some(_) => {}
            None => anyhow::bail!("stream closed before Done"),
        }
    }

    session.shutdown(Some("talker"))?;
    Ok(())
}
