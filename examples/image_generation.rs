//! AR + DiT image generation (BAGEL / GLM-Image shape, paper §2.1):
//! an understanding LLM digests the prompt, its hidden states condition
//! a DiT generator.  Writes the generated latent as a PGM preview.
//!
//! ```sh
//! cargo run --release --offline --example image_generation -- "a bowl of ramen"
//! ```

use std::sync::Arc;

use omni_serve::config::presets;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::runtime::Artifacts;
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::tokenizer::Tokenizer;
use omni_serve::trace::{Modality, Request, Workload};

fn main() -> anyhow::Result<()> {
    let prompt = std::env::args().nth(1).unwrap_or_else(|| "a bowl of ramen".into());
    let artifacts = Arc::new(Artifacts::load(&Artifacts::default_dir())?);
    let tok = Tokenizer::new(4096);

    let orch = Orchestrator::new(
        presets::bagel(false),
        artifacts,
        Registry::builtin(),
        RunOptions::default(),
    )?;

    let req = Request {
        id: 1,
        arrival_s: 0.0,
        modality: Modality::Text,
        prompt_tokens: tok.encode(&prompt),
        mm_frames: 0,
        seed: 7,
        max_text_tokens: 12,
        max_audio_tokens: 0,
        diffusion_steps: 24,
        ignore_eos: true,
    };
    let workload = Workload { name: "image-gen".into(), requests: vec![req] };
    let summary = orch.run_workload(&workload, None)?;
    println!(
        "generated 1 image in {:.2}s (understand residence {:.2}s, generate residence {:.2}s)",
        summary.report.mean_jct(),
        summary.report.stage_mean_time("understand"),
        summary.report.stage_mean_time("generate"),
    );
    if let Some(d) = summary.stages.iter().find_map(|s| s.diffusion.as_ref()) {
        println!(
            "diffusion: {} trunk steps run, {} skipped by step cache ({:.0}% hit)",
            d.steps_run,
            d.steps_skipped,
            100.0 * d.steps_skipped as f64 / (d.steps_run + d.steps_skipped).max(1) as f64
        );
    }
    println!("note: latents are from randomly initialized sim weights — the point is the pipeline, not the pixels");
    Ok(())
}
