//! Quickstart: serve a couple of multimodal requests through the
//! Qwen2.5-Omni-sim pipeline (Thinker -> Talker -> DiT Vocoder).
//!
//! Run `make artifacts` first, then:
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use std::sync::Arc;

use omni_serve::config::presets;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::runtime::Artifacts;
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::tokenizer::Tokenizer;
use omni_serve::trace::{Modality, Request, Workload};

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts produced by `make artifacts`.  Exit
    // cleanly when they are absent (CI containers have no JAX) so this
    // example can be *run*, not just built, everywhere.
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        println!(
            "quickstart: no compiled artifacts at {} — run `make artifacts` first (skipping)",
            dir.display()
        );
        return Ok(());
    }
    let artifacts = Arc::new(Artifacts::load(&dir)?);

    // 2. Pick a pipeline preset (stage graph + placement + batching).
    let config = presets::qwen25_omni();
    println!("pipeline `{}` with {} stages", config.name, config.stages.len());

    // 3. Build the disaggregated orchestrator.
    let orch = Orchestrator::new(
        config,
        artifacts,
        Registry::builtin(),
        RunOptions::default(),
    )?;

    // 4. Create two requests: one spoken-audio question, one image.
    let tok = Tokenizer::new(4096);
    let requests = vec![
        Request {
            id: 1,
            arrival_s: 0.0,
            modality: Modality::Audio,
            prompt_tokens: tok.encode("please describe this recording"),
            mm_frames: 48,
            seed: 11,
            max_text_tokens: 24,
            max_audio_tokens: 80,
            diffusion_steps: 0,
            ignore_eos: true,
        },
        Request {
            id: 2,
            arrival_s: 0.0,
            modality: Modality::Image,
            prompt_tokens: tok.encode("what dish is shown in the photo"),
            mm_frames: 32,
            seed: 22,
            max_text_tokens: 20,
            max_audio_tokens: 64,
            diffusion_steps: 0,
            ignore_eos: true,
        },
    ];
    let workload = Workload { name: "quickstart".into(), requests };

    // 5. Serve and report.
    let summary = orch.run_workload(&workload, Some("talker"))?;
    println!(
        "completed {} requests in {:.2}s  (mean JCT {:.2}s, mean TTFT {:.2}s, mean RTF {:.2})",
        summary.report.completed,
        summary.wall_s,
        summary.report.mean_jct(),
        summary.report.mean_ttft(),
        summary.report.mean_rtf(),
    );
    for stage in ["thinker", "talker", "vocoder"] {
        println!(
            "  {stage:>8}: mean residence {:.2}s, {} output tokens/frames",
            summary.report.stage_mean_time(stage),
            summary.report.stage_tokens(stage),
        );
    }
    Ok(())
}
