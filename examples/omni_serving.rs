//! End-to-end validation driver (DESIGN.md deliverable (b)): loads the
//! Qwen3-Omni-sim any-to-any pipeline, serves a batched multimodal
//! workload through the fully disaggregated backend — via the typed
//! streaming API ([`OmniRequest`] → [`ResponseStream`] deltas) — AND the
//! monolithic baseline, and reports latency/throughput for both.  This
//! is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! cargo run --release --offline --example omni_serving -- [n_requests]
//! ```

use std::sync::Arc;

use omni_serve::baseline::{run_monolithic, BaselineOptions};
use omni_serve::config::presets;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::runtime::Artifacts;
use omni_serve::serving::{OmniRequest, OutputDelta, ServingSession, SessionOptions};
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::trace::datasets;
use omni_serve::util::fmt;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        println!(
            "omni_serving: no compiled artifacts at {} — run `make artifacts` first (skipping)",
            dir.display()
        );
        return Ok(());
    }
    let artifacts = Arc::new(Artifacts::load(&dir)?);
    let workload = datasets::ucf101(42, n, 0.0);
    println!(
        "workload: {} x {} (avg input {:.1} tok, text out {:.1}, audio out {:.1})",
        workload.len(),
        workload.name,
        workload.avg_input_tokens(),
        workload.avg_text_out(),
        workload.avg_audio_out()
    );

    // --- disaggregated (vLLM-Omni-style), through the streaming API ---
    let orch = Orchestrator::new(
        presets::qwen3_omni(),
        artifacts.clone(),
        Registry::builtin(),
        RunOptions::default(),
    )?;
    let session = ServingSession::start(&orch, SessionOptions::default())?;
    let mut streams = Vec::with_capacity(workload.len());
    for r in workload.requests.iter().cloned() {
        streams.push(session.submit_request(OmniRequest::from(r).streaming(true))?);
    }
    // Consume every stream: requests run concurrently inside the stage
    // graph; the deltas prove each one produced audio mid-flight.
    let (mut total_deltas, mut first_audio) = (0usize, Vec::with_capacity(streams.len()));
    for rs in &mut streams {
        let mut first: Option<f64> = None;
        loop {
            match rs.recv() {
                Some(OutputDelta::AudioChunk { t, .. }) => {
                    total_deltas += 1;
                    first.get_or_insert(t);
                }
                Some(OutputDelta::Done { .. }) => break,
                Some(_) => {}
                None => anyhow::bail!("stream closed before Done"),
            }
        }
        if let Some(t) = first {
            first_audio.push(t - rs.submitted_t());
        }
    }
    let ours = session.shutdown(Some("talker"))?;
    println!("\n-- omni-serve (disaggregated, streaming API, continuous batching) --");
    print_summary(&ours.report, ours.wall_s);
    println!(
        "   streaming: {} audio deltas across {} requests, mean time-to-first-audio {}",
        total_deltas,
        streams.len(),
        fmt::dur(first_audio.iter().sum::<f64>() / first_audio.len().max(1) as f64),
    );
    for s in &ours.stages {
        if let Some(ar) = &s.ar {
            println!(
                "   {:>8}: {} calls ({} scan), exec {}, marshal {}, preempt {}",
                s.name,
                ar.prefill_calls + ar.decode_calls + ar.scan_calls,
                ar.scan_calls,
                fmt::dur(ar.exec_seconds),
                fmt::dur(ar.marshal_seconds),
                ar.preemptions,
            );
        }
    }

    // --- monolithic baseline (HF-Transformers-like) ---
    let base = run_monolithic(
        &artifacts,
        &presets::qwen3_omni(),
        &workload,
        &BaselineOptions { lazy_compile: true, no_kv_cache: false },
        Some("talker"),
    )?;
    println!("\n-- baseline (monolithic, serial, lazy compile) --");
    print_summary(&base, base.wall_s);

    println!("\n-- comparison (paper Fig. 6 shape) --");
    println!(
        "  JCT reduction: {:.1}%   (paper: 91.4% for Qwen3-Omni)",
        (1.0 - ours.report.mean_jct() / base.mean_jct()) * 100.0
    );
    println!(
        "  RTF reduction: {:.1}%   (paper: 90.7%)",
        (1.0 - ours.report.mean_rtf() / base.mean_rtf()) * 100.0
    );
    println!(
        "  Thinker TPS: {:.1} vs {:.1}  ({:.2}x; paper: 12.97x)",
        ours.report.stage_tps("thinker"),
        base.stage_tps("thinker"),
        ours.report.stage_tps("thinker") / base.stage_tps("thinker"),
    );
    println!(
        "  Talker  TPS: {:.1} vs {:.1}  ({:.2}x; paper: 7.98x)",
        ours.report.stage_tps("talker"),
        base.stage_tps("talker"),
        ours.report.stage_tps("talker") / base.stage_tps("talker"),
    );
    Ok(())
}

fn print_summary(r: &omni_serve::metrics::RunReport, wall: f64) {
    let tpot = if r.tpot.is_empty() {
        String::new()
    } else {
        format!(
            " TPOT p50={} p95={}",
            fmt::dur(r.tpot_percentile(50.0)),
            fmt::dur(r.tpot_percentile(95.0)),
        )
    };
    println!(
        "   completed={} wall={} JCT mean={} TTFT mean={}{} RTF mean={:.3}",
        r.completed,
        fmt::dur(wall),
        fmt::dur(r.mean_jct()),
        fmt::dur(r.mean_ttft()),
        tpot,
        r.mean_rtf()
    );
    for s in ["thinker", "talker", "vocoder"] {
        println!(
            "   {:>8}: residence {} | tokens {} | TPS {:.1}",
            s,
            fmt::dur(r.stage_mean_time(s)),
            r.stage_tokens(s),
            r.stage_tps(s)
        );
    }
}
