//! Building a custom any-to-any pipeline with the public API (paper
//! §3.2: "users define any-to-any models as a stage graph"):
//!
//! * compose a new two-stage graph (MiMo backbone -> CNN vocoder — a
//!   combination no preset ships),
//! * register a CUSTOM transfer function for the edge,
//! * replicate the hot vocoder stage 2x with affinity routing (paper
//!   §3.3 "flexible GPU allocation" — the edge fans out across the
//!   replicas through `connector::router`),
//! * serve requests through it.
//!
//! ```sh
//! cargo run --release --offline --example custom_stage_graph
//! ```

use std::sync::Arc;

use omni_serve::config::{
    ConnectorKind, EdgeConfig, PipelineConfig, RoutingKind, StageConfig, StageKind,
};
use omni_serve::engine::vocoder::VocoderJob;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::runtime::Artifacts;
use omni_serve::stage_graph::transfers::{EngineCmd, Registry, TransferCtx};
use omni_serve::tokenizer::Tokenizer;
use omni_serve::trace::{Modality, Request, Workload};

fn main() -> anyhow::Result<()> {
    let artifacts = Arc::new(Artifacts::load(&Artifacts::default_dir())?);

    // 1. Define the stage graph: MiMo AR backbone -> Qwen3 CNN vocoder,
    //    connected over the SHARED-MEMORY connector with a custom edge fn.
    //    The vocoder runs TWO engine replicas: the edge's affinity
    //    routing keeps every chunk of a request on one replica (our
    //    transfer accumulates per-request state consumer-side), while
    //    different requests synthesize on different replicas in parallel.
    let config = PipelineConfig {
        name: "custom-tts".into(),
        stages: vec![
            StageConfig::new("backbone", "mimo", StageKind::Ar)
                .on_devices(&[0])
                .with_batch(4),
            StageConfig::new("wave", "voc_cnn3", StageKind::CnnVocoder)
                .on_devices(&[1])
                .with_replicas(2)
                .with_batch(4),
        ],
        edges: vec![EdgeConfig {
            from: "backbone".into(),
            to: "wave".into(),
            transfer: "every_other_token".into(),
            connector: ConnectorKind::Shm,
            routing: RoutingKind::Affinity,
        }],
        n_devices: 2,
        device_bytes: omni_serve::device::DEFAULT_DEVICE_BYTES,
        autoscaler: None,
        admission: None,
        cache: None,
        transport: omni_serve::config::TransportConfig::default(),
        cluster: None,
    };

    // 2. Register the custom transfer: keep every other token (a toy
    //    "frame-rate adapter"), chunked to the vocoder's frame capacity.
    let mut registry = Registry::builtin();
    registry.register(
        "every_other_token",
        Arc::new(|ctx: TransferCtx| {
            let mut buf: std::collections::HashMap<u64, (Vec<u32>, usize)> = Default::default();
            Box::new(move |item| {
                let mut cmds = vec![];
                let (acc, chunks) = buf.entry(item.req_id).or_default();
                if let Some(t) = item.tensor("tokens") {
                    for (i, &tok) in t.as_i32()?.iter().enumerate() {
                        if i % 2 == 0 {
                            acc.push(tok as u32);
                        }
                    }
                }
                let cap = ctx.chunk_frames.max(1);
                while acc.len() >= cap || (item.finished && !acc.is_empty()) {
                    let take = acc.len().min(cap);
                    let tokens: Vec<u32> = acc.drain(..take).collect();
                    let final_chunk = item.finished && acc.is_empty();
                    cmds.push(EngineCmd::SubmitVocoder(VocoderJob {
                        req_id: item.req_id,
                        chunk_idx: *chunks,
                        tokens,
                        final_chunk,
                    }));
                    *chunks += 1;
                    if final_chunk {
                        break;
                    }
                }
                Ok(cmds)
            })
        }),
    );

    // 3. Serve.
    let orch = Orchestrator::new(config, artifacts, registry, RunOptions::default())?;
    let tok = Tokenizer::new(2048);
    let requests: Vec<Request> = (0..3)
        .map(|i| Request {
            id: i + 1,
            arrival_s: 0.0,
            modality: Modality::Text,
            prompt_tokens: tok.encode("synthesize me some speech please"),
            mm_frames: 0,
            seed: 100 + i,
            max_text_tokens: 96,
            max_audio_tokens: 0,
            diffusion_steps: 0,
            ignore_eos: true,
        })
        .collect();
    let workload = Workload { name: "custom".into(), requests };
    let summary = orch.run_workload(&workload, Some("backbone"))?;
    println!(
        "custom pipeline served {} requests in {:.2}s (JCT mean {:.2}s) over shm connector",
        summary.report.completed,
        summary.wall_s,
        summary.report.mean_jct()
    );
    println!(
        "backbone produced {} tokens; vocoder synthesized {} frames (every other token)",
        summary.report.stage_tokens("backbone"),
        summary.report.stage_tokens("wave"),
    );
    // Per-replica view of the replicated vocoder: affinity routing split
    // the requests across the two engines.
    for s in summary.stage_replicas("wave") {
        if let Some(v) = &s.vocoder {
            println!("  wave replica {}: {} chunks over {} calls", s.replica, v.chunks_done, v.calls);
        }
    }
    Ok(())
}
