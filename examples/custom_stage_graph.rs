//! Building custom any-to-any pipelines with the public API (paper
//! §3.2: "users define any-to-any models as a stage graph"):
//!
//! Part 1 (runs everywhere, no artifacts needed) — branching fan-out:
//! * load the `qwen3-omni-branching` preset, where one prompt fans out
//!   from the thinker into a parallel image arm and a speech arm that
//!   share its prefill,
//! * validate it into a [`StageGraph`] and walk [`BranchInfo`] to see
//!   which stages belong to which branch and where each branch exits,
//! * show the fractional-sharing config (encoder + vocoder as 300-milli
//!   slots co-resident on device 0 under the time-slice scheduler),
//! * show the validator rejecting a *partial* fan-in (an edge that
//!   merges only some of the branches).
//!
//! Part 2 (needs `make artifacts`, skipped gracefully otherwise):
//! * compose a new two-stage graph (MiMo backbone -> CNN vocoder — a
//!   combination no preset ships),
//! * register a CUSTOM transfer function for the edge,
//! * replicate the hot vocoder stage 2x with affinity routing (paper
//!   §3.3 "flexible GPU allocation" — the edge fans out across the
//!   replicas through `connector::router`),
//! * serve requests through it.
//!
//! ```sh
//! cargo run --release --offline --example custom_stage_graph
//! ```

use std::sync::Arc;

use omni_serve::config::{
    presets, ConnectorKind, EdgeConfig, PipelineConfig, RoutingKind, StageConfig, StageKind,
};
use omni_serve::engine::vocoder::VocoderJob;
use omni_serve::gpu_share::DEVICE_MILLI;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::runtime::Artifacts;
use omni_serve::stage_graph::transfers::{EngineCmd, Registry, TransferCtx};
use omni_serve::stage_graph::StageGraph;
use omni_serve::tokenizer::Tokenizer;
use omni_serve::trace::{Modality, Request, Workload};

/// Part 1: validate a branching fan-out graph and inspect its branches.
fn branching_fanout_tour() -> anyhow::Result<()> {
    let registry = Registry::builtin();
    let config = presets::by_name("qwen3-omni-branching").expect("preset registered");
    let graph = StageGraph::build(config, &registry)?;

    let name = |i: usize| graph.stage(i).name.as_str();
    println!(
        "pipeline `{}`: entry `{}`, {} exit stage(s)",
        graph.config.name,
        name(graph.entry),
        graph.exits.len()
    );
    for s in &graph.config.stages {
        if s.compute_milli < DEVICE_MILLI {
            println!(
                "  stage `{}` is fractional: {}/{} of device {:?}",
                s.name, s.compute_milli, DEVICE_MILLI, s.devices
            );
        }
    }
    // One prompt -> parallel image + speech arms sharing the thinker's
    // prefill.  A request completes when BOTH branch exits deliver.
    for b in graph.branches() {
        let stages: Vec<&str> = b.stages.iter().map(|&i| name(i)).collect();
        let exits: Vec<&str> = b.exits.iter().map(|&i| name(i)).collect();
        println!(
            "  branch from `{}` via `{}`: stages {:?}, exits {:?}",
            name(b.root),
            name(b.head),
            stages,
            exits
        );
    }

    // The validator rejects fan-ins that merge only SOME branches: add
    // a thinker->vocoder shortcut so the vocoder would join the speech
    // arm with the fan-out root while the image arm runs free.
    let mut bad = presets::by_name("qwen3-omni-branching").unwrap();
    bad.edges.push(EdgeConfig {
        from: "thinker".into(),
        to: "vocoder".into(),
        transfer: "talker2vocoder".into(),
        connector: ConnectorKind::Shm,
        routing: RoutingKind::Affinity,
    });
    match StageGraph::build(bad, &registry) {
        Ok(_) => anyhow::bail!("partial fan-in unexpectedly accepted"),
        Err(e) => println!("  partial fan-in rejected as expected: {e}"),
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    branching_fanout_tour()?;

    // Part 2 needs the AOT artifacts produced by `make artifacts`.
    // Exit cleanly when they are absent (CI containers have no JAX) so
    // this example can be *run*, not just built, everywhere.
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        println!(
            "custom_stage_graph: no compiled artifacts at {} — run `make artifacts` first \
             (skipping the serving part)",
            dir.display()
        );
        return Ok(());
    }
    let artifacts = Arc::new(Artifacts::load(&dir)?);

    // 1. Define the stage graph: MiMo AR backbone -> Qwen3 CNN vocoder,
    //    connected over the SHARED-MEMORY connector with a custom edge fn.
    //    The vocoder runs TWO engine replicas: the edge's affinity
    //    routing keeps every chunk of a request on one replica (our
    //    transfer accumulates per-request state consumer-side), while
    //    different requests synthesize on different replicas in parallel.
    let config = PipelineConfig {
        name: "custom-tts".into(),
        stages: vec![
            StageConfig::new("backbone", "mimo", StageKind::Ar)
                .on_devices(&[0])
                .with_batch(4),
            StageConfig::new("wave", "voc_cnn3", StageKind::CnnVocoder)
                .on_devices(&[1])
                .with_replicas(2)
                .with_batch(4),
        ],
        edges: vec![EdgeConfig {
            from: "backbone".into(),
            to: "wave".into(),
            transfer: "every_other_token".into(),
            connector: ConnectorKind::Shm,
            routing: RoutingKind::Affinity,
        }],
        n_devices: 2,
        device_bytes: omni_serve::device::DEFAULT_DEVICE_BYTES,
        autoscaler: None,
        admission: None,
        cache: None,
        transport: omni_serve::config::TransportConfig::default(),
        cluster: None,
        share: None,
    };

    // 2. Register the custom transfer: keep every other token (a toy
    //    "frame-rate adapter"), chunked to the vocoder's frame capacity.
    let mut registry = Registry::builtin();
    registry.register(
        "every_other_token",
        Arc::new(|ctx: TransferCtx| {
            let mut buf: std::collections::HashMap<u64, (Vec<u32>, usize)> = Default::default();
            Box::new(move |item| {
                let mut cmds = vec![];
                let (acc, chunks) = buf.entry(item.req_id).or_default();
                if let Some(t) = item.tensor("tokens") {
                    for (i, &tok) in t.as_i32()?.iter().enumerate() {
                        if i % 2 == 0 {
                            acc.push(tok as u32);
                        }
                    }
                }
                let cap = ctx.chunk_frames.max(1);
                while acc.len() >= cap || (item.finished && !acc.is_empty()) {
                    let take = acc.len().min(cap);
                    let tokens: Vec<u32> = acc.drain(..take).collect();
                    let final_chunk = item.finished && acc.is_empty();
                    cmds.push(EngineCmd::SubmitVocoder(VocoderJob {
                        req_id: item.req_id,
                        chunk_idx: *chunks,
                        tokens,
                        final_chunk,
                    }));
                    *chunks += 1;
                    if final_chunk {
                        break;
                    }
                }
                Ok(cmds)
            })
        }),
    );

    // 3. Serve.
    let orch = Orchestrator::new(config, artifacts, registry, RunOptions::default())?;
    let tok = Tokenizer::new(2048);
    let requests: Vec<Request> = (0..3)
        .map(|i| Request {
            id: i + 1,
            arrival_s: 0.0,
            modality: Modality::Text,
            prompt_tokens: tok.encode("synthesize me some speech please"),
            mm_frames: 0,
            seed: 100 + i,
            max_text_tokens: 96,
            max_audio_tokens: 0,
            diffusion_steps: 0,
            ignore_eos: true,
        })
        .collect();
    let workload = Workload { name: "custom".into(), requests };
    let summary = orch.run_workload(&workload, Some("backbone"))?;
    println!(
        "custom pipeline served {} requests in {:.2}s (JCT mean {:.2}s) over shm connector",
        summary.report.completed,
        summary.wall_s,
        summary.report.mean_jct()
    );
    println!(
        "backbone produced {} tokens; vocoder synthesized {} frames (every other token)",
        summary.report.stage_tokens("backbone"),
        summary.report.stage_tokens("wave"),
    );
    // Per-replica view of the replicated vocoder: affinity routing split
    // the requests across the two engines.
    for s in summary.stage_replicas("wave") {
        if let Some(v) = &s.vocoder {
            println!("  wave replica {}: {} chunks over {} calls", s.replica, v.chunks_done, v.calls);
        }
    }
    Ok(())
}
