//! Fig. 7 reproduction: per-stage execution-time decomposition for
//! Qwen3-Omni (video inputs).  The paper's finding: the Talker dominates
//! overall latency for BOTH systems because it generates ~3.6x more
//! tokens than the Thinker (545.4 audio vs 150.9 text on average).

use std::sync::Arc;

use omni_serve::baseline::{run_monolithic, BaselineOptions};
use omni_serve::bench_util::{self, Table};
use omni_serve::config::presets;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::trace::datasets;

fn main() -> anyhow::Result<()> {
    let artifacts = bench_util::load_artifacts();
    let n = bench_util::bench_n(6);
    let wl = datasets::ucf101(7, n, 0.0);
    println!(
        "workload: ucf101-sim n={n} (avg in {:.1}, text out {:.1}, audio out {:.1}; paper: 841.6 / 150.9 / 545.4 unscaled)",
        wl.avg_input_tokens(),
        wl.avg_text_out(),
        wl.avg_audio_out()
    );

    let orch = Orchestrator::new(
        presets::qwen3_omni(),
        Arc::clone(&artifacts),
        Registry::builtin(),
        RunOptions::default(),
    )?;
    let ours = orch.run_workload(&wl, Some("talker"))?.report;
    let base = run_monolithic(
        &artifacts,
        &presets::qwen3_omni(),
        &wl,
        &BaselineOptions { lazy_compile: true, no_kv_cache: false },
        Some("talker"),
    )?;

    let mut t = Table::new(
        "Fig. 7 — Qwen3-Omni per-stage time decomposition (mean residence seconds)",
        &["system", "thinker", "talker", "vocoder", "talker share"],
    );
    for (sys, r) in [("baseline", &base), ("omni-serve", &ours)] {
        let th = r.stage_mean_time("thinker");
        let ta = r.stage_mean_time("talker");
        let vo = r.stage_mean_time("vocoder");
        t.row(vec![
            sys.into(),
            format!("{th:.2}"),
            format!("{ta:.2}"),
            format!("{vo:.2}"),
            format!("{:.0}%", 100.0 * ta / (th + ta + vo).max(1e-9)),
        ]);
    }
    t.print();
    println!(
        "token counts: thinker {} vs talker {} (ratio {:.1}x; paper ~3.6x)",
        ours.stage_tokens("thinker"),
        ours.stage_tokens("talker"),
        ours.stage_tokens("talker") as f64 / ours.stage_tokens("thinker").max(1) as f64
    );
    Ok(())
}
