//! §4.2 "MiMo-Audio model" reproduction: RTF on SeedTTS-sim.
//!
//! Paper reference: baseline RTF 1.39; ours 0.60 WITHOUT execution-graph
//! compilation; 0.12 WITH graph compilation (11.58x total).  Graph
//! compilation maps to the fused multi-step scan executable
//! (`multi_step = SCAN_STEPS`); the baseline's missing compilation maps
//! to per-request recompilation.

use std::sync::Arc;

use omni_serve::baseline::{run_monolithic, BaselineOptions};
use omni_serve::bench_util::{self, Table};
use omni_serve::config::presets;
use omni_serve::engine::ar::SCAN_STEPS;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::trace::datasets;

fn main() -> anyhow::Result<()> {
    let artifacts = bench_util::load_artifacts();
    let n = bench_util::bench_n(6);
    let wl = datasets::seedtts(5, n, 0.0);

    let base = run_monolithic(
        &artifacts,
        &presets::mimo_audio(1),
        &wl,
        &BaselineOptions { lazy_compile: true, no_kv_cache: false },
        Some("backbone"),
    )?;

    let run = |multi_step: usize| -> anyhow::Result<omni_serve::metrics::RunReport> {
        let orch = Orchestrator::new(
            presets::mimo_audio(multi_step),
            Arc::clone(&artifacts),
            Registry::builtin(),
            RunOptions::default(),
        )?;
        Ok(orch.run_workload(&wl, Some("backbone"))?.report)
    };
    let ours_plain = run(1)?;
    let ours_scan = run(SCAN_STEPS)?;

    let mut t = Table::new(
        "MiMo-Audio — RTF on SeedTTS-sim (paper: 1.39 / 0.60 / 0.12; 11.58x)",
        &["system", "RTF", "JCT(s)", "speedup vs baseline"],
    );
    for (sys, r) in [
        ("baseline (original impl)", &base),
        ("omni-serve (no graph compile)", &ours_plain),
        ("omni-serve (+graph compile)", &ours_scan),
    ] {
        t.row(vec![
            sys.into(),
            format!("{:.3}", r.mean_rtf()),
            format!("{:.2}", r.mean_jct()),
            bench_util::speedup(base.mean_rtf(), r.mean_rtf()),
        ]);
    }
    t.print();
    Ok(())
}
