//! §4.2 "BAGEL Model" reproduction: JCT for text-to-image and
//! image-to-image generation, baseline (original monolithic impl, no
//! step cache, serial) vs omni-serve (disaggregated understand/generate,
//! step cache, pipelined requests).
//!
//! Paper reference: T2I 23.12s -> 9.64s (2.40x); I2I 41.39s -> 11.12s
//! (3.72x) at 1024x1024 on VBench prompts.

use std::sync::Arc;

use omni_serve::baseline::{run_monolithic, BaselineOptions};
use omni_serve::bench_util::{self, Table};
use omni_serve::config::presets;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::trace::datasets;

fn main() -> anyhow::Result<()> {
    let artifacts = bench_util::load_artifacts();
    let n = bench_util::bench_n(4);

    let mut t = Table::new(
        "BAGEL — JCT on VBench-sim (paper: T2I 23.12->9.64s 2.40x, I2I 41.39->11.12s 3.72x)",
        &["task", "baseline JCT(s)", "omni-serve JCT(s)", "speedup"],
    );
    for (task, i2i) in [("T2I", false), ("I2I", true)] {
        let wl = datasets::vbench(11, n, 0.0, 24, i2i);
        // Original-impl baseline: serial, stage barriers, no step cache —
        // but keep compiled executables resident (the original research
        // repos do reuse their graphs across requests).
        let base = run_monolithic(
            &artifacts,
            &presets::bagel(i2i),
            &wl,
            &BaselineOptions { lazy_compile: false, no_kv_cache: false },
            None,
        )?;
        let orch = Orchestrator::new(
            presets::bagel(i2i),
            Arc::clone(&artifacts),
            Registry::builtin(),
            RunOptions::default(),
        )?;
        let ours = orch.run_workload(&wl, None)?.report;
        t.row(vec![
            task.into(),
            format!("{:.2}", base.mean_jct()),
            format!("{:.2}", ours.mean_jct()),
            bench_util::speedup(base.mean_jct(), ours.mean_jct()),
        ]);
    }
    t.print();
    Ok(())
}
