//! Scheduler-policy benchmark: FIFO (static batching) vs continuous
//! batching JCT on the bundled AR traces (paper §3.3 — per-stage request
//! batching is where the serving-efficiency win comes from, on top of
//! disaggregation itself).
//!
//! Unlike the figure benches this one needs no compiled artifacts: it
//! drives the *real* `BatchPolicy` implementations through the
//! deterministic discrete-time AR-stage model in
//! `omni_serve::scheduler::sim`, which reproduces the engine's iteration
//! skeleton (chunked prefill, one token per decode step, join/evict at
//! token boundaries) under a calibrated dispatch+per-token cost model.
//!
//! Output: mean/p50/p99 JCT, makespan, and batch occupancy per policy and
//! trace, plus the JCT reduction of continuous batching over FIFO, a
//! token-budget sweep showing the admission-control knob, the stage-
//! replication comparison (paper §3.3 flexible GPU allocation): the
//! qwen3-omni-rep2 preset's 2-replica Talker vs the single-replica
//! baseline under every routing policy, asserted to win on mean JCT —
//! and the elastic-autoscaler section: on the bursty mixed-modality
//! trace the autoscaled two-stage run is asserted to beat EVERY static
//! replica split with the same GPU budget on mean JCT, with at least one
//! scale-up and one scale-down recorded.  The cross-node section (ISSUE
//! 8) asserts transfer-cost-aware placement beats round-robin placement
//! on mean JCT for all 32 seeds under the per-link bandwidth model.
//! The fractional section (ISSUE 9) asserts packed-fractional GPU
//! sharing — encoder + vocoder co-resident on one device, the freed
//! device buying a third DiT replica — beats whole-device packing on
//! mean JCT for all 32 seeds of the branching fan-out trace.

use omni_serve::bench_util::{self, Table};
use omni_serve::config::presets;
use omni_serve::scheduler::policy::{BatchPolicy, ContinuousBatchingPolicy, FifoPolicy};
use omni_serve::scheduler::sim::{
    cross_node_comparison, elastic_comparison, fractional_comparison, from_workload,
    prefix_cache_comparison, simulate, simulate_disagg, simulate_replicated, SimCost, SimReport,
    SimRouting,
};
use omni_serve::scheduler::StageAllocator;
use omni_serve::trace::Workload;
use omni_serve::trace::datasets;
use omni_serve::util::fmt;

const MAX_BATCH: usize = 4;

fn run(policy: &mut dyn BatchPolicy, wl: &Workload) -> SimReport {
    simulate(policy, MAX_BATCH, &SimCost::default(), &from_workload(wl))
}

fn main() {
    let n = bench_util::bench_n(64);

    // The paper's offline-batch evaluation mode (all requests at t=0) and
    // an online Poisson-arrival mode, across the bundled AR traces.
    let workloads: Vec<Workload> = vec![
        datasets::librispeech(1, n, 0.0),
        datasets::seedtts(1, n, 0.0),
        datasets::ucf101(1, n, 0.0),
        datasets::librispeech(2, n, 4.0),
        datasets::seedtts(2, n, 4.0),
    ];

    let mut t = Table::new(
        "Scheduler: FIFO (static batching) vs continuous batching, AR-stage model",
        &[
            "trace", "rate", "policy", "mean JCT", "p50", "p99", "makespan", "mean batch",
            "JCT reduction",
        ],
    );
    for wl in &workloads {
        let rate = wl.requests.iter().map(|r| r.arrival_s).fold(0.0, f64::max);
        let mode = if rate > 0.0 { "online" } else { "offline" };
        let fifo = run(&mut FifoPolicy, wl);
        let cont = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, wl);
        let reductions =
            ["-".to_string(), bench_util::reduction_pct(fifo.mean_jct(), cont.mean_jct())];
        for (rep, reduction) in [&fifo, &cont].into_iter().zip(reductions) {
            let mut jct = rep.jct.clone();
            t.row(vec![
                wl.name.clone(),
                mode.into(),
                rep.policy.clone(),
                fmt::dur(rep.mean_jct()),
                fmt::dur(jct.p50()),
                fmt::dur(jct.p99()),
                fmt::dur(rep.makespan_s),
                format!("{:.2}", rep.mean_batch),
                reduction,
            ]);
        }
    }
    t.print();

    // Admission-control sweep: the max-batch-tokens budget trades batch
    // occupancy (throughput) against queueing (per-request latency).
    let wl = datasets::librispeech(3, n, 0.0);
    let mut t = Table::new(
        "Continuous batching: max_batch_tokens admission budget sweep",
        &["budget", "mean JCT", "p99", "makespan", "mean batch"],
    );
    for budget in [0usize, 512, 256, 128, 64] {
        let rep = run(&mut ContinuousBatchingPolicy { max_batch_tokens: budget }, &wl);
        let mut jct = rep.jct.clone();
        t.row(vec![
            if budget == 0 { "unlimited".into() } else { budget.to_string() },
            fmt::dur(rep.mean_jct()),
            fmt::dur(jct.p99()),
            fmt::dur(rep.makespan_s),
            format!("{:.2}", rep.mean_batch),
        ]);
    }
    t.print();

    // Stage replication (paper §3.3 flexible GPU allocation): the
    // qwen3-omni-rep2 preset gives the hot Talker stage two engine
    // replicas; the replicated AR-stage model shows the JCT win per
    // routing policy on the same traces, no compiled artifacts needed.
    let rep_preset = presets::qwen3_omni_replicated();
    let plan = StageAllocator::new(&rep_preset).plan(None).unwrap();
    let talker = plan.by_name("talker").unwrap();
    assert_eq!(talker.replicas, 2, "preset gives the talker two replicas");
    let talker_batch = talker.max_batch;
    let mk_policies = |n: usize| -> Vec<Box<dyn BatchPolicy>> {
        (0..n)
            .map(|_| {
                Box::new(ContinuousBatchingPolicy {
                    max_batch_tokens: talker.max_batch_tokens,
                }) as Box<dyn BatchPolicy>
            })
            .collect()
    };
    let mut t = Table::new(
        "Talker replication (qwen3-omni vs qwen3-omni-rep2), AR-stage model",
        &["trace", "replicas", "routing", "mean JCT", "p99", "makespan", "JCT reduction"],
    );
    let mut rep2_beats_rep1 = true;
    for wl in [datasets::seedtts(1, n, 0.0), datasets::librispeech(2, n, 4.0)] {
        let reqs = from_workload(&wl);
        let mut one_p = mk_policies(1);
        let one =
            simulate_replicated(&mut one_p, talker_batch, &SimCost::default(), &reqs, SimRouting::Affinity);
        let mut jct1 = one.jct.clone();
        t.row(vec![
            wl.name.clone(),
            "1".into(),
            "-".into(),
            fmt::dur(one.mean_jct()),
            fmt::dur(jct1.p99()),
            fmt::dur(one.makespan_s),
            "-".into(),
        ]);
        for routing in [SimRouting::Affinity, SimRouting::RoundRobin, SimRouting::LeastWork] {
            let mut two_p = mk_policies(2);
            let two =
                simulate_replicated(&mut two_p, talker_batch, &SimCost::default(), &reqs, routing);
            rep2_beats_rep1 &= two.mean_jct() < one.mean_jct();
            let mut jct2 = two.jct.clone();
            t.row(vec![
                wl.name.clone(),
                "2".into(),
                routing.name().into(),
                fmt::dur(two.mean_jct()),
                fmt::dur(jct2.p99()),
                fmt::dur(two.makespan_s),
                bench_util::reduction_pct(one.mean_jct(), two.mean_jct()),
            ]);
        }
    }
    t.print();
    assert!(
        rep2_beats_rep1,
        "talker replicas=2 must beat replicas=1 mean JCT on the bundled traces"
    );

    // Elastic autoscaling (paper §3: flexible GPU allocation under LIVE
    // traffic): on a bursty mixed-modality trace whose bottleneck stage
    // flips mid-run (analysis burst = Thinker-bound, speech burst =
    // Talker-bound), the autoscaled run must beat EVERY static replica
    // split of the same GPU budget on mean JCT — no fixed split is right
    // for both phases.  Asserted; also pinned by `tests/serving.rs`.
    let budget = 4usize;
    let wl = datasets::bursty_mixed(1, n.max(32), 2.0);
    let mut t = Table::new(
        "Elastic autoscaling vs static replica splits (two-stage AR model, bursty trace)",
        &["allocation", "mean JCT", "p99", "makespan", "gpu-seconds", "scale events", "JCT reduction"],
    );
    let (static_reports, auto) = elastic_comparison(&wl, budget);
    let best_static =
        static_reports.iter().map(|r| r.mean_jct()).fold(f64::INFINITY, f64::min);
    for rep in &static_reports {
        let mut jct = rep.jct.clone();
        t.row(vec![
            rep.policy.clone(),
            fmt::dur(rep.mean_jct()),
            fmt::dur(jct.p99()),
            fmt::dur(rep.makespan_s),
            format!("{:.2}", rep.replica_seconds),
            "-".into(),
            "-".into(),
        ]);
    }
    {
        let mut jct = auto.jct.clone();
        t.row(vec![
            auto.policy.clone(),
            fmt::dur(auto.mean_jct()),
            fmt::dur(jct.p99()),
            fmt::dur(auto.makespan_s),
            format!("{:.2}", auto.replica_seconds),
            format!("{} up / {} down", auto.scale_ups, auto.scale_downs),
            bench_util::reduction_pct(best_static, auto.mean_jct()),
        ]);
    }
    t.print();
    for rep in &static_reports {
        assert!(
            auto.mean_jct() < rep.mean_jct(),
            "autoscaled {:.3}s !< {} {:.3}s on {}",
            auto.mean_jct(),
            rep.policy,
            rep.mean_jct(),
            wl.name
        );
        assert_eq!(rep.jct.len(), wl.len());
    }
    assert_eq!(auto.jct.len(), wl.len());
    assert!(auto.scale_ups >= 1 && auto.scale_downs >= 1, "bursty trace must trigger both directions");
    assert!(auto.max_slots <= budget, "autoscaler exceeded its GPU budget");

    // Prefill/decode disaggregation (paper §3.4 + the kv_transfer
    // subsystem): on the prefill-heavy mixed trace, phase-tuned split
    // pools must beat the fused AR pool on mean JCT AND mean TTFT at
    // the same GPU budget, and the autoscaled split must keep the JCT
    // win within budget while scaling each pool independently.
    // Asserted; also pinned by `tests/disagg.rs` and the
    // `omni-serve bench --trace prefill-heavy` CI smoke.
    let budget = 4usize;
    let wl = datasets::prefill_heavy(1, n.max(64), 56.0);
    let c = simulate_disagg(&wl, budget);
    let mut t = Table::new(
        "Prefill/decode disaggregation vs fused AR pool (prefill-heavy trace, equal budget)",
        &["pool layout", "allocation", "mean JCT", "p99", "mean TTFT", "makespan", "JCT reduction"],
    );
    for (label, rep) in [
        ("fused (b4)", &c.fused),
        ("fused (b8)", &c.fused_wide),
        ("prefill+decode", &c.split_static),
        ("prefill+decode", &c.split_auto),
    ] {
        let mut jct = rep.jct.clone();
        t.row(vec![
            label.into(),
            rep.policy.clone(),
            fmt::dur(rep.mean_jct()),
            fmt::dur(jct.p99()),
            fmt::dur(rep.mean_ttft()),
            fmt::dur(rep.makespan_s),
            bench_util::reduction_pct(c.fused_best_jct(), rep.mean_jct()),
        ]);
    }
    t.print();
    for rep in [&c.fused, &c.fused_wide, &c.split_static, &c.split_auto] {
        assert_eq!(rep.jct.len(), wl.len(), "{}: incomplete run", rep.policy);
    }
    // The split must beat fused at EITHER batch cap — the win certifies
    // disaggregation itself, not batch-cap tuning.
    assert!(
        c.split_static.mean_jct() < c.fused_best_jct(),
        "disaggregated pools must beat the best fused pool on mean JCT ({:.3}s !< {:.3}s)",
        c.split_static.mean_jct(),
        c.fused_best_jct()
    );
    assert!(
        c.split_static.mean_ttft() < c.fused_best_ttft(),
        "disaggregated pools must beat the best fused pool on mean TTFT ({:.3}s !< {:.3}s)",
        c.split_static.mean_ttft(),
        c.fused_best_ttft()
    );
    assert!(
        c.split_auto.mean_jct() < c.fused_best_jct(),
        "autoscaled split must keep the JCT win ({:.3}s !< {:.3}s)",
        c.split_auto.mean_jct(),
        c.fused_best_jct()
    );
    assert!(c.split_auto.max_slots <= budget, "autoscaled split exceeded its GPU budget");
    assert!(
        c.split_auto.stage_scale_ups.iter().all(|&u| u >= 1),
        "each pool must record at least one scale event: {:?}",
        c.split_auto.stage_scale_ups
    );
    println!(
        "\nP/D split vs best fused on {}: mean JCT {} -> {}, mean TTFT {} -> {} (prefill pool {} ups, decode pool {} ups)",
        wl.name,
        fmt::dur(c.fused_best_jct()),
        fmt::dur(c.split_static.mean_jct()),
        fmt::dur(c.fused_best_ttft()),
        fmt::dur(c.split_static.mean_ttft()),
        c.split_auto.stage_scale_ups[0],
        c.split_auto.stage_scale_ups[1],
    );

    // Global prefix cache (ISSUE 7): on the shared-prefix trace the
    // prefix-cached engine must beat the cold engine on BOTH mean TTFT
    // and mean JCT at the same GPU budget, for EVERY one of 32 seeds.
    // Asserted; also pinned by `tests/scheduler.rs` and the
    // `omni-serve bench --trace shared-prefix` CI smoke.
    let mut t = Table::new(
        "Global prefix cache vs cold engine (shared-prefix trace, equal budget)",
        &["seed", "arm", "mean TTFT", "mean JCT", "p99 JCT", "makespan", "attached tok"],
    );
    let (mut worst_ttft, mut worst_jct) = (f64::INFINITY, f64::INFINITY);
    for seed in 1..=32u64 {
        let c = prefix_cache_comparison(seed, MAX_BATCH);
        assert_eq!(c.cached.jct.len(), c.cold.jct.len(), "seed {seed}: incomplete run");
        assert!(
            c.cached.mean_ttft() < c.cold.mean_ttft(),
            "seed {seed}: cached {:.4}s !< cold {:.4}s mean TTFT",
            c.cached.mean_ttft(),
            c.cold.mean_ttft()
        );
        assert!(
            c.cached.mean_jct() < c.cold.mean_jct(),
            "seed {seed}: cached {:.4}s !< cold {:.4}s mean JCT",
            c.cached.mean_jct(),
            c.cold.mean_jct()
        );
        worst_ttft = worst_ttft.min(c.ttft_margin());
        worst_jct = worst_jct.min(c.jct_margin());
        // Keep the table readable: print the first three seeds only.
        if seed <= 3 {
            for rep in [&c.cold, &c.cached] {
                let mut jct = rep.jct.clone();
                t.row(vec![
                    seed.to_string(),
                    rep.policy.clone(),
                    fmt::dur(rep.mean_ttft()),
                    fmt::dur(rep.mean_jct()),
                    fmt::dur(jct.p99()),
                    fmt::dur(rep.makespan_s),
                    rep.tokens_skipped.to_string(),
                ]);
            }
        }
    }
    t.print();
    println!(
        "prefix cache vs cold over 32 seeds: worst TTFT margin {:+.1}%, worst JCT margin {:+.1}%",
        100.0 * worst_ttft,
        100.0 * worst_jct,
    );

    // Cross-node placement (ISSUE 8): on the prefill-heavy trace over a
    // 3-node cluster with a 10 Gbps link model, transfer-cost-aware
    // placement (co-located prefill->decode, cross-node only on the
    // light vocoder handoff) must beat round-robin placement on mean
    // JCT for EVERY one of 32 seeds at identical hardware.  Asserted;
    // also pinned by `tests/scheduler.rs` and the
    // `omni-serve bench --trace cross-node` CI smoke.
    let mut t = Table::new(
        "Transfer-aware vs round-robin placement (3-node cluster, 10 Gbps link model)",
        &["seed", "placement", "mean JCT", "p99 JCT", "makespan", "cross hops", "wire time"],
    );
    let (mut worst_xnode, mut sum_xnode) = (f64::INFINITY, 0.0);
    for seed in 1..=32u64 {
        let c = cross_node_comparison(seed);
        assert_eq!(
            c.transfer_aware.jct.len(),
            c.round_robin.jct.len(),
            "seed {seed}: incomplete run"
        );
        assert!(
            c.transfer_aware.mean_jct() < c.round_robin.mean_jct(),
            "seed {seed}: transfer-aware {:.4}s !< round-robin {:.4}s mean JCT",
            c.transfer_aware.mean_jct(),
            c.round_robin.mean_jct()
        );
        assert!(
            c.transfer_aware.cross_transfers < c.round_robin.cross_transfers,
            "seed {seed}: the win must come from moving fewer bytes across the link"
        );
        worst_xnode = worst_xnode.min(c.jct_margin());
        sum_xnode += c.jct_margin();
        // Keep the table readable: print the first three seeds only.
        if seed <= 3 {
            for rep in [&c.round_robin, &c.transfer_aware] {
                let mut jct = rep.jct.clone();
                t.row(vec![
                    seed.to_string(),
                    rep.policy.clone(),
                    fmt::dur(rep.mean_jct()),
                    fmt::dur(jct.p99()),
                    fmt::dur(rep.makespan_s),
                    rep.cross_transfers.to_string(),
                    fmt::dur(rep.transfer_s),
                ]);
            }
        }
    }
    t.print();
    println!(
        "transfer-aware vs round-robin over 32 seeds: mean JCT margin {:+.1}%, worst {:+.1}%",
        100.0 * sum_xnode / 32.0,
        100.0 * worst_xnode,
    );

    // Fractional GPU sharing (ISSUE 9): on the branching fan-out trace
    // (one prompt → parallel image + speech arms), carving the encoder
    // and vocoder into 300-milli slots co-resident on one device frees
    // a device for a third DiT replica; at equal hardware the packed-
    // fractional layout must beat whole-device packing on mean JCT for
    // EVERY one of 32 seeds.  Asserted; also pinned by
    // `tests/scheduler.rs` and the `omni-serve bench --trace fractional`
    // CI smoke.
    let mut t = Table::new(
        "Packed-fractional vs whole-device layout (branching fan-out, 6 devices)",
        &["seed", "layout", "mean JCT", "p99 JCT", "makespan"],
    );
    let (mut worst_frac, mut sum_frac) = (f64::INFINITY, 0.0);
    for seed in 1..=32u64 {
        let c = fractional_comparison(seed);
        assert_eq!(
            c.fractional.jct.len(),
            c.whole.jct.len(),
            "seed {seed}: incomplete run"
        );
        assert!(
            c.fractional.mean_jct() < c.whole.mean_jct(),
            "seed {seed}: fractional {:.4}s !< whole {:.4}s mean JCT",
            c.fractional.mean_jct(),
            c.whole.mean_jct()
        );
        worst_frac = worst_frac.min(c.jct_margin());
        sum_frac += c.jct_margin();
        // Keep the table readable: print the first three seeds only.
        if seed <= 3 {
            for rep in [&c.whole, &c.fractional] {
                let mut jct = rep.jct.clone();
                t.row(vec![
                    seed.to_string(),
                    rep.label.clone(),
                    fmt::dur(rep.mean_jct()),
                    fmt::dur(jct.p99()),
                    fmt::dur(rep.makespan_s),
                ]);
            }
        }
    }
    t.print();
    println!(
        "fractional vs whole over 32 seeds: mean JCT margin {:+.1}%, worst {:+.1}%",
        100.0 * sum_frac / 32.0,
        100.0 * worst_frac,
    );

    // Headline check (also pinned by `tests/scheduler.rs`): continuous
    // batching must beat FIFO mean JCT on the bundled AR traces.
    let wl = datasets::librispeech(1, n, 0.0);
    let fifo = run(&mut FifoPolicy, &wl);
    let cont = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, &wl);
    println!(
        "\ncontinuous batching vs FIFO on {}: mean JCT {} -> {} ({} reduction)",
        wl.name,
        fmt::dur(fifo.mean_jct()),
        fmt::dur(cont.mean_jct()),
        bench_util::reduction_pct(fifo.mean_jct(), cont.mean_jct()),
    );
    assert!(
        cont.mean_jct() < fifo.mean_jct(),
        "continuous batching must beat FIFO on the bundled AR trace"
    );
}
