//! Ablations over the design choices DESIGN.md calls out:
//!   1. streaming stage output on/off          (TTFT, §3.3)
//!   2. chunked prefill on/off                 (JCT under mixed load)
//!   3. per-stage batch cap sweep              (throughput scaling)
//!   4. step-cache threshold sweep             (DiT quality/speed knob)
//!   5. multi-step fused decode sweep          (dispatch amortization)
//!
//! Run a subset: `cargo bench --bench ablations -- streaming batching`

use std::sync::Arc;

use omni_serve::bench_util::{self, Table};
use omni_serve::config::presets;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::trace::datasets;

fn want(which: &[String], name: &str) -> bool {
    which.is_empty() || which.iter().any(|w| w == name)
}

fn main() -> anyhow::Result<()> {
    let which: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let artifacts = bench_util::load_artifacts();
    let n = bench_util::bench_n(6);

    if want(&which, "streaming") {
        let wl = datasets::ucf101(3, n, 0.0);
        let mut t = Table::new(
            "Ablation: streaming stage output (qwen3-omni, ucf101-sim)",
            &["streaming", "TTFT(s)", "JCT(s)"],
        );
        for streaming in [true, false] {
            let orch = Orchestrator::new(
                presets::qwen3_omni(),
                Arc::clone(&artifacts),
                Registry::builtin(),
                RunOptions { streaming, ..Default::default() },
            )?;
            let r = orch.run_workload(&wl, Some("talker"))?.report;
            t.row(vec![
                streaming.to_string(),
                format!("{:.2}", r.mean_ttft()),
                format!("{:.2}", r.mean_jct()),
            ]);
        }
        t.print();
    }

    if want(&which, "chunked_prefill") {
        let wl = datasets::ucf101(4, n, 0.0); // video = long prompts
        let mut t = Table::new(
            "Ablation: chunked prefill (qwen3-omni, long multimodal prompts)",
            &["chunked", "TTFT(s)", "JCT(s)"],
        );
        for chunked in [true, false] {
            let mut cfg = presets::qwen3_omni();
            for s in &mut cfg.stages {
                s.chunked_prefill = chunked;
            }
            let orch = Orchestrator::new(
                cfg,
                Arc::clone(&artifacts),
                Registry::builtin(),
                RunOptions::default(),
            )?;
            let r = orch.run_workload(&wl, Some("talker"))?.report;
            t.row(vec![
                chunked.to_string(),
                format!("{:.2}", r.mean_ttft()),
                format!("{:.2}", r.mean_jct()),
            ]);
        }
        t.print();
    }

    if want(&which, "batching") {
        let wl = datasets::seedtts(9, n.max(8), 0.0);
        let mut t = Table::new(
            "Ablation: per-stage batch cap (mimo-audio, seedtts-sim)",
            &["max_batch", "wall(s)", "JCT(s)", "backbone TPS"],
        );
        for cap in [1usize, 2, 4, 8] {
            let mut cfg = presets::mimo_audio(1);
            for s in &mut cfg.stages {
                s.max_batch = cap;
            }
            let orch = Orchestrator::new(
                cfg,
                Arc::clone(&artifacts),
                Registry::builtin(),
                RunOptions::default(),
            )?;
            let summary = orch.run_workload(&wl, Some("backbone"))?;
            t.row(vec![
                cap.to_string(),
                format!("{:.2}", summary.wall_s),
                format!("{:.2}", summary.report.mean_jct()),
                format!("{:.1}", summary.report.stage_tps("backbone")),
            ]);
        }
        t.print();
    }

    if want(&which, "stepcache") {
        let wl = datasets::vbench(6, 3, 0.0, 20, false);
        let mut t = Table::new(
            "Ablation: TeaCache-style step-cache threshold (qwen_image)",
            &["threshold", "JCT(s)", "steps run", "steps skipped"],
        );
        for thr in [0.0f32, 0.10, 0.15, 0.25] {
            let orch = Orchestrator::new(
                presets::dit_single("qwen_image", 20, thr),
                Arc::clone(&artifacts),
                Registry::builtin(),
                RunOptions::default(),
            )?;
            let summary = orch.run_workload(&wl, None)?;
            let d = summary.stages.iter().find_map(|s| s.diffusion.clone()).unwrap_or_default();
            t.row(vec![
                format!("{thr}"),
                format!("{:.2}", summary.report.mean_jct()),
                d.steps_run.to_string(),
                d.steps_skipped.to_string(),
            ]);
        }
        t.print();
    }

    if want(&which, "multistep") {
        let wl = datasets::seedtts(12, n, 0.0);
        let mut t = Table::new(
            "Ablation: fused multi-step decode (mimo-audio)",
            &["multi_step", "wall(s)", "JCT(s)", "RTF"],
        );
        for ms in [1usize, omni_serve::engine::ar::SCAN_STEPS] {
            let orch = Orchestrator::new(
                presets::mimo_audio(ms),
                Arc::clone(&artifacts),
                Registry::builtin(),
                RunOptions::default(),
            )?;
            let summary = orch.run_workload(&wl, Some("backbone"))?;
            t.row(vec![
                ms.to_string(),
                format!("{:.2}", summary.wall_s),
                format!("{:.2}", summary.report.mean_jct()),
                format!("{:.3}", summary.report.mean_rtf()),
            ]);
        }
        t.print();
    }

    Ok(())
}
