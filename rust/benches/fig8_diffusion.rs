//! Fig. 8 reproduction: the diffusion engine vs a Diffusers-like serial
//! baseline on DiT image/video models (Qwen-Image, Qwen-Image-Edit,
//! Wan2.2-T2V, Wan2.2-I2V sims).
//!
//! Paper reference: omni-serve's diffusion engine is ~1.26x faster
//! overall (fused attention backend + step caching + batched CFG).

use std::sync::Arc;

use omni_serve::baseline::{run_monolithic, BaselineOptions};
use omni_serve::bench_util::{self, Table};
use omni_serve::config::presets;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::trace::datasets;

fn main() -> anyhow::Result<()> {
    let artifacts = bench_util::load_artifacts();
    let n = bench_util::bench_n(3);

    let mut t = Table::new(
        "Fig. 8 — DiT generation JCT vs Diffusers-like baseline (paper: ~1.26x overall)",
        &["model", "task", "baseline JCT(s)", "omni-serve JCT(s)", "speedup"],
    );
    let mut geo = 1.0f64;
    let mut cnt = 0usize;
    for (model, task, image_cond) in [
        ("qwen_image", "T2I", false),
        ("qwen_image_edit", "I2I", true),
        ("wan22_t2v", "T2V", false),
        ("wan22_i2v", "I2V", true),
    ] {
        let wl = datasets::vbench(23, n, 0.0, 20, image_cond);
        // Diffusers-like: serial, no step cache.
        let base = run_monolithic(
            &artifacts,
            &presets::dit_single(model, 20, 0.0),
            &wl,
            &BaselineOptions { lazy_compile: false, no_kv_cache: false },
            None,
        )?;
        let orch = Orchestrator::new(
            presets::dit_single(model, 20, 0.10),
            Arc::clone(&artifacts),
            Registry::builtin(),
            RunOptions::default(),
        )?;
        let ours = orch.run_workload(&wl, None)?.report;
        let sp = base.mean_jct() / ours.mean_jct().max(1e-9);
        geo *= sp;
        cnt += 1;
        t.row(vec![
            model.into(),
            task.into(),
            format!("{:.2}", base.mean_jct()),
            format!("{:.2}", ours.mean_jct()),
            format!("{sp:.2}x"),
        ]);
    }
    t.print();
    println!("overall (geomean): {:.2}x  (paper: 1.26x)", geo.powf(1.0 / cnt as f64));
    Ok(())
}
