//! §Perf microbenchmarks: per-layer hot-path timings used for the
//! optimization pass (EXPERIMENTS.md §Perf).
//!
//! L3 measurements: AR decode step cost decomposition (executable time vs
//! host KV marshaling) across batch buckets, prefill chunk cost, DiT step
//! cost, and connector overhead per decode step.

use omni_serve::bench_util::{self, Table};
use omni_serve::engine::ar::{token_job, ArEngine, ArEngineOptions};
use omni_serve::engine::SamplingParams;
use omni_serve::tokenizer::BOS_ID;

fn main() -> anyhow::Result<()> {
    let artifacts = bench_util::load_artifacts();
    let steps = bench_util::bench_n(48);

    let mut t = Table::new(
        "Perf: AR decode step decomposition (thinker3 = largest model)",
        &["batch", "steps", "total/step", "exec/step", "marshal/step", "marshal %", "tok/s"],
    );
    for batch in [1usize, 2, 4, 8] {
        let mut e = ArEngine::new(
            &artifacts,
            "thinker3",
            ArEngineOptions { max_batch: batch, stream_chunk: 0, ..Default::default() },
        )?;
        e.submit_many((0..batch).map(|i| {
            token_job(
                i as u64,
                &[BOS_ID, 7 + i as u32],
                SamplingParams { max_new_tokens: steps, ignore_eos: true, ..Default::default() },
            )
        }));
        let t0 = std::time::Instant::now();
        e.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        let iters = e.stats.decode_calls.max(1) as f64;
        let toks = e.stats.decode_tokens as f64;
        t.row(vec![
            batch.to_string(),
            format!("{}", e.stats.decode_calls),
            format!("{:.2}ms", wall / iters * 1e3),
            format!("{:.2}ms", e.stats.exec_seconds / iters * 1e3),
            format!("{:.2}ms", e.stats.marshal_seconds / iters * 1e3),
            format!("{:.0}%", 100.0 * e.stats.marshal_seconds / wall),
            format!("{:.1}", toks / wall),
        ]);
    }
    t.print();

    // Prefill throughput (chunked).
    let mut t = Table::new(
        "Perf: chunked prefill throughput (thinker3)",
        &["batch", "prompt", "prefill tok/s"],
    );
    for batch in [1usize, 4] {
        let mut e = ArEngine::new(
            &artifacts,
            "thinker3",
            ArEngineOptions { max_batch: batch, stream_chunk: 0, ..Default::default() },
        )?;
        let prompt: Vec<u32> = std::iter::once(BOS_ID).chain(2..128).collect();
        for i in 0..batch {
            e.submit(token_job(
                i as u64,
                &prompt,
                SamplingParams { max_new_tokens: 1, ignore_eos: true, ..Default::default() },
            ));
        }
        let t0 = std::time::Instant::now();
        e.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        t.row(vec![
            batch.to_string(),
            prompt.len().to_string(),
            format!("{:.0}", e.stats.prefill_tokens as f64 / wall),
        ]);
    }
    t.print();
    Ok(())
}
