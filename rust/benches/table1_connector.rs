//! Table 1 reproduction: unified-connector data-transfer latency for the
//! two Qwen-Omni edges (Thinker2Talker hidden states, Talker2Vocoder
//! codec tokens), per connector transport.
//!
//! Paper reference (Qwen2.5-Omni): Thinker2Talker shm 5.49 ms / Mooncake
//! 8.28 ms; Talker2Vocoder 0.53 ms.  The shape to reproduce: shm < TCP,
//! and the token edge is ~10x cheaper than the hidden-state edge.

use omni_serve::bench_util::{self, Table};
use omni_serve::config::ConnectorKind;
use omni_serve::connector::{self, tcp::MooncakeStore};
use omni_serve::engine::StageItem;
use omni_serve::runtime::HostTensor;
use omni_serve::util::fmt;

fn payload_hiddens() -> StageItem {
    // Thinker2Talker: one request's hidden-state stream for a Qwen2.5-sim
    // response (~150 paper tokens -> 38 scaled, d=256) per stream chunk
    // of 16 plus tokens.
    StageItem::new(1)
        .with("tokens", HostTensor::i32(vec![38], vec![7; 38]))
        .with("hiddens", HostTensor::f32(vec![38, 256], vec![0.5; 38 * 256]))
}

fn payload_tokens() -> StageItem {
    // Talker2Vocoder: one codec chunk (64 frames of token ids).
    StageItem::new(1).with("tokens", HostTensor::i32(vec![64], vec![9; 64]))
}

fn bench_edge(kind: ConnectorKind, store: Option<&str>, item: &StageItem, iters: usize) -> f64 {
    let (mut tx, mut rx) = connector::pair(kind, "bench", store).unwrap();
    // Warmup.
    for _ in 0..8 {
        tx.send(item.clone()).unwrap();
        rx.recv().unwrap().unwrap();
    }
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        tx.send(item.clone()).unwrap();
        rx.recv().unwrap().unwrap();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() -> anyhow::Result<()> {
    let store = MooncakeStore::spawn("127.0.0.1:0")?;
    let addr = store.addr().to_string();
    let iters = bench_util::bench_n(200);

    let mut t = Table::new(
        "Table 1 — unified connector transfer latency (paper: T2T shm 5.49ms / Mooncake 8.28ms; T2V 0.53ms)",
        &["edge", "payload", "inline", "shared memory", "mooncake (TCP)"],
    );
    for (edge, item) in [
        ("Thinker2Talker", payload_hiddens()),
        ("Talker2Vocoder", payload_tokens()),
    ] {
        let inline = bench_edge(ConnectorKind::Inline, None, &item, iters);
        let shm = bench_edge(ConnectorKind::Shm, None, &item, iters);
        let tcp = bench_edge(ConnectorKind::Tcp, Some(&addr), &item, iters);
        t.row(vec![
            edge.into(),
            fmt::bytes(item.payload_bytes()),
            fmt::dur(inline),
            fmt::dur(shm),
            fmt::dur(tcp),
        ]);
    }
    t.print();
    println!("(one-way send->recv latency, mean of {iters} transfers)");
    Ok(())
}
