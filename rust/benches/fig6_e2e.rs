//! Fig. 6 reproduction: end-to-end RTF / JCT / Thinker TPS / Talker TPS
//! on the Qwen-Omni pipelines, omni-serve (disaggregated) vs the
//! monolithic HF-style baseline, across the three input modalities
//! (librispeech/food101/ucf101 sims).
//!
//! Paper reference points: Qwen2.5-Omni RTF -61.4% JCT -61.6%
//! (Thinker TPS x1.29, Talker x1.97); Qwen3-Omni RTF -90.7% JCT -91.4%
//! (Thinker x12.97, Talker x7.98 — the baseline lacks execution-graph
//! compilation, modeled as per-request recompilation).

use std::sync::Arc;

use omni_serve::baseline::{run_monolithic, BaselineOptions};
use omni_serve::bench_util::{self, Table};
use omni_serve::config::presets;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::trace::datasets;

fn main() -> anyhow::Result<()> {
    let artifacts = bench_util::load_artifacts();
    let n = bench_util::bench_n(6);
    let seed = 42;

    let mut table = Table::new(
        "Fig. 6 — end-to-end on Qwen-Omni models",
        &["model", "dataset", "system", "RTF", "JCT(s)", "thinker TPS", "talker TPS"],
    );
    let mut summary = Table::new(
        "Fig. 6 — reductions vs baseline (paper: Qwen2.5 RTF-61.4%/JCT-61.6%; Qwen3 RTF-90.7%/JCT-91.4%)",
        &["model", "dataset", "RTF red.", "JCT red.", "thinker TPS x", "talker TPS x"],
    );

    for (model, cfg_fn, baseline_lazy) in [
        ("qwen2.5-omni", presets::qwen25_omni as fn() -> omni_serve::config::PipelineConfig, false),
        ("qwen3-omni", presets::qwen3_omni as fn() -> omni_serve::config::PipelineConfig, true),
    ] {
        for (dsname, wl) in [
            ("librispeech", datasets::librispeech(seed, n, 0.0)),
            ("food101", datasets::food101(seed, n, 0.0)),
            ("ucf101", datasets::ucf101(seed, n, 0.0)),
        ] {
            // --- disaggregated ---
            let orch = Orchestrator::new(
                cfg_fn(),
                Arc::clone(&artifacts),
                Registry::builtin(),
                RunOptions::default(),
            )?;
            let ours = orch.run_workload(&wl, Some("talker"))?.report;
            // --- baseline ---
            let base = run_monolithic(
                &artifacts,
                &cfg_fn(),
                &wl,
                &BaselineOptions { lazy_compile: baseline_lazy, no_kv_cache: false },
                Some("talker"),
            )?;
            for (sys, r) in [("baseline", &base), ("omni-serve", &ours)] {
                table.row(vec![
                    model.into(),
                    dsname.into(),
                    sys.into(),
                    format!("{:.3}", r.mean_rtf()),
                    format!("{:.2}", r.mean_jct()),
                    format!("{:.1}", r.stage_tps("thinker")),
                    format!("{:.1}", r.stage_tps("talker")),
                ]);
            }
            summary.row(vec![
                model.into(),
                dsname.into(),
                bench_util::reduction_pct(base.mean_rtf(), ours.mean_rtf()),
                bench_util::reduction_pct(base.mean_jct(), ours.mean_jct()),
                format!("{:.2}x", ours.stage_tps("thinker") / base.stage_tps("thinker").max(1e-9)),
                format!("{:.2}x", ours.stage_tps("talker") / base.stage_tps("talker").max(1e-9)),
            ]);
        }
    }
    table.print();
    summary.print();
    Ok(())
}
