//! API-compatible **stub** for the `xla-rs` PJRT bindings.
//!
//! The real runtime executes AOT-lowered HLO-text artifacts (produced by
//! `python/compile/aot.py`) on the PJRT CPU client.  This build environment
//! has neither crates.io access nor a PJRT plugin, so this crate provides
//! the exact type/method surface `omni_serve::runtime::stage_rt` compiles
//! against, with one deliberate gate: [`PjRtClient::cpu`] returns an error
//! explaining how to enable the real backend.
//!
//! Everything downstream of that gate degrades cleanly: engines fail to
//! construct with a clear message, and the integration tests / benches that
//! need compiled artifacts skip (they already skip when `artifacts/
//! manifest.json` is absent, which is also the case in this environment).
//!
//! To run real model compute, replace this path dependency in
//! `rust/Cargo.toml` with the actual `xla` crate (and run `make artifacts`);
//! no source change in `omni_serve` is required — the method signatures
//! below are kept in lockstep with the subset of `xla-rs` the runtime uses.

use std::fmt;

/// Error type for all stubbed operations.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const GATE: &str = "PJRT runtime unavailable: this build uses the vendored API stub \
                    (rust/vendor/xla). Point the `xla` dependency at the real xla-rs \
                    bindings and rebuild to execute compiled artifacts";

fn gate<T>() -> Result<T> {
    Err(Error(GATE.to_string()))
}

/// Host element types accepted by [`PjRtClient::buffer_from_host_buffer`]
/// and [`Literal::to_vec`].
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for f64 {}
impl NativeType for i64 {}

/// Stub of the PJRT client.  [`PjRtClient::cpu`] is the gate — it always
/// errors, so no other method is reachable at runtime.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// In the real bindings this creates the CPU PJRT client; here it is
    /// the single gating point for the whole runtime layer.
    pub fn cpu() -> Result<Self> {
        gate()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        gate()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        gate()
    }
}

/// Stub of a device-resident buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        gate()
    }
}

/// Stub of a compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed argument buffers; the real API returns one
    /// result list per device.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        gate()
    }
}

/// Stub of a host literal (downloaded tensor).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        gate()
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        gate()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        gate()
    }
}

/// Shape of an array literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Stub of a parsed HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        gate()
    }
}

/// Stub of an XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_gated_with_actionable_message() {
        let err = PjRtClient::cpu().err().expect("stub must gate");
        let msg = err.to_string();
        assert!(msg.contains("vendored API stub"), "{msg}");
        assert!(msg.contains("xla-rs"), "{msg}");
    }
}
