//! Offline shim for the [`anyhow`](https://docs.rs/anyhow) API surface this
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension trait,
//! and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build environment has no crates.io access, so this crate reimplements
//! the small subset we need on top of `std` only.  Semantics intentionally
//! mirror the real crate:
//!
//! * `Display` prints the outermost message; `{:#}` (alternate) prints the
//!   whole cause chain joined with `": "`; `Debug` prints an `anyhow`-style
//!   multi-line report (so `fn main() -> anyhow::Result<()>` output is
//!   readable).
//! * Any `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//!   via `?`, capturing its `source()` chain.
//! * [`Error`] deliberately does **not** implement `std::error::Error`, so
//!   the blanket conversion cannot conflict with the reflexive `From`.

use std::fmt;

/// A dynamic error: an ordered cause chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err())
            .context("loading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
    }

    #[test]
    fn debug_is_multiline_report() {
        let e = Error::msg("inner").context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("0: inner"));
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let v = 3;
        assert_eq!(format!("{}", anyhow!("v = {v}")), "v = 3");
        assert_eq!(format!("{}", anyhow!("v = {}", v)), "v = 3");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xFF])?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
