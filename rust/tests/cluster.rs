//! Cluster-subsystem integration tests (ISSUE 8): the controller/agent
//! loopback lifecycle — register, place, assign, drive a trace through
//! chained store streams, drain with per-edge stats — plus the liveness
//! contract: a node that goes silent or hangs up mid-run surfaces as a
//! structured error naming the node, never a hang.  The two-PROCESS
//! variant (real `omni-serve agent` child) lives in
//! `tests/serve_smoke.rs`; these run the agents in-process for speed.

use std::io::Read;
use std::net::TcpListener;
use std::time::Duration;

use omni_serve::cluster::agent::spawn_in_process;
use omni_serve::cluster::wire::{write_msg, CtlMsg};
use omni_serve::cluster::{run_cluster_trace, AgentOptions, ControllerOptions};
use omni_serve::config::{PlacementPolicy, TransportConfig};

/// Fast control-plane cadence so the suite stays quick: beats every
/// 2 ms, silence declared after 2 s.
fn fast_transport() -> TransportConfig {
    TransportConfig { heartbeat_s: 0.002, read_timeout_s: 2.0 }
}

fn agent_opts(node_id: &str) -> AgentOptions {
    let mut o = AgentOptions::new(node_id, "127.0.0.1:0");
    o.transport = fast_transport();
    o
}

#[test]
fn loopback_cluster_trace_runs_end_to_end_with_per_edge_stats() {
    // Two in-process agents hosting a 3-stage chain.  Round-robin
    // placement scatters the stages (0, 1, 0), so every frame genuinely
    // crosses between both agents' relay workers.
    let (addr_a, handle_a) = spawn_in_process(agent_opts("n0")).unwrap();
    let (addr_b, handle_b) = spawn_in_process(agent_opts("n1")).unwrap();

    let payloads: Vec<Vec<u8>> = (0..24u8).map(|i| vec![i; 32 + i as usize]).collect();
    let opts = ControllerOptions {
        transport: fast_transport(),
        placement: PlacementPolicy::RoundRobin,
        ..Default::default()
    };
    let report = run_cluster_trace(
        &[addr_a.to_string(), addr_b.to_string()],
        &["prefill", "decode", "vocoder"],
        &payloads,
        &opts,
    )
    .unwrap();

    assert_eq!(report.nodes, vec!["n0".to_string(), "n1".to_string()]);
    assert_eq!(report.completed, 24, "every frame must survive the whole chain");
    assert_eq!(report.plan.placements.len(), 3, "one replica per stage");
    let nodes: Vec<usize> = report.plan.placements.iter().map(|p| p.node).collect();
    assert_eq!(nodes, vec![0, 1, 0], "round-robin alternates over the registered nodes");
    // Per-hop transfer counters crossed the control plane in `Stats`,
    // labelled `{node}/{stage}#{replica}`.  Every hop moved every frame
    // plus the end-of-stream sentinel.
    assert_eq!(report.edges.len(), 3);
    let total_bytes: usize = payloads.iter().map(|p| p.len()).sum();
    for e in &report.edges {
        assert!(
            e.label.starts_with("n0/") || e.label.starts_with("n1/"),
            "stat label must name its node: {e:?}"
        );
        assert_eq!(e.frames, 25, "24 payloads + sentinel: {e:?}");
        assert_eq!(e.bytes as usize, total_bytes, "{e:?}");
        assert!(e.p95_ms >= e.p50_ms, "{e:?}");
    }
    assert!(report.heartbeats > 0, "agents must have heartbeated during the run");

    // Both agents drained cleanly and report what they hosted.
    let rep_a = handle_a.join().unwrap().unwrap();
    let rep_b = handle_b.join().unwrap().unwrap();
    assert_eq!(rep_a.assignments, 2, "round-robin gave n0 stages 0 and 2");
    assert_eq!(rep_b.assignments, 1);
    assert_eq!(rep_a.frames_moved + rep_b.frames_moved, 3 * 24);
}

#[test]
fn transfer_aware_policy_colocates_a_chain_that_fits_one_node() {
    // With equal edge weights and room to spare, transfer-aware
    // placement chains every stage onto the upstream's node: zero
    // cross-node hops, the whole pipeline on the first agent.
    let (addr_a, handle_a) = spawn_in_process(agent_opts("ta0")).unwrap();
    let (addr_b, handle_b) = spawn_in_process(agent_opts("ta1")).unwrap();
    let opts = ControllerOptions { transport: fast_transport(), ..Default::default() };
    let payloads = vec![b"one".to_vec(), b"two".to_vec()];
    let report =
        run_cluster_trace(&[addr_a.to_string(), addr_b.to_string()], &["a", "b"], &payloads, &opts)
            .unwrap();
    assert_eq!(report.completed, 2);
    let nodes: Vec<usize> = report.plan.placements.iter().map(|p| p.node).collect();
    assert_eq!(nodes, vec![0, 0], "transfer-aware co-locates the edge's endpoints");
    assert_eq!(report.plan.cross_pairs(), 0);
    let rep_a = handle_a.join().unwrap().unwrap();
    let rep_b = handle_b.join().unwrap().unwrap();
    assert_eq!(rep_a.assignments, 2);
    assert_eq!(rep_b.assignments, 0, "the second node idles; nothing crossed to it");
}

#[test]
fn silent_node_aborts_the_run_with_a_structured_error_naming_it() {
    // A zombie agent: registers, then never heartbeats.  The controller
    // must abort with an error naming the node — not hang the collector.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let zombie = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        write_msg(
            &mut s,
            &CtlMsg::Register { node_id: "zombie".into(), gpus: 2, device_bytes: 1 << 30 },
        )
        .unwrap();
        // Hold the socket open silently until the controller gives up.
        std::thread::sleep(Duration::from_secs(2));
        drop(s);
    });

    let opts = ControllerOptions {
        transport: TransportConfig { heartbeat_s: 0.05, read_timeout_s: 0.3 },
        ..Default::default()
    };
    let err = run_cluster_trace(&[addr.to_string()], &["relay"], &[b"x".to_vec()], &opts)
        .unwrap_err()
        .to_string();
    assert!(err.contains("zombie"), "error must name the dead node: {err}");
    assert!(err.contains("no heartbeat within the read timeout"), "{err}");
    zombie.join().unwrap();
}

#[test]
fn node_hangup_mid_run_aborts_with_a_structured_error() {
    // A crasher: registers, then drops the control stream.  Distinct
    // message from the silent case — the peer hung up, it did not stall.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let crasher = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        write_msg(
            &mut s,
            &CtlMsg::Register { node_id: "crasher".into(), gpus: 2, device_bytes: 1 << 30 },
        )
        .unwrap();
        drop(s);
    });

    let opts = ControllerOptions {
        transport: TransportConfig { heartbeat_s: 0.05, read_timeout_s: 1.0 },
        ..Default::default()
    };
    let err = run_cluster_trace(&[addr.to_string()], &["relay"], &[b"x".to_vec()], &opts)
        .unwrap_err()
        .to_string();
    assert!(err.contains("crasher"), "error must name the dead node: {err}");
    assert!(err.contains("hung up"), "{err}");
    crasher.join().unwrap();
}

#[test]
fn agent_surfaces_a_dead_controller_instead_of_hanging() {
    // The symmetric contract: an agent whose controller vanishes after
    // the handshake errors out naming the silent peer.
    let mut opts = agent_opts("orphan");
    opts.transport = TransportConfig { heartbeat_s: 0.05, read_timeout_s: 0.3 };
    let (addr, handle) = spawn_in_process(opts).unwrap();

    let mut ctl = std::net::TcpStream::connect(addr).unwrap();
    // Consume the Register frame, then go silent WITHOUT heartbeating.
    let mut buf = [0u8; 256];
    let _ = ctl.read(&mut buf).unwrap();
    std::thread::sleep(Duration::from_millis(600));
    drop(ctl);

    let err = handle.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("orphan"), "error must name the agent: {err}");
    assert!(err.contains("controller dead"), "{err}");
}
