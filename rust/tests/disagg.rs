//! Prefill/decode disaggregation acceptance tests (ISSUE 4, paper §3.4).
//!
//! Artifact-free: the `simulate_disagg` comparison on the prefill-heavy
//! mixed trace (the same harness as `benches/sched_batching.rs` and the
//! `omni-serve bench --trace prefill-heavy` CI smoke).  With compiled
//! artifacts: the real prefill engine → `KvHandoff` → decode engine path
//! must reproduce the fused engine's greedy tokens bit-for-bit, and the
//! decode engine's block import must dedup shared prefixes.

use omni_serve::config::StageRole;
use omni_serve::engine::ar::{token_job, ArEngine, ArEngineOptions};
use omni_serve::engine::{SamplingParams, StageItem};
use omni_serve::kv_transfer::{KvHandoff, KV_TENSOR};
use omni_serve::runtime::Artifacts;
use omni_serve::scheduler::sim::simulate_disagg;
use omni_serve::tokenizer::BOS_ID;
use omni_serve::trace::datasets;

// -------------------------------------------------------------------------
// Sim-level acceptance (no artifacts needed).
// -------------------------------------------------------------------------

#[test]
fn disagg_beats_fused_on_the_prefill_heavy_trace_at_equal_budget() {
    let budget = 4usize;
    let wl = datasets::prefill_heavy(1, 64, 56.0);
    let c = simulate_disagg(&wl, budget);
    for rep in [&c.fused, &c.fused_wide, &c.split_static, &c.split_auto] {
        assert_eq!(rep.jct.len(), wl.len(), "{}: incomplete run", rep.policy);
    }
    // The headline: split pools win BOTH latency metrics at equal GPU,
    // against the fused pool at WHICHEVER batch cap suits it better.
    assert!(
        c.split_static.mean_jct() < c.fused_best_jct(),
        "split {:.4}s !< best fused {:.4}s mean JCT",
        c.split_static.mean_jct(),
        c.fused_best_jct()
    );
    assert!(
        c.split_static.mean_ttft() < c.fused_best_ttft(),
        "split {:.4}s !< best fused {:.4}s mean TTFT",
        c.split_static.mean_ttft(),
        c.fused_best_ttft()
    );
    // The autoscaled split keeps the JCT win inside the budget and
    // scales the prefill and decode pools independently: at least one
    // scale event recorded in EACH pool.
    assert!(c.split_auto.mean_jct() < c.fused_best_jct());
    assert!(c.split_auto.max_slots <= budget);
    assert!(
        c.split_auto.stage_scale_ups[0] >= 1 && c.split_auto.stage_scale_ups[1] >= 1,
        "pools did not scale independently: {:?}",
        c.split_auto.stage_scale_ups
    );
}

#[test]
fn disagg_comparison_is_deterministic() {
    let wl = datasets::prefill_heavy(3, 64, 56.0);
    let a = simulate_disagg(&wl, 4);
    let b = simulate_disagg(&wl, 4);
    assert_eq!(a.fused.jct.mean(), b.fused.jct.mean());
    assert_eq!(a.split_static.ttft.mean(), b.split_static.ttft.mean());
    assert_eq!(a.split_auto.scale_ups, b.split_auto.scale_ups);
}

// -------------------------------------------------------------------------
// Real-engine handoff tests (need compiled artifacts; skipped in CI
// containers without JAX).
// -------------------------------------------------------------------------

fn artifacts() -> Option<Artifacts> {
    let dir = Artifacts::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Artifacts::load(&dir).unwrap())
    } else {
        eprintln!("skipping: run `make artifacts`");
        None
    }
}

fn collect_tokens(items: &[StageItem], req: u64) -> Vec<i32> {
    let mut out = vec![];
    for it in items.iter().filter(|i| i.req_id == req) {
        if let Some(t) = it.tensor("tokens") {
            out.extend_from_slice(t.as_i32().unwrap());
        }
    }
    out
}

fn sampling(n: usize) -> SamplingParams {
    SamplingParams { max_new_tokens: n, temperature: 0.0, top_k: 0, ignore_eos: true, seed: 9 }
}

fn engine(art: &Artifacts, role: StageRole) -> ArEngine {
    ArEngine::new(
        art,
        "mimo",
        ArEngineOptions { max_batch: 2, stream_chunk: 0, role, ..Default::default() },
    )
    .unwrap()
}

/// Run a prompt through a prefill engine and return its handoff item.
fn prefill_handoff_with(
    art: &Artifacts,
    req: u64,
    prompt: &[u32],
    s: SamplingParams,
) -> StageItem {
    let mut pre = engine(art, StageRole::Prefill);
    pre.submit(token_job(req, prompt, s));
    let items = pre.run_to_completion().unwrap();
    assert_eq!(items.len(), 1, "prefill emits exactly one handoff item");
    let item = items.into_iter().next().unwrap();
    assert!(item.finished);
    assert_eq!(item.tensor("tokens").unwrap().len(), 1, "first token rides along");
    assert_eq!(pre.stats.kv_exports, 1);
    assert!(pre.stats.kv_export_bytes > 0);
    assert_eq!(pre.stats.decode_calls, 0, "prefill engines never decode");
    item
}

fn prefill_handoff(art: &Artifacts, req: u64, prompt: &[u32], max_new: usize) -> StageItem {
    prefill_handoff_with(art, req, prompt, sampling(max_new))
}

#[test]
fn prefill_then_decode_matches_the_fused_engine_exactly() {
    let Some(art) = artifacts() else { return };
    let prompt: Vec<u32> = std::iter::once(BOS_ID).chain((0..39).map(|i| 10 + i)).collect();

    let mut fused = engine(&art, StageRole::Fused);
    fused.submit(token_job(1, &prompt, sampling(12)));
    let fused_toks = collect_tokens(&fused.run_to_completion().unwrap(), 1);
    assert_eq!(fused_toks.len(), 12);

    let item = prefill_handoff(&art, 1, &prompt, 12);
    let h = KvHandoff::from_tensor(item.tensor(KV_TENSOR).unwrap()).unwrap();
    assert_eq!(h.len, prompt.len());
    assert_eq!(h.first_token as i32, fused_toks[0], "prefill samples the same first token");

    let mut dec = engine(&art, StageRole::Decode);
    dec.submit_handoff(h).unwrap();
    let dec_toks = collect_tokens(&dec.run_to_completion().unwrap(), 1);
    assert_eq!(dec_toks, fused_toks, "the split must reproduce fused greedy decode");
    assert_eq!(dec.stats.kv_imports, 1);
    assert_eq!(dec.stats.prefill_calls, 0, "decode engines never prefill");
}

#[test]
fn stochastic_continuation_matches_fused_sampling() {
    // The handoff carries the sampler PRNG state captured AFTER the
    // first sample, so temperature>0 decode must also reproduce the
    // fused stream bit-for-bit — the greedy tests alone would never
    // notice a broken state capture (greedy sampling skips the PRNG).
    let Some(art) = artifacts() else { return };
    let prompt: Vec<u32> = std::iter::once(BOS_ID).chain((0..19).map(|i| 60 + i)).collect();
    let s = SamplingParams {
        max_new_tokens: 16,
        temperature: 0.8,
        top_k: 8,
        ignore_eos: true,
        seed: 42,
    };

    let mut fused = engine(&art, StageRole::Fused);
    fused.submit(token_job(5, &prompt, s.clone()));
    let fused_toks = collect_tokens(&fused.run_to_completion().unwrap(), 5);
    assert_eq!(fused_toks.len(), 16);

    let item = prefill_handoff_with(&art, 5, &prompt, s);
    let h = KvHandoff::from_tensor(item.tensor(KV_TENSOR).unwrap()).unwrap();
    let mut dec = engine(&art, StageRole::Decode);
    dec.submit_handoff(h).unwrap();
    let dec_toks = collect_tokens(&dec.run_to_completion().unwrap(), 5);
    assert_eq!(dec_toks, fused_toks, "stochastic split decode must match fused sampling");
}

#[test]
fn decode_engine_dedups_shared_prefixes_across_handoffs() {
    let Some(art) = artifacts() else { return };
    // Two requests sharing a long prompt prefix: the second import must
    // reuse the first one's resident prefix blocks.
    let base: Vec<u32> = std::iter::once(BOS_ID).chain((0..32).map(|i| 40 + i)).collect();
    let mut p2 = base.clone();
    p2.push(999);

    let a = prefill_handoff(&art, 1, &base, 6);
    let b = prefill_handoff(&art, 2, &p2, 6);
    let mut dec = engine(&art, StageRole::Decode);
    dec.submit_handoff(KvHandoff::from_tensor(a.tensor(KV_TENSOR).unwrap()).unwrap()).unwrap();
    dec.submit_handoff(KvHandoff::from_tensor(b.tensor(KV_TENSOR).unwrap()).unwrap()).unwrap();
    let items = dec.run_to_completion().unwrap();
    assert_eq!(collect_tokens(&items, 1).len(), 6);
    assert_eq!(collect_tokens(&items, 2).len(), 6);
    assert_eq!(dec.stats.kv_imports, 2);
    assert!(
        dec.stats.kv_reused_blocks >= 1,
        "shared prefix blocks must dedup on import (got {})",
        dec.stats.kv_reused_blocks
    );
}

#[test]
fn handoff_geometry_mismatch_is_a_clean_error() {
    let Some(art) = artifacts() else { return };
    let item = prefill_handoff(&art, 1, &[BOS_ID, 5, 6, 7], 4);
    let good = KvHandoff::from_tensor(item.tensor(KV_TENSOR).unwrap()).unwrap();
    // A prefill-role engine has no decode executables; it must refuse
    // even a well-formed handoff.
    let mut pre = engine(&art, StageRole::Prefill);
    assert!(pre.submit_handoff(good.clone()).is_err());
    assert!(pre.idle());
    let mut h = good;
    h.n_heads += 1;
    // Geometry is re-checked structurally first (kv payload no longer
    // matches), so a doctored handoff errors instead of corrupting KV.
    let mut dec = engine(&art, StageRole::Decode);
    assert!(dec.submit_handoff(h).is_err());
    assert!(dec.idle(), "rejected handoffs must not enqueue");
}
