//! Runtime-layer integration: artifact loading, executable compilation,
//! numerics of the compiled entries against expected invariants, and the
//! diffusion/vocoder engines in isolation.

use omni_serve::engine::diffusion::{DiffusionEngine, DiffusionJob, DiffusionOptions};
use omni_serve::engine::vocoder::{VocoderEngine, VocoderJob, VocoderKind};
use omni_serve::runtime::{Artifacts, HostTensor, StageRuntime};

fn artifacts() -> Option<Artifacts> {
    let dir = Artifacts::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Artifacts::load(&dir).unwrap())
    } else {
        eprintln!("skipping: run `make artifacts`");
        None
    }
}

#[test]
fn decode_entry_runs_and_respects_shapes() {
    let Some(art) = artifacts() else { return };
    let mut rt = StageRuntime::new(&art, "mimo").unwrap();
    let m = rt.model().clone();
    let b = 1usize;
    let kv_shape: Vec<usize> = m.entry("decode.b1").unwrap().inputs[1].shape.clone();
    let kv = HostTensor::zeros_f32(kv_shape.clone());
    let outs = rt
        .run(
            "decode.b1",
            &[
                HostTensor::i32(vec![b], vec![1]),
                kv,
                HostTensor::i32(vec![b], vec![0]),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 3);
    assert_eq!(outs[0].shape, vec![1, 2048]); // logits [B, vocab]
    assert_eq!(outs[1].shape, vec![1, 256]); // hidden [B, d]
    assert_eq!(outs[2].shape, kv_shape);
    // Writing position 0 must leave rows >= 1 untouched (zeros).
    let kv_out = outs[2].as_f32().unwrap();
    let dh = 64;
    let s = 256;
    // layer 0, k, batch 0, head 0: row 0 written, row 1 zero.
    let row0 = &kv_out[0..dh];
    let row1 = &kv_out[dh..2 * dh];
    assert!(row0.iter().any(|&x| x != 0.0), "row 0 should be written");
    assert!(row1.iter().all(|&x| x == 0.0), "row 1 must stay zero");
    let _ = s;
}

#[test]
fn decode_is_deterministic_across_calls() {
    let Some(art) = artifacts() else { return };
    let mut rt = StageRuntime::new(&art, "talker25").unwrap();
    let m = rt.model().clone();
    let e = m.entry("decode.b2").unwrap();
    let kv = HostTensor::zeros_f32(e.inputs[2].shape.clone());
    let cond = HostTensor::f32(vec![2, 256], vec![0.25; 2 * 256]);
    let inputs = vec![
        HostTensor::i32(vec![2], vec![5, 9]),
        cond,
        kv,
        HostTensor::i32(vec![2], vec![0, 0]),
    ];
    let a = rt.run("decode.b2", &inputs).unwrap();
    let b = rt.run("decode.b2", &inputs).unwrap();
    assert_eq!(a[0], b[0]);
}

#[test]
fn bad_inputs_rejected_with_clear_errors() {
    let Some(art) = artifacts() else { return };
    let mut rt = StageRuntime::new(&art, "mimo").unwrap();
    // Wrong arity.
    let err = rt.run("decode.b1", &[HostTensor::i32(vec![1], vec![0])]).unwrap_err();
    assert!(format!("{err}").contains("inputs"), "{err}");
    // Wrong shape.
    let m = rt.model().clone();
    let kv = HostTensor::zeros_f32(m.entry("decode.b1").unwrap().inputs[1].shape.clone());
    let err = rt
        .run(
            "decode.b1",
            &[
                HostTensor::i32(vec![2], vec![0, 0]), // batch 2 into b1
                kv,
                HostTensor::i32(vec![1], vec![0]),
            ],
        )
        .unwrap_err();
    assert!(format!("{err}").contains("shape"), "{err}");
    // Unknown entry.
    assert!(rt.run("nope.b1", &[]).is_err());
}

#[test]
fn diffusion_engine_denoises_and_caches() {
    let Some(art) = artifacts() else { return };
    let mut eng = DiffusionEngine::new(
        &art,
        "voc_dit25",
        DiffusionOptions {
            max_batch: 2,
            steps: 8,
            cfg_scale: 1.0,
            stepcache_threshold: 0.30,
            lazy_compile: false,
        },
    )
    .unwrap();
    let n = eng.n_tokens();
    let ctd = eng.cond_tokens_dim();
    eng.submit_many((0..2).map(|i| DiffusionJob {
        req_id: i,
        chunk_idx: 0,
        cond: vec![],
        cond_tokens: vec![0.1; n * ctd],
        seed: i,
        steps: 0,
        final_chunk: true,
    }));
    let items = eng.run_to_completion().unwrap();
    assert_eq!(items.len(), 2);
    for it in &items {
        assert!(it.finished);
        let latent = it.tensor("latent").unwrap();
        assert_eq!(latent.shape, vec![n, 32]);
        assert!(latent.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }
    assert!(eng.stats.steps_run > 0);
    assert!(
        eng.stats.steps_skipped > 0,
        "threshold 0.30 should skip some steps (ran {}, skipped {})",
        eng.stats.steps_run,
        eng.stats.steps_skipped
    );
    // Skipping never exceeds total work.
    assert_eq!((eng.stats.steps_run + eng.stats.steps_skipped) as usize, 2 * 8);
}

#[test]
fn stepcache_disabled_runs_every_step() {
    let Some(art) = artifacts() else { return };
    let mut eng = DiffusionEngine::new(
        &art,
        "voc_dit25",
        DiffusionOptions { max_batch: 1, steps: 6, cfg_scale: 1.0, stepcache_threshold: 0.0, lazy_compile: false },
    )
    .unwrap();
    let n = eng.n_tokens();
    let ctd = eng.cond_tokens_dim();
    eng.submit(DiffusionJob {
        req_id: 1,
        chunk_idx: 0,
        cond: vec![],
        cond_tokens: vec![0.0; n * ctd],
        seed: 3,
        steps: 0,
        final_chunk: true,
    });
    eng.run_to_completion().unwrap();
    assert_eq!(eng.stats.steps_run, 6);
    assert_eq!(eng.stats.steps_skipped, 0);
}

#[test]
fn cnn_vocoder_produces_trimmed_waveform() {
    let Some(art) = artifacts() else { return };
    let mut eng = VocoderEngine::new(&art, "voc_cnn3", VocoderKind::Cnn, 2, false).unwrap();
    let up = eng.samples_per_frame();
    eng.submit(VocoderJob { req_id: 1, chunk_idx: 0, tokens: vec![5; 10], final_chunk: true });
    eng.submit(VocoderJob { req_id: 2, chunk_idx: 0, tokens: vec![9; 64], final_chunk: true });
    let items = eng.run_to_completion().unwrap();
    assert_eq!(items.len(), 2);
    let w1 = items.iter().find(|i| i.req_id == 1).unwrap().tensor("wave").unwrap();
    assert_eq!(w1.shape, vec![10 * up]); // trimmed to real frames
    let w2 = items.iter().find(|i| i.req_id == 2).unwrap().tensor("wave").unwrap();
    assert_eq!(w2.shape, vec![64 * up]);
    // tanh output range
    assert!(w2.as_f32().unwrap().iter().all(|x| x.abs() <= 1.0));
}

#[test]
fn patch_decoder_output_shape() {
    let Some(art) = artifacts() else { return };
    let mut eng =
        VocoderEngine::new(&art, "mimo_codec", VocoderKind::PatchDecoder, 4, false).unwrap();
    eng.submit(VocoderJob { req_id: 7, chunk_idx: 0, tokens: vec![3; 20], final_chunk: true });
    let items = eng.run_to_completion().unwrap();
    let w = items[0].tensor("wave").unwrap();
    assert_eq!(w.shape, vec![20 * eng.samples_per_frame()]);
}

#[test]
fn mm_encoder_masks_padding() {
    let Some(art) = artifacts() else { return };
    let mut rt = StageRuntime::new(&art, "enc25").unwrap();
    let m = rt.model().clone();
    let t_max = m.cfg_usize("t_max").unwrap();
    let fd = m.cfg_usize("feat_dim").unwrap();
    let d = m.cfg_usize("d_out").unwrap();
    let mut feats = vec![0f32; t_max * fd];
    for x in feats.iter_mut().take(10 * fd) {
        *x = 0.3;
    }
    let mut mask = vec![0f32; t_max];
    for x in mask.iter_mut().take(10) {
        *x = 1.0;
    }
    let outs = rt
        .run(
            "encode.b1",
            &[
                HostTensor::f32(vec![1, t_max, fd], feats),
                HostTensor::f32(vec![1, t_max], mask),
            ],
        )
        .unwrap();
    let e = outs[0].as_f32().unwrap();
    assert!(e[..10 * d].iter().any(|&x| x != 0.0));
    assert!(e[10 * d..].iter().all(|&x| x == 0.0), "masked rows must be zero");
}
