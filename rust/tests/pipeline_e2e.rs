//! Whole-pipeline integration tests: every preset serves a small
//! workload to completion through the disaggregated orchestrator, with
//! sane metrics; connector transports and streaming behave as specified.

use std::sync::Arc;

use omni_serve::baseline::{run_monolithic, BaselineOptions};
use omni_serve::config::{presets, ConnectorKind};
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::runtime::Artifacts;
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::trace::datasets;

fn artifacts() -> Option<Arc<Artifacts>> {
    let dir = Artifacts::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Arc::new(Artifacts::load(&dir).unwrap()))
    } else {
        eprintln!("skipping: run `make artifacts`");
        None
    }
}

#[test]
fn qwen25_omni_pipeline_completes() {
    let Some(art) = artifacts() else { return };
    let wl = datasets::librispeech(1, 3, 0.0);
    let orch = Orchestrator::new(
        presets::qwen25_omni(),
        art,
        Registry::builtin(),
        RunOptions::default(),
    )
    .unwrap();
    let s = orch.run_workload(&wl, Some("talker")).unwrap();
    assert_eq!(s.report.completed, 3);
    assert!(s.report.mean_jct() > 0.0);
    assert!(s.report.mean_rtf().is_finite());
    // All three stages saw all requests.
    for stage in ["thinker", "talker", "vocoder"] {
        assert!(s.report.stage_tokens(stage) > 0, "stage {stage} produced nothing");
    }
    // Audio volume ~ matches requested caps.
    let want: usize = wl.requests.iter().map(|r| r.max_audio_tokens).sum();
    assert_eq!(s.report.stage_tokens("talker"), want);
}

#[test]
fn qwen3_omni_streaming_beats_barriers_on_ttft() {
    let Some(art) = artifacts() else { return };
    let wl = datasets::food101(2, 3, 0.0);
    let run = |streaming: bool| {
        let orch = Orchestrator::new(
            presets::qwen3_omni(),
            Arc::clone(&art),
            Registry::builtin(),
            RunOptions { streaming, ..Default::default() },
        )
        .unwrap();
        orch.run_workload(&wl, Some("talker")).unwrap().report
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(on.completed, 3);
    assert_eq!(off.completed, 3);
    assert!(
        on.mean_ttft() < off.mean_ttft(),
        "streaming TTFT {:.3} should beat barrier TTFT {:.3}",
        on.mean_ttft(),
        off.mean_ttft()
    );
}

#[test]
fn mimo_pipeline_all_connector_kinds() {
    let Some(art) = artifacts() else { return };
    let wl = datasets::seedtts(3, 2, 0.0);
    let mut tokens_per_kind = vec![];
    for kind in [ConnectorKind::Inline, ConnectorKind::Shm, ConnectorKind::Tcp] {
        let mut cfg = presets::mimo_audio(1);
        for e in &mut cfg.edges {
            e.connector = kind;
        }
        let orch = Orchestrator::new(
            cfg,
            Arc::clone(&art),
            Registry::builtin(),
            RunOptions::default(),
        )
        .unwrap();
        let s = orch.run_workload(&wl, Some("backbone")).unwrap();
        assert_eq!(s.report.completed, 2, "connector {kind:?}");
        tokens_per_kind.push(s.report.stage_tokens("backbone"));
    }
    // Transport must not change WHAT is produced.
    assert_eq!(tokens_per_kind[0], tokens_per_kind[1]);
    assert_eq!(tokens_per_kind[1], tokens_per_kind[2]);
}

#[test]
fn replicated_talker_pipeline_matches_single_replica_output() {
    // qwen3-omni-rep2 runs the Talker as TWO engine replicas behind the
    // routed connector layer (affinity on the thinker→talker edge, fan-in
    // on talker→vocoder).  Replication must change WHEN work runs, never
    // WHAT is produced: token volumes match the single-replica pipeline.
    let Some(art) = artifacts() else { return };
    let wl = datasets::librispeech(9, 4, 0.0);
    let run = |cfg: omni_serve::config::PipelineConfig| {
        let orch = Orchestrator::new(
            cfg,
            Arc::clone(&art),
            Registry::builtin(),
            RunOptions::default(),
        )
        .unwrap();
        orch.run_workload(&wl, Some("talker")).unwrap()
    };
    let base = run(presets::qwen3_omni());
    let rep = run(presets::qwen3_omni_replicated());
    assert_eq!(rep.report.completed, 4);
    assert_eq!(
        base.report.stage_tokens("thinker"),
        rep.report.stage_tokens("thinker")
    );
    assert_eq!(
        base.report.stage_tokens("talker"),
        rep.report.stage_tokens("talker")
    );
    // Both talker replicas produced a summary; the rollup covers the
    // whole stage's admissions.
    assert_eq!(rep.stage_replicas("talker").len(), 2);
    let rollup = rep.stage_rollup("talker").unwrap();
    let per_replica: u64 = rep
        .stage_replicas("talker")
        .iter()
        .map(|s| s.sched.as_ref().map(|sc| sc.admitted).unwrap_or(0))
        .sum();
    assert_eq!(rollup.sched.unwrap().admitted, per_replica);
}

#[test]
fn bagel_pipeline_generates_images() {
    let Some(art) = artifacts() else { return };
    let wl = datasets::vbench(4, 2, 0.0, 8, false);
    let orch = Orchestrator::new(
        presets::bagel(false),
        art,
        Registry::builtin(),
        RunOptions::default(),
    )
    .unwrap();
    let s = orch.run_workload(&wl, None).unwrap();
    assert_eq!(s.report.completed, 2);
    let d = s.stages.iter().find_map(|st| st.diffusion.clone()).unwrap();
    assert!(d.jobs_done == 2);
    assert!(d.steps_run > 0);
}

#[test]
fn baseline_and_disaggregated_agree_on_workload_content() {
    let Some(art) = artifacts() else { return };
    // Same workload, same artifacts: thinker must emit the same NUMBER of
    // tokens (greedy caps), and the talker volume must match exactly.
    let wl = datasets::librispeech(5, 2, 0.0);
    let orch = Orchestrator::new(
        presets::qwen25_omni(),
        Arc::clone(&art),
        Registry::builtin(),
        RunOptions::default(),
    )
    .unwrap();
    let ours = orch.run_workload(&wl, Some("talker")).unwrap().report;
    let base = run_monolithic(
        &art,
        &presets::qwen25_omni(),
        &wl,
        &BaselineOptions::default(),
        Some("talker"),
    )
    .unwrap();
    assert_eq!(ours.stage_tokens("thinker"), base.stage_tokens("thinker"));
    assert_eq!(ours.stage_tokens("talker"), base.stage_tokens("talker"));
}

#[test]
fn online_arrivals_respected() {
    let Some(art) = artifacts() else { return };
    let wl = datasets::seedtts(8, 3, 4.0); // ~4 req/s Poisson
    let orch = Orchestrator::new(
        presets::mimo_audio(1),
        art,
        Registry::builtin(),
        RunOptions { realtime_arrivals: true, ..Default::default() },
    )
    .unwrap();
    let s = orch.run_workload(&wl, Some("backbone")).unwrap();
    assert_eq!(s.report.completed, 3);
    // Wall clock must cover the last arrival.
    let last = wl.requests.iter().map(|r| r.arrival_s).fold(0.0, f64::max);
    assert!(s.wall_s >= last, "wall {:.3} < last arrival {last:.3}", s.wall_s);
}

#[test]
fn custom_registry_transfer_is_used() {
    let Some(art) = artifacts() else { return };
    use omni_serve::stage_graph::transfers::{EngineCmd, TransferCtx};
    let mut reg = Registry::builtin();
    // A transfer that drops everything: downstream never gets jobs, so the
    // pipeline cannot complete -> proves the custom transfer is in effect.
    // We instead *count* invocations through a channel and forward normally.
    let (tx, rx) = std::sync::mpsc::channel::<u64>();
    let tx = std::sync::Mutex::new(tx);
    reg.register(
        "counting_t2v",
        Arc::new(move |ctx: TransferCtx| {
            let tx = tx.lock().unwrap().clone();
            let mut inner = Registry::builtin().instantiate("tokens2patches", ctx).unwrap();
            Box::new(move |item| {
                tx.send(item.req_id).ok();
                let cmds: Vec<EngineCmd> = inner(item)?;
                Ok(cmds)
            })
        }),
    );
    let mut cfg = presets::mimo_audio(1);
    cfg.edges[0].transfer = "counting_t2v".into();
    let wl = datasets::seedtts(2, 2, 0.0);
    let orch = Orchestrator::new(cfg, art, reg, RunOptions::default()).unwrap();
    let s = orch.run_workload(&wl, Some("backbone")).unwrap();
    assert_eq!(s.report.completed, 2);
    assert!(rx.try_iter().count() > 0, "custom transfer never invoked");
}

#[test]
fn epd_disaggregated_pipeline_matches_fused() {
    // Full E/P/D mode (standalone encoder + prefill/decode split, paper
    // §3.4) must produce the same token volumes as the fused pipeline:
    // the decode stage re-emits every thinker token (the first one comes
    // through the KV handoff), and the talker stream is untouched.
    let Some(art) = artifacts() else { return };
    let wl = datasets::ucf101(6, 2, 0.0);
    let run = |cfg: omni_serve::config::PipelineConfig| {
        let orch = Orchestrator::new(
            cfg,
            Arc::clone(&art),
            Registry::builtin(),
            RunOptions::default(),
        )
        .unwrap();
        orch.run_workload(&wl, Some("talker")).unwrap()
    };
    let fused = run(presets::qwen3_omni()).report;
    let epd_summary = run(presets::qwen3_omni_epd());
    let epd = &epd_summary.report;
    assert_eq!(epd.completed, 2);
    assert_eq!(fused.stage_tokens("thinker"), epd.stage_tokens("decode"));
    assert_eq!(fused.stage_tokens("talker"), epd.stage_tokens("talker"));
    // The prefill stage emitted exactly one (first) token per request,
    // and the KV-transfer counters saw one handoff per request.
    assert_eq!(epd.stage_tokens("prefill"), 2);
    let prefill = epd_summary.stage_rollup("prefill").unwrap().ar.unwrap();
    let decode = epd_summary.stage_rollup("decode").unwrap().ar.unwrap();
    assert_eq!(prefill.kv_exports, 2);
    assert_eq!(decode.kv_imports, 2);
    assert!(prefill.kv_export_bytes > 0);
    assert_eq!(decode.prefill_calls, 0, "the decode pool never prefills");
    assert_eq!(prefill.decode_calls, 0, "the prefill pool never decodes");
}
