//! CI loopback smoke test: start `omni-serve`'s TCP frontend, drive one
//! connection through `ping` + `generate` + `stats` + `shutdown`, and
//! assert a clean teardown.
//!
//! Runs WITHOUT compiled artifacts (the CI containers have no JAX): the
//! server binds and answers `ping`/`stats`/`config` from the static
//! plan, and `generate` returns a structured `error` object instead of
//! killing the connection.  When artifacts exist the same script also
//! asserts the full `generate` → completion path through the shared
//! ServingSession.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use omni_serve::config::{presets, AdmissionConfig};
use omni_serve::json;
use omni_serve::runtime::Artifacts;
use omni_serve::server::{ServeOptions, Server};

fn send(c: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> json::Value {
    c.write_all(req.as_bytes()).unwrap();
    c.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    json::parse(&line).unwrap_or_else(|e| panic!("bad response `{line}`: {e}"))
}

#[test]
fn loopback_ping_generate_stats_shutdown() {
    let dir = Artifacts::default_dir();
    let have_artifacts = dir.join("manifest.json").exists();
    let artifacts = if have_artifacts {
        Arc::new(Artifacts::load(&dir).unwrap())
    } else {
        Arc::new(Artifacts::empty())
    };
    let server = Server::bind(
        "127.0.0.1:0",
        presets::mimo_audio(1),
        artifacts,
        ServeOptions::default(),
    )
    .unwrap();
    let addr = server.addr();
    let h = std::thread::spawn(move || server.serve_n(1));

    let mut c = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());

    // 1. ping
    let v = send(&mut c, &mut reader, r#"{"op": "ping"}"#);
    assert_eq!(v.get("ok").as_bool(), Some(true));

    // 1b. malformed / unknown-op lines get a structured error frame on
    // the SAME still-alive connection — never a silent drop or a kill.
    let v = send(&mut c, &mut reader, r#"{"op": "generate", BROKEN"#);
    assert!(
        v.get("error").as_str().unwrap_or_default().contains("bad request JSON"),
        "{v:?}"
    );
    let v = send(&mut c, &mut reader, r#"{"op": "transmogrify"}"#);
    assert!(
        v.get("error").as_str().unwrap_or_default().contains("unknown op"),
        "{v:?}"
    );
    let v = send(&mut c, &mut reader, r#"{"op": "cancel"}"#);
    assert!(!v.get("error").is_null(), "cancel without req_id errors: {v:?}");
    // Cancel before any session exists: structured no-op, not an error.
    let v = send(&mut c, &mut reader, r#"{"op": "cancel", "req_id": 999}"#);
    assert_eq!(v.get("ok").as_bool(), Some(true));
    assert_eq!(v.get("cancelled").as_bool(), Some(false));

    // 2. stats before any generate: static plan, not live; the goodput
    // accounting keys are present (zeroed) even without a session.
    let v = send(&mut c, &mut reader, r#"{"op": "stats"}"#);
    assert_eq!(v.get("live").as_bool(), Some(false));
    let stages = v.get("stages").as_arr().unwrap();
    assert_eq!(stages.len(), 2, "mimo pipeline has backbone + patch_dec");
    assert_eq!(stages[0].get("replicas").as_usize(), Some(1));
    assert_eq!(v.get("offered").as_usize(), Some(0));
    assert_eq!(v.get("rejected").as_usize(), Some(0));
    assert_eq!(v.get("goodput").as_f64(), Some(0.0));
    assert_eq!(v.get("edges").as_arr().unwrap().len(), 0, "no session, no edge counters");

    // 3. generate
    let v = send(
        &mut c,
        &mut reader,
        r#"{"op": "generate", "prompt": "hi", "max_text_tokens": 4, "max_audio_tokens": 8}"#,
    );
    if have_artifacts {
        assert_eq!(v.get("completed").as_bool(), Some(true), "{v:?}");
        assert!(v.get("jct_s").as_f64().unwrap() >= 0.0);
        // 3b. stats now reports the LIVE session, including per-edge
        // transfer counters for the backbone→patch_dec hop.
        let v = send(&mut c, &mut reader, r#"{"op": "stats"}"#);
        assert_eq!(v.get("live").as_bool(), Some(true));
        let stages = v.get("stages").as_arr().unwrap();
        assert!(stages.iter().all(|s| s.get("replicas").as_usize() == Some(1)));
        assert_eq!(v.get("inflight").as_usize(), Some(0));
        let edges = v.get("edges").as_arr().unwrap();
        assert_eq!(edges.len(), 1, "mimo pipeline has one edge: {v:?}");
        assert!(edges[0].get("frames").as_usize().unwrap() > 0, "{v:?}");
        assert!(edges[0].get("bytes").as_usize().unwrap() > 0, "{v:?}");
    } else {
        // No compiled models: a structured error, not a dropped line.
        let err = v.get("error").as_str().unwrap_or_default().to_string();
        assert!(!err.is_empty(), "expected structured error, got {v:?}");
    }

    // 4. clean shutdown of the shared session (no-op without one).
    let v = send(&mut c, &mut reader, r#"{"op": "shutdown"}"#);
    assert_eq!(v.get("ok").as_bool(), Some(true));
    if have_artifacts {
        assert_eq!(v.get("completed").as_usize(), Some(1));
    }

    drop(c);
    drop(reader);
    h.join().unwrap().unwrap();
}

/// Protocol v2 over real TCP: a streaming `generate` on one connection
/// (accepted header + delta frames), cancelled from a SECOND connection,
/// resolves with `{"event": "done", "cancelled": true}`.  Needs
/// artifacts (skipped otherwise, like the session tests).
#[test]
fn streaming_generate_with_cross_connection_cancel() {
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let artifacts = Arc::new(Artifacts::load(&dir).unwrap());
    let server = Server::bind(
        "127.0.0.1:0",
        presets::mimo_audio(1),
        artifacts,
        ServeOptions::default(),
    )
    .unwrap();
    let addr = server.addr();
    let h = std::thread::spawn(move || server.serve_concurrent(2));

    // Connection A: long streaming request (MiMo's generation budget is
    // max_text_tokens — 512 keeps it running while we cancel).
    let mut a = TcpStream::connect(&addr).unwrap();
    let mut ra = BufReader::new(a.try_clone().unwrap());
    let accepted = send(
        &mut a,
        &mut ra,
        r#"{"op": "generate", "stream": true, "prompt": "say something long",
            "max_text_tokens": 512, "max_audio_tokens": 512}"#
            .replace('\n', " ")
            .as_str(),
    );
    assert_eq!(accepted.get("event").as_str(), Some("accepted"), "{accepted:?}");
    let req_id = accepted.get("req_id").as_usize().unwrap();

    // First delta frame proves mid-flight streaming (audio chunks from
    // the patch decoder arrive before the request is anywhere near done).
    let mut line = String::new();
    ra.read_line(&mut line).unwrap();
    let first = json::parse(&line).unwrap();
    assert_eq!(first.get("event").as_str(), Some("delta"), "{first:?}");

    // Connection B: cancel A's request.
    let mut b = TcpStream::connect(&addr).unwrap();
    let mut rb = BufReader::new(b.try_clone().unwrap());
    let v = send(&mut b, &mut rb, &format!(r#"{{"op": "cancel", "req_id": {req_id}}}"#));
    assert_eq!(v.get("ok").as_bool(), Some(true));
    assert_eq!(v.get("cancelled").as_bool(), Some(true), "{v:?}");

    // A's stream terminates with done{cancelled: true}.
    loop {
        let mut line = String::new();
        ra.read_line(&mut line).unwrap();
        let v = json::parse(&line).unwrap_or_else(|e| panic!("bad frame `{line}`: {e}"));
        match v.get("event").as_str() {
            Some("delta") => continue,
            Some("done") => {
                assert_eq!(v.get("req_id").as_usize(), Some(req_id));
                assert_eq!(v.get("cancelled").as_bool(), Some(true), "{v:?}");
                break;
            }
            other => panic!("unexpected frame {other:?}: {v:?}"),
        }
    }

    // Clean teardown through B, then close both connections.
    let v = send(&mut b, &mut rb, r#"{"op": "shutdown"}"#);
    assert_eq!(v.get("ok").as_bool(), Some(true));
    drop((a, ra, b, rb));
    h.join().unwrap().unwrap();
}

/// Two-process multi-node smoke (ISSUE 8): spawn a REAL `omni-serve
/// agent` child process on 127.0.0.1, drive a two-stage trace across
/// the process boundary with the in-process controller, and assert
/// clean registration, end-to-end frame delivery, per-edge transfer
/// stats harvested over the control plane, and a clean drain (the
/// child exits 0).  Artifact-free, like the loopback smoke above.
#[test]
fn two_process_agent_runs_a_cluster_trace_end_to_end() {
    use omni_serve::cluster::{run_cluster_trace, ControllerOptions};
    use omni_serve::config::TransportConfig;
    use std::io::Read;
    use std::process::{Command, Stdio};

    let mut child = Command::new(env!("CARGO_BIN_EXE_omni-serve"))
        .args([
            "agent",
            "--node-id",
            "smoke0",
            "--listen",
            "127.0.0.1:0",
            "--heartbeat",
            "0.005",
        ])
        .stdout(Stdio::piped())
        .spawn()
        .unwrap();
    // The agent announces its bound address on stdout before accepting.
    let mut out = BufReader::new(child.stdout.take().unwrap());
    let mut line = String::new();
    out.read_line(&mut line).unwrap();
    assert!(line.starts_with("agent smoke0 listening on "), "unexpected banner: {line:?}");
    let addr = line.trim().rsplit(' ').next().unwrap().to_string();

    let payloads: Vec<Vec<u8>> = (0..16u8).map(|i| vec![i; 64 + i as usize]).collect();
    let opts = ControllerOptions {
        transport: TransportConfig { heartbeat_s: 0.005, read_timeout_s: 5.0 },
        ..Default::default()
    };
    let report =
        run_cluster_trace(&[addr], &["prefill", "decode"], &payloads, &opts).unwrap();

    assert_eq!(report.nodes, vec!["smoke0".to_string()]);
    assert_eq!(report.completed, 16, "every frame must cross the process boundary intact");
    assert_eq!(report.plan.placements.len(), 2, "both stages homed on the one node");
    // Per-hop transfer counters crossed the control plane in `Stats`.
    assert_eq!(report.edges.len(), 2);
    let total_bytes: usize = payloads.iter().map(|p| p.len()).sum();
    for e in &report.edges {
        assert!(e.label.starts_with("smoke0/"), "{e:?}");
        assert_eq!(e.frames, 17, "16 payloads + the end-of-stream sentinel: {e:?}");
        assert_eq!(e.bytes as usize, total_bytes, "{e:?}");
    }
    assert!(report.heartbeats > 0, "the agent must have heartbeated during the run");

    // The child drains cleanly: prints its hop summary and exits 0.
    let status = child.wait().unwrap();
    assert!(status.success(), "agent exited {status:?}");
    let mut rest = String::new();
    out.read_to_string(&mut rest).unwrap();
    assert!(
        rest.contains("agent smoke0 drained: 2 replicas hosted"),
        "missing drain summary: {rest:?}"
    );
}

/// Prefix-cache smoke over real TCP (ISSUE 7): two IDENTICAL streaming
/// requests back to back on one connection.  The second replays the
/// first's prompt AND seed, so its prefill attaches the KV blocks the
/// first request released into the global prefix cache: its TTFT
/// (submit to first delta frame, wall clock) must be strictly lower,
/// and the `stats` op must report a nonzero prefix-cache hit count.
/// Needs artifacts (skipped otherwise, like the other live suites).
#[test]
fn identical_repeat_request_hits_the_prefix_cache_and_cuts_ttft() {
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let artifacts = Arc::new(Artifacts::load(&dir).unwrap());
    let server = Server::bind(
        "127.0.0.1:0",
        presets::mimo_audio(1),
        artifacts,
        ServeOptions::default(),
    )
    .unwrap();
    let addr = server.addr();
    let h = std::thread::spawn(move || server.serve_n(1));

    let mut c = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());

    // 33 words -> 34 prompt tokens -> two full 16-token KV blocks for
    // the repeat to attach (the tokenizer is one id per word plus BOS).
    let prompt = (0..33).map(|i| format!("w{i}")).collect::<Vec<_>>().join(" ");
    let req = format!(
        r#"{{"op": "generate", "stream": true, "prompt": "{prompt}", "seed": 7, "max_text_tokens": 8, "max_audio_tokens": 8}}"#
    );

    // Submit → first delta, wall clock; then drain to the `done` frame.
    let run = |c: &mut TcpStream, reader: &mut BufReader<TcpStream>| -> f64 {
        let start = std::time::Instant::now();
        let accepted = send(c, reader, &req);
        assert_eq!(accepted.get("event").as_str(), Some("accepted"), "{accepted:?}");
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let first = json::parse(&line).unwrap_or_else(|e| panic!("bad frame `{line}`: {e}"));
        assert_eq!(first.get("event").as_str(), Some("delta"), "{first:?}");
        let ttft = start.elapsed().as_secs_f64();
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let v = json::parse(&line).unwrap_or_else(|e| panic!("bad frame `{line}`: {e}"));
            match v.get("event").as_str() {
                Some("delta") => continue,
                Some("done") => {
                    assert_eq!(v.get("cancelled").as_bool(), Some(false), "{v:?}");
                    break;
                }
                other => panic!("unexpected frame {other:?}: {v:?}"),
            }
        }
        ttft
    };

    let cold = run(&mut c, &mut reader);
    let warm = run(&mut c, &mut reader);
    assert!(
        warm < cold,
        "repeat TTFT {warm:.4}s !< cold TTFT {cold:.4}s — the prefix attach bought nothing"
    );

    // The stats op surfaces the attach live.
    let v = send(&mut c, &mut reader, r#"{"op": "stats"}"#);
    assert_eq!(v.get("live").as_bool(), Some(true));
    assert!(v.get("prefix_hits").as_usize().unwrap() >= 1, "{v:?}");
    assert!(v.get("prefix_hit_rate").as_f64().unwrap() > 0.0, "{v:?}");

    let v = send(&mut c, &mut reader, r#"{"op": "shutdown"}"#);
    assert_eq!(v.get("ok").as_bool(), Some(true));
    drop((c, reader));
    h.join().unwrap().unwrap();
}

/// Overload over real TCP (ISSUE 6): an admission-enabled server answers
/// a flood of unmeetable-deadline `generate`s with structured
/// `{"error": "rejected"}` frames on the still-alive connection — one-shot
/// AND streaming — then serves an admitted request to a clean `done`, and
/// `stats`/`shutdown` report the goodput accounting.  Needs artifacts
/// (skipped otherwise, like the other live-session suites).
#[test]
fn overload_rejections_are_structured_frames_and_stats_report_goodput() {
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let artifacts = Arc::new(Artifacts::load(&dir).unwrap());
    let server = Server::bind(
        "127.0.0.1:0",
        presets::mimo_audio(1),
        artifacts,
        ServeOptions { admission: Some(AdmissionConfig::default()), ..Default::default() },
    )
    .unwrap();
    let addr = server.addr();
    let h = std::thread::spawn(move || server.serve_n(1));

    let mut c = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());

    // 1. Flood: four one-shot requests whose 50 ms deadline can never
    // cover their own multi-second estimated cost.  Each gets an
    // immediate structured rejection and the connection stays usable.
    for _ in 0..4 {
        let v = send(
            &mut c,
            &mut reader,
            r#"{"op": "generate", "prompt": "storm", "deadline_s": 0.05,
                "max_text_tokens": 512, "max_audio_tokens": 512}"#
                .replace('\n', " ")
                .as_str(),
        );
        assert_eq!(v.get("error").as_str(), Some("rejected"), "{v:?}");
        assert!(v.get("req_id").as_usize().is_some());
        let reason = v.get("reason").as_str().unwrap_or_default();
        assert!(reason.contains("deadline"), "reason should name the deadline: {v:?}");
        assert!(v.get("retry_after_s").as_f64().unwrap() > 0.0);
    }

    // 2. A streaming flood victim: the accepted header goes out first,
    // then the stream terminates with the structured rejected frame —
    // never a bare connection drop.
    let v = send(
        &mut c,
        &mut reader,
        r#"{"op": "generate", "stream": true, "prompt": "storm", "deadline_s": 0.05,
            "max_text_tokens": 512, "max_audio_tokens": 512}"#
            .replace('\n', " ")
            .as_str(),
    );
    assert_eq!(v.get("event").as_str(), Some("accepted"), "{v:?}");
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap_or_else(|e| panic!("bad frame `{line}`: {e}"));
    assert_eq!(v.get("error").as_str(), Some("rejected"), "{v:?}");
    assert_eq!(v.get("event").as_str(), Some("rejected"), "{v:?}");
    assert!(!v.get("reason").is_null());

    // 3. An admitted request (no deadline: nothing to miss) still runs
    // to a clean completion on the same connection.
    let v = send(
        &mut c,
        &mut reader,
        r#"{"op": "generate", "prompt": "hi", "max_text_tokens": 4, "max_audio_tokens": 8}"#,
    );
    assert_eq!(v.get("completed").as_bool(), Some(true), "{v:?}");

    // 4. stats: the live session's goodput accounting — 6 offered, 5
    // rejected, the deadline-less completion in-SLO.
    let v = send(&mut c, &mut reader, r#"{"op": "stats"}"#);
    assert_eq!(v.get("live").as_bool(), Some(true));
    assert_eq!(v.get("offered").as_usize(), Some(6));
    assert_eq!(v.get("rejected").as_usize(), Some(5));
    assert_eq!(v.get("in_slo").as_usize(), Some(1));
    assert_eq!(v.get("shed").as_usize(), Some(0), "nothing queued long enough to shed");
    let goodput = v.get("goodput").as_f64().unwrap();
    assert!((goodput - 1.0 / 6.0).abs() < 1e-9, "goodput 1 in-SLO / 6 offered, got {goodput}");

    // 5. shutdown reports the same accounting.
    let v = send(&mut c, &mut reader, r#"{"op": "shutdown"}"#);
    assert_eq!(v.get("ok").as_bool(), Some(true));
    assert_eq!(v.get("completed").as_usize(), Some(1));
    assert_eq!(v.get("rejected").as_usize(), Some(5));
    assert!(v.get("goodput").as_f64().unwrap() > 0.0);

    drop((c, reader));
    h.join().unwrap().unwrap();
}
