//! Scheduler-subsystem integration tests: batching policies at token
//! boundaries, the per-stage admission queue, the stage allocator, stage
//! graph validation, and the policy-level JCT claim behind
//! `benches/sched_batching.rs`.  None of these need compiled artifacts.

use omni_serve::config::{presets, EdgeConfig, PipelineConfig, SchedPolicyKind, StageKind};
use omni_serve::scheduler::policy::{
    BatchPolicy, ContinuousBatchingPolicy, EngineView, FifoPolicy, PendingJob, StepBatchingPolicy,
};
use omni_serve::scheduler::sim::{from_workload, simulate, SimCost};
use omni_serve::scheduler::StageAllocator;
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::stage_graph::StageGraph;
use omni_serve::trace::datasets;

fn jobs(costs: &[usize]) -> Vec<PendingJob> {
    costs
        .iter()
        .enumerate()
        .map(|(i, &c)| PendingJob { req_id: i as u64, cost_tokens: c })
        .collect()
}

// ---------------------------------------------------------------------------
// Continuous batching: join/evict at token boundaries, token budget.
// ---------------------------------------------------------------------------

#[test]
fn continuous_batching_joins_and_evicts_at_token_boundaries() {
    // Walk the policy through an engine's life: each `admit` call happens
    // at a token boundary; the view reflects the evictions of the
    // previous iteration.
    let mut p = ContinuousBatchingPolicy { max_batch_tokens: 0 };

    // Boundary 0: empty engine, 3 pending, batch of 4 -> all join.
    let v0 = EngineView { running: 0, max_batch: 4, ..Default::default() };
    assert_eq!(p.admit(&jobs(&[20, 20, 20]), &v0), 3);

    // Boundary 1: 3 running, one slot free -> a late arrival joins the
    // running batch immediately (no drain barrier).
    let v1 = EngineView { running: 3, max_batch: 4, committed_tokens: 60, ..Default::default() };
    assert_eq!(p.admit(&jobs(&[20]), &v1), 1);

    // Boundary 2: batch full -> nothing joins.
    let v2 = EngineView { running: 4, max_batch: 4, committed_tokens: 80, ..Default::default() };
    assert_eq!(p.admit(&jobs(&[20]), &v2), 0);

    // Boundary 3: one sequence finished (evicted at the boundary) -> its
    // slot refills at once.
    let v3 = EngineView { running: 3, max_batch: 4, committed_tokens: 60, ..Default::default() };
    assert_eq!(p.admit(&jobs(&[20]), &v3), 1);
}

#[test]
fn continuous_batching_enforces_max_batch_tokens() {
    let mut p = ContinuousBatchingPolicy { max_batch_tokens: 128 };
    let view = EngineView { running: 2, max_batch: 8, committed_tokens: 100, ..Default::default() };
    // 100 committed of 128: a 20-token job fits, a second does not.
    assert_eq!(p.admit(&jobs(&[20, 20]), &view), 1);
    // Budget pressure never deadlocks an empty engine.
    let empty = EngineView { running: 0, max_batch: 8, ..Default::default() };
    assert_eq!(p.admit(&jobs(&[4096]), &empty), 1);
}

// ---------------------------------------------------------------------------
// Step-level batching: denoise-step cohort grouping.
// ---------------------------------------------------------------------------

#[test]
fn step_batching_groups_matching_denoise_steps() {
    let mut p = StepBatchingPolicy { step_window: 2 };
    // Empty engine: a fresh cohort starts.
    let empty = EngineView { running: 0, max_batch: 4, ..Default::default() };
    assert_eq!(p.admit(&jobs(&[10, 10]), &empty), 2);
    // Lanes at steps {0, 1}: still within the window -> new jobs join the
    // cohort (their step-0 trunks batch with the young lanes).
    let young = EngineView {
        running: 2,
        max_batch: 4,
        lane_steps: vec![0, 1],
        ..Default::default()
    };
    assert_eq!(p.admit(&jobs(&[10]), &young), 1);
    // Lanes deep into denoising: joining would misalign the cohort, so
    // the job waits for the drain.
    let deep = EngineView {
        running: 2,
        max_batch: 4,
        lane_steps: vec![6, 8],
        ..Default::default()
    };
    assert_eq!(p.admit(&jobs(&[10]), &deep), 0);
    // The gate is the DEEPEST lane: one freshly started lane must not
    // hold the join window open while another is far into its schedule.
    let mixed = EngineView {
        running: 2,
        max_batch: 4,
        lane_steps: vec![0, 7],
        ..Default::default()
    };
    assert_eq!(p.admit(&jobs(&[10]), &mixed), 0);
    // Slots still bound the cohort.
    let full = EngineView {
        running: 4,
        max_batch: 4,
        lane_steps: vec![0, 0, 1, 1],
        ..Default::default()
    };
    assert_eq!(p.admit(&jobs(&[10]), &full), 0);
}

// ---------------------------------------------------------------------------
// FIFO: strict order, drain-then-refill.
// ---------------------------------------------------------------------------

#[test]
fn fifo_is_strictly_drain_then_refill() {
    let mut p = FifoPolicy;
    let busy = EngineView { running: 1, max_batch: 8, ..Default::default() };
    assert_eq!(p.admit(&jobs(&[1, 1, 1]), &busy), 0);
    let idle = EngineView { running: 0, max_batch: 8, ..Default::default() };
    assert_eq!(p.admit(&jobs(&[1; 12]), &idle), 8, "refill caps at max_batch");
}

// ---------------------------------------------------------------------------
// The headline claim: continuous batching beats FIFO mean JCT on the
// bundled AR traces (acceptance criterion of the scheduler bench).
// ---------------------------------------------------------------------------

#[test]
fn continuous_batching_beats_fifo_on_bundled_ar_traces() {
    for wl in [
        datasets::librispeech(1, 48, 0.0),
        datasets::seedtts(1, 48, 0.0),
        datasets::librispeech(2, 48, 4.0),
    ] {
        let reqs = from_workload(&wl);
        let fifo = simulate(&mut FifoPolicy, 4, &SimCost::default(), &reqs);
        let cont = simulate(
            &mut ContinuousBatchingPolicy { max_batch_tokens: 0 },
            4,
            &SimCost::default(),
            &reqs,
        );
        assert_eq!(fifo.jct.len(), wl.len());
        assert_eq!(cont.jct.len(), wl.len());
        assert!(
            cont.mean_jct() < fifo.mean_jct(),
            "{}: continuous {:.3}s !< fifo {:.3}s",
            wl.name,
            cont.mean_jct(),
            fifo.mean_jct()
        );
    }
}

// ---------------------------------------------------------------------------
// Overload control: SLO-aware admission + shedding beats
// FIFO-with-deadlines on goodput at every overload multiple,
// deterministically across 32 seeds — the acceptance property behind
// `omni-serve bench --trace overload-storm` (both call
// `overload_comparison`, so the gate and this test cannot drift).
// ---------------------------------------------------------------------------

#[test]
fn admission_beats_fifo_goodput_across_32_seeds_of_overload_storm() {
    use omni_serve::scheduler::sim::overload_comparison;
    let lanes = 4;
    for mult in [2.0, 3.0, 5.0] {
        let mut worst = f64::INFINITY;
        let mut sum = 0.0;
        for seed in 1..=32u64 {
            let c = overload_comparison(seed, lanes, mult);
            for rep in [&c.fifo, &c.admission] {
                // Nothing is ever silently dropped: every offered request
                // lands in exactly one outcome bucket.
                assert_eq!(rep.offered, 96);
                assert_eq!(
                    rep.rejected + rep.shed + rep.expired + rep.in_slo + rep.missed,
                    rep.offered,
                    "{} seed {seed} at {mult}x: outcome buckets do not partition",
                    rep.policy
                );
            }
            assert_eq!(c.fifo.rejected + c.fifo.shed, 0, "FIFO never refuses work");
            let m = c.margin();
            assert!(
                m > 0.0,
                "seed {seed} at {mult}x load: admission goodput {:.3} !> fifo {:.3}",
                c.admission.goodput(),
                c.fifo.goodput()
            );
            sum += m;
            worst = worst.min(m);
        }
        println!(
            "overload-storm {mult:.0}x over 32 seeds: goodput margin mean {:+.3} worst {:+.3}",
            sum / 32.0,
            worst
        );
        assert!(worst > 0.0, "margin must hold for every seed, worst was {worst:+.3}");
    }
    // Determinism: the same seed replays to the identical comparison.
    let a = overload_comparison(7, lanes, 3.0);
    let b = overload_comparison(7, lanes, 3.0);
    assert_eq!(a.margin(), b.margin());
    assert_eq!(
        (a.fifo.in_slo, a.fifo.expired, a.fifo.missed),
        (b.fifo.in_slo, b.fifo.expired, b.fifo.missed)
    );
    assert_eq!(
        (a.admission.in_slo, a.admission.rejected, a.admission.shed),
        (b.admission.in_slo, b.admission.rejected, b.admission.shed)
    );
}

// ---------------------------------------------------------------------------
// Global prefix cache: the cached engine beats the cold engine on BOTH
// mean TTFT and mean JCT at the same GPU budget, deterministically
// across 32 seeds of the shared-prefix trace — the acceptance property
// behind `omni-serve bench --trace shared-prefix` (both call
// `prefix_cache_comparison`, so the gate and this test cannot drift).
// ---------------------------------------------------------------------------

#[test]
fn prefix_cache_beats_cold_across_32_seeds_of_shared_prefix() {
    use omni_serve::scheduler::sim::prefix_cache_comparison;
    let max_batch = 4;
    let (mut worst_ttft, mut worst_jct) = (f64::INFINITY, f64::INFINITY);
    for seed in 1..=32u64 {
        let c = prefix_cache_comparison(seed, max_batch);
        // Both arms serve the identical offered load to completion.
        assert_eq!(c.cached.jct.len(), 64, "seed {seed}: cached run incomplete");
        assert_eq!(c.cold.jct.len(), 64, "seed {seed}: cold run incomplete");
        assert_eq!(c.cold.hits, 0, "the cold arm must never attach");
        assert!(c.cached.hits > 0, "seed {seed}: hot trace produced no attaches");
        assert!(
            c.cached.mean_ttft() < c.cold.mean_ttft(),
            "seed {seed}: cached {:.4}s !< cold {:.4}s mean TTFT",
            c.cached.mean_ttft(),
            c.cold.mean_ttft()
        );
        assert!(
            c.cached.mean_jct() < c.cold.mean_jct(),
            "seed {seed}: cached {:.4}s !< cold {:.4}s mean JCT",
            c.cached.mean_jct(),
            c.cold.mean_jct()
        );
        worst_ttft = worst_ttft.min(c.ttft_margin());
        worst_jct = worst_jct.min(c.jct_margin());
    }
    println!(
        "shared-prefix over 32 seeds: worst TTFT margin {:+.1}%, worst JCT margin {:+.1}%",
        100.0 * worst_ttft,
        100.0 * worst_jct
    );
    assert!(worst_ttft > 0.0 && worst_jct > 0.0);
    // Determinism: the same seed replays to the identical comparison.
    let a = prefix_cache_comparison(9, max_batch);
    let b = prefix_cache_comparison(9, max_batch);
    assert_eq!(a.cached.tokens_skipped, b.cached.tokens_skipped);
    assert_eq!(a.cached.jct.mean(), b.cached.jct.mean());
    assert_eq!(a.cold.ttft.mean(), b.cold.ttft.mean());
}

// ---------------------------------------------------------------------------
// Cluster placement: the transfer-aware replica→node assignment beats
// naive round-robin on mean JCT at equal hardware, deterministically
// across 32 seeds of the prefill-heavy trace — the acceptance property
// behind `omni-serve bench --trace cross-node` (both call
// `cross_node_comparison`, so the gate and this test cannot drift).
// ---------------------------------------------------------------------------

#[test]
fn transfer_aware_placement_beats_round_robin_across_32_seeds() {
    use omni_serve::scheduler::sim::cross_node_comparison;
    let mut worst = f64::INFINITY;
    let mut sum = 0.0;
    for seed in 1..=32u64 {
        let c = cross_node_comparison(seed);
        // Both arms serve the identical offered load to completion on
        // identically sized hardware (2 replicas per stage either way).
        assert_eq!(c.transfer_aware.jct.len(), 48, "seed {seed}: aware run incomplete");
        assert_eq!(c.round_robin.jct.len(), 48, "seed {seed}: rr run incomplete");
        // The aware plan keeps every KV replica pair node-local, so only
        // the byte-light vocoder hop crosses: one transfer per request
        // vs round-robin's two.
        assert_eq!(c.transfer_aware.cross_transfers, 48, "seed {seed}");
        assert_eq!(c.round_robin.cross_transfers, 96, "seed {seed}");
        assert!(
            c.transfer_aware.mean_jct() < c.round_robin.mean_jct(),
            "seed {seed}: transfer-aware {:.4}s !< round-robin {:.4}s mean JCT",
            c.transfer_aware.mean_jct(),
            c.round_robin.mean_jct()
        );
        let m = c.jct_margin();
        assert!(m > 0.03, "seed {seed}: JCT margin {:+.1}% below the 3% floor", 100.0 * m);
        sum += m;
        worst = worst.min(m);
    }
    println!(
        "cross-node over 32 seeds: JCT margin mean {:+.1}% worst {:+.1}%",
        100.0 * sum / 32.0,
        100.0 * worst
    );
    // Determinism: the same seed replays to the identical comparison.
    let a = cross_node_comparison(5);
    let b = cross_node_comparison(5);
    assert_eq!(a.transfer_aware.jct.mean(), b.transfer_aware.jct.mean());
    assert_eq!(a.round_robin.makespan_s, b.round_robin.makespan_s);
    assert_eq!(a.transfer_aware.transfer_s, b.transfer_aware.transfer_s);
    assert_eq!(a.aware_plan, b.aware_plan);
}

// ---------------------------------------------------------------------------
// Fractional GPU sharing (ISSUE 9): carving the encoder + vocoder into
// co-resident fractional slots frees a whole device for a third DiT
// replica, and at equal hardware (6 devices) the packed-fractional
// layout beats whole-device packing on mean JCT for every seed of the
// branching fan-out trace — the acceptance property behind
// `omni-serve bench --trace fractional` (both call
// `fractional_comparison`, so the gate and this test cannot drift).
// ---------------------------------------------------------------------------

#[test]
fn fractional_packing_beats_whole_device_packing_across_32_seeds() {
    use omni_serve::scheduler::sim::fractional_comparison;
    let mut worst = f64::INFINITY;
    let mut sum = 0.0;
    for seed in 1..=32u64 {
        let c = fractional_comparison(seed);
        // Both layouts serve the identical branching load to completion
        // (48 requests, each completing BOTH its image and speech arm).
        assert_eq!(c.fractional.jct.len(), 48, "seed {seed}: fractional run incomplete");
        assert_eq!(c.whole.jct.len(), 48, "seed {seed}: whole run incomplete");
        assert!(
            c.fractional.mean_jct() < c.whole.mean_jct(),
            "seed {seed}: fractional {:.4}s !< whole {:.4}s mean JCT",
            c.fractional.mean_jct(),
            c.whole.mean_jct()
        );
        let m = c.jct_margin();
        sum += m;
        worst = worst.min(m);
    }
    println!(
        "fractional over 32 seeds: JCT margin mean {:+.1}% worst {:+.1}%",
        100.0 * sum / 32.0,
        100.0 * worst
    );
    // Determinism: the same seed replays to the identical comparison.
    let a = fractional_comparison(7);
    let b = fractional_comparison(7);
    assert_eq!(a.fractional.jct.mean(), b.fractional.jct.mean());
    assert_eq!(a.whole.makespan_s, b.whole.makespan_s);
}

// ---------------------------------------------------------------------------
// StageAllocator validation.
// ---------------------------------------------------------------------------

#[test]
fn allocator_plans_presets_and_resolves_policies() {
    let p = presets::qwen25_omni();
    let plan = StageAllocator::new(&p).plan(None).unwrap();
    assert_eq!(plan.by_name("thinker").unwrap().policy, SchedPolicyKind::Continuous);
    assert_eq!(plan.by_name("vocoder").unwrap().policy, SchedPolicyKind::StepLevel);
    let epd = presets::qwen3_omni_epd();
    let plan = StageAllocator::new(&epd).plan(None).unwrap();
    assert_eq!(plan.by_name("encoder").unwrap().policy, SchedPolicyKind::Fifo);
}

#[test]
fn allocator_rejects_invalid_configs() {
    // Duplicate device in a TP group.
    let mut p = presets::qwen3_omni();
    p.stages[0].devices = vec![1, 1];
    assert!(StageAllocator::new(&p).plan(None).is_err());

    // Continuous batching on a non-AR stage.
    let mut p = presets::qwen25_omni();
    p.stages[2].sched.policy = SchedPolicyKind::Continuous;
    assert!(StageAllocator::new(&p).plan(None).is_err());

    // Token budget on a non-AR stage.
    let mut p = presets::qwen25_omni();
    p.stages[2].sched.max_batch_tokens = 64;
    assert!(StageAllocator::new(&p).plan(None).is_err());
}

// ---------------------------------------------------------------------------
// StageGraph::build validation (unknown transfer, cycle, multiple entries).
// ---------------------------------------------------------------------------

fn edge(from: &str, to: &str, transfer: &str) -> EdgeConfig {
    EdgeConfig {
        from: from.into(),
        to: to.into(),
        transfer: transfer.into(),
        connector: omni_serve::config::ConnectorKind::Inline,
        routing: omni_serve::config::RoutingKind::Auto,
    }
}

#[test]
fn stage_graph_rejects_unknown_transfer() {
    let mut p = presets::qwen3_omni();
    p.edges[0].transfer = "does_not_exist".into();
    let err = StageGraph::build(p, &Registry::builtin()).unwrap_err();
    assert!(format!("{err:#}").contains("unknown transfer"), "{err:#}");
}

#[test]
fn stage_graph_rejects_cycle() {
    let mut p = presets::qwen3_omni();
    p.edges.push(edge("vocoder", "thinker", "thinker2talker"));
    let err = StageGraph::build(p, &Registry::builtin()).unwrap_err();
    assert!(format!("{err:#}").contains("cycle"), "{err:#}");
}

#[test]
fn stage_graph_rejects_multiple_entries() {
    let mut p = presets::qwen3_omni();
    p.edges.remove(0); // thinker->talker gone: thinker AND talker become entries
    let err = StageGraph::build(p, &Registry::builtin()).unwrap_err();
    assert!(format!("{err:#}").contains("exactly one entry"), "{err:#}");
}

#[test]
fn stage_graph_accepts_custom_transfer_after_registration() {
    use omni_serve::stage_graph::transfers::{Transfer, TransferCtx};
    let mut reg = Registry::builtin();
    reg.register(
        "custom",
        std::sync::Arc::new(|_ctx: TransferCtx| -> Transfer { Box::new(|_item| Ok(vec![])) }),
    );
    let mut p: PipelineConfig = presets::qwen3_omni();
    p.edges[0].transfer = "custom".into();
    assert!(StageGraph::build(p, &reg).is_ok());
}

// ---------------------------------------------------------------------------
// Stage replication: allocator packing, routing validation, and the
// replicated sim model end-to-end (paper §3.3 flexible GPU allocation).
// ---------------------------------------------------------------------------

#[test]
fn allocator_packs_replicas_and_keeps_single_replica_plans_identical() {
    let base = StageAllocator::new(&presets::qwen3_omni()).plan(None).unwrap();
    for a in base.assignments() {
        assert_eq!(a.replicas, 1);
        assert_eq!(a.replica_devices, vec![a.devices.clone()]);
    }
    let rep = StageAllocator::new(&presets::qwen3_omni_replicated()).plan(None).unwrap();
    let talker = rep.by_name("talker").unwrap();
    assert_eq!(talker.replicas, 2);
    assert_eq!(talker.replica_devices.len(), 2);
    // Replica 0 keeps the configured placement; replica 1 is packed onto
    // another device rather than stacked.
    assert_eq!(talker.replica_devices[0], talker.devices);
    assert_ne!(talker.replica_devices[1], talker.replica_devices[0]);
}

#[test]
fn replicated_ar_stage_demands_affinity_routing_at_graph_build() {
    let mut p = presets::qwen3_omni_replicated();
    p.edges[0].routing = omni_serve::config::RoutingKind::RoundRobin;
    let err = StageGraph::build(p, &Registry::builtin()).unwrap_err();
    assert!(format!("{err:#}").contains("affinity"), "{err:#}");
}

#[test]
fn replicated_sim_reproduces_the_flexible_allocation_win() {
    use omni_serve::scheduler::sim::{simulate_replicated, SimRouting};
    // End-to-end on the sim model: the bundled preset's talker stage at
    // replicas=2 (qwen3-omni-rep2) beats replicas=1 (qwen3-omni) on mean
    // JCT over a bundled trace — the bench's acceptance property.
    let plan = StageAllocator::new(&presets::qwen3_omni_replicated()).plan(None).unwrap();
    let talker = plan.by_name("talker").unwrap();
    let wl = datasets::seedtts(21, 32, 0.0);
    let reqs = from_workload(&wl);
    let mk = |n: usize| -> Vec<Box<dyn BatchPolicy>> {
        (0..n)
            .map(|_| {
                Box::new(ContinuousBatchingPolicy { max_batch_tokens: talker.max_batch_tokens })
                    as Box<dyn BatchPolicy>
            })
            .collect()
    };
    let one = simulate_replicated(
        &mut mk(1),
        talker.max_batch,
        &SimCost::default(),
        &reqs,
        SimRouting::Affinity,
    );
    let two = simulate_replicated(
        &mut mk(talker.replicas),
        talker.max_batch,
        &SimCost::default(),
        &reqs,
        SimRouting::Affinity,
    );
    assert_eq!(one.jct.len(), wl.len());
    assert_eq!(two.jct.len(), wl.len());
    assert!(
        two.mean_jct() < one.mean_jct(),
        "replicas=2 {:.3}s !< replicas=1 {:.3}s",
        two.mean_jct(),
        one.mean_jct()
    );
}

#[test]
fn replication_fields_survive_json_roundtrip() {
    let p = presets::qwen3_omni_replicated();
    let s = omni_serve::config::loader::to_json_string(&p);
    let v = omni_serve::json::parse(&s).unwrap();
    let q = omni_serve::config::loader::from_value(&v).unwrap();
    assert_eq!(q.stage("talker").unwrap().replicas, 2);
    assert_eq!(q.edges[0].routing, omni_serve::config::RoutingKind::CacheAware);
}

#[test]
fn sched_fields_survive_json_roundtrip() {
    let mut p = presets::qwen25_omni();
    p.stages[0].sched.policy = SchedPolicyKind::Continuous;
    p.stages[0].sched.max_batch_tokens = 256;
    p.stages[0].sched.queue_depth = 16;
    let s = omni_serve::config::loader::to_json_string(&p);
    let v = omni_serve::json::parse(&s).unwrap();
    let q = omni_serve::config::loader::from_value(&v).unwrap();
    assert_eq!(q.stages[0].sched.policy, SchedPolicyKind::Continuous);
    assert_eq!(q.stages[0].sched.max_batch_tokens, 256);
    assert_eq!(q.stages[0].sched.queue_depth, 16);
}

#[test]
fn policies_validate_against_stage_kinds_in_graph_build() {
    // StageGraph::build -> PipelineConfig::validate does structural checks;
    // the allocator runs at orchestrator construction.  Both paths reject a
    // StepLevel policy on an AR stage.
    let mut p = presets::mimo_audio(1);
    p.stages[0].sched.policy = SchedPolicyKind::StepLevel;
    assert_eq!(p.stages[0].kind, StageKind::Ar);
    assert!(StageAllocator::new(&p).plan(None).is_err());
}
