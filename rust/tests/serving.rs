//! Serving-runtime integration tests: the elastic-autoscaler acceptance
//! property on the deterministic AR-stage model (no artifacts needed),
//! and — when compiled artifacts exist — the persistent ServingSession
//! over the real pipeline.

use std::time::Duration;

use omni_serve::config::presets;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::scheduler::sim::elastic_comparison;
use omni_serve::serving::{
    OmniRequest, OutputDelta, ServingSession, SessionOptions, StreamRecv, WaitResult,
};
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::trace::datasets;

// -------------------------------------------------------------------------
// The acceptance criterion: on the bursty mixed-modality trace, the
// autoscaled run beats EVERY static replica split with the same total
// GPU budget on mean JCT, and records both scale directions.
// -------------------------------------------------------------------------

#[test]
fn autoscaled_beats_every_static_split_on_the_bursty_trace() {
    let budget = 4usize;
    let wl = datasets::bursty_mixed(1, 48, 2.0);
    let (statics, auto) = elastic_comparison(&wl, budget);
    assert_eq!(auto.jct.len(), wl.len(), "autoscaled run must complete everything");
    assert!(auto.scale_ups >= 1, "expected at least one scale-up");
    assert!(auto.scale_downs >= 1, "expected at least one scale-down");
    assert!(auto.max_slots <= budget, "budget violated: peak {} slots", auto.max_slots);
    assert_eq!(statics.len(), budget - 1, "every split of the budget is covered");
    for rep in &statics {
        assert_eq!(rep.jct.len(), wl.len());
        assert!(
            auto.mean_jct() < rep.mean_jct(),
            "autoscaled {:.3}s !< {} {:.3}s",
            auto.mean_jct(),
            rep.policy,
            rep.mean_jct()
        );
    }
}

#[test]
fn autoscaling_holds_fewer_gpu_seconds_than_the_full_static_budget() {
    // Elasticity is not just faster — between bursts it returns slots,
    // so its ∫replicas·dt stays under budget × makespan.
    let wl = datasets::bursty_mixed(5, 40, 2.5);
    let (_, auto) = elastic_comparison(&wl, 4);
    assert!(auto.replica_seconds < 4.0 * auto.makespan_s);
    // The timeline starts from the min allocation and never dips below it.
    for (_, counts) in &auto.timeline {
        assert_eq!(counts.len(), 2);
        assert!(counts.iter().all(|&c| c >= 1));
    }
}

// -------------------------------------------------------------------------
// Real-runtime session tests (need compiled artifacts; skipped in CI
// containers without JAX).
// -------------------------------------------------------------------------

fn artifacts() -> Option<std::sync::Arc<omni_serve::runtime::Artifacts>> {
    let dir = omni_serve::runtime::Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        return None;
    }
    Some(std::sync::Arc::new(omni_serve::runtime::Artifacts::load(&dir).unwrap()))
}

#[test]
fn serving_session_submits_continuously_and_drains() {
    let Some(artifacts) = artifacts() else { return };
    let orch = Orchestrator::new(
        presets::mimo_audio(1),
        artifacts,
        Registry::builtin(),
        RunOptions::default(),
    )
    .unwrap();
    let session = ServingSession::start(&orch, SessionOptions::default()).unwrap();
    // Two waves of requests through ONE spawned pipeline.
    let wl = datasets::seedtts(3, 4, 0.0);
    let mut handles = Vec::new();
    for r in wl.requests.iter().take(2).cloned() {
        handles.push(session.submit(r).unwrap());
    }
    for h in &handles {
        loop {
            match h.wait_timeout(Duration::from_millis(200)) {
                WaitResult::Done(c) => {
                    assert!(c.completed_t >= h.submitted_t());
                    break;
                }
                WaitResult::Rejected { .. } => panic!("no admission controller configured"),
                WaitResult::Timeout => assert!(!session.failed(), "pipeline failed"),
                WaitResult::Closed => panic!("collector gone"),
            }
        }
    }
    assert_eq!(session.inflight(), 0);
    // Second wave on the same session.
    let h = session.submit(wl.requests[2].clone()).unwrap();
    loop {
        match h.wait_timeout(Duration::from_millis(200)) {
            WaitResult::Done(_) => break,
            WaitResult::Rejected { .. } => panic!("no admission controller configured"),
            WaitResult::Timeout => assert!(!session.failed()),
            WaitResult::Closed => panic!("collector gone"),
        }
    }
    assert!(session.drain(Duration::from_secs(5)));
    let summary = session.shutdown(Some("backbone")).unwrap();
    assert_eq!(summary.report.completed, 3);
    assert!(summary.report.mean_jct() > 0.0);
}

#[test]
fn streaming_request_delivers_typed_deltas_before_done() {
    let Some(artifacts) = artifacts() else { return };
    let orch = Orchestrator::new(
        presets::mimo_audio(1),
        artifacts,
        Registry::builtin(),
        RunOptions::default(),
    )
    .unwrap();
    let session = ServingSession::start(&orch, SessionOptions::default()).unwrap();
    let wl = datasets::seedtts(5, 2, 0.0);
    let mut rs = session
        .submit_request(OmniRequest::from(wl.requests[0].clone()).streaming(true))
        .unwrap();
    let mut audio_before_done = 0usize;
    let mut stage_dones = 0usize;
    let (mut done_t, mut first_audio_t) = (f64::MAX, f64::MAX);
    loop {
        match rs.next_timeout(Duration::from_secs(30)) {
            StreamRecv::Delta(OutputDelta::AudioChunk { wave, t }) => {
                assert!(!wave.is_empty());
                audio_before_done += 1;
                first_audio_t = first_audio_t.min(t);
            }
            StreamRecv::Delta(OutputDelta::StageDone { .. }) => stage_dones += 1,
            StreamRecv::Delta(OutputDelta::Done { t, jct_s, cancelled, usage }) => {
                assert!(!cancelled);
                assert!(jct_s > 0.0);
                assert_eq!(usage.deltas, audio_before_done);
                assert!(usage.audio_samples > 0);
                done_t = t;
                break;
            }
            StreamRecv::Delta(_) => {}
            StreamRecv::Timeout => panic!("stream starved"),
            StreamRecv::Closed => panic!("stream closed before Done"),
        }
    }
    assert!(rs.is_done());
    assert!(audio_before_done >= 1, "no mid-flight audio delta arrived");
    assert!(first_audio_t < done_t, "first AudioChunk must precede Done");
    assert!(stage_dones >= 1, "backbone's StageDone marker must stream");
    // Non-streaming requests still resolve through the shim unchanged,
    // and the report now carries client-boundary TPOT samples.
    let h = session.submit(wl.requests[1].clone()).unwrap();
    loop {
        match h.wait_timeout(Duration::from_millis(500)) {
            WaitResult::Done(c) => {
                assert!(c.completed_t >= h.submitted_t());
                break;
            }
            WaitResult::Rejected { .. } => panic!("no admission controller configured"),
            WaitResult::Timeout => assert!(!session.failed()),
            WaitResult::Closed => panic!("collector gone"),
        }
    }
    let summary = session.shutdown(Some("backbone")).unwrap();
    assert_eq!(summary.report.completed, 2);
    assert_eq!(summary.report.cancelled, 0);
    if audio_before_done >= 2 {
        assert!(!summary.report.tpot.is_empty(), "inter-delta gaps must be recorded");
    }
}

#[test]
fn run_workload_wrapper_matches_the_one_shot_contract() {
    // The one-shot API is now a wrapper over ServingSession; it must
    // still complete a whole trace and report per-stage summaries.
    let Some(artifacts) = artifacts() else { return };
    let orch = Orchestrator::new(
        presets::mimo_audio(1),
        artifacts,
        Registry::builtin(),
        RunOptions::default(),
    )
    .unwrap();
    let wl = datasets::seedtts(7, 3, 0.0);
    let s = orch.run_workload(&wl, Some("backbone")).unwrap();
    assert_eq!(s.report.completed, wl.len());
    assert!(s.stages.iter().any(|st| st.name == "backbone"));
    assert!(s.stages.iter().any(|st| st.name == "patch_dec"));
}
