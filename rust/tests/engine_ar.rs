//! Integration tests for the AR engine against real compiled artifacts:
//! continuous batching, chunked prefill, multi-step scan equivalence,
//! streaming, preemption, and conditioning.
//!
//! Requires `make artifacts`; tests skip (with a note) if missing.

use omni_serve::engine::ar::{embed_job, token_job, ArEngine, ArEngineOptions, Preprocess, SCAN_STEPS};
use omni_serve::engine::{SamplingParams, StageItem};
use omni_serve::runtime::Artifacts;
use omni_serve::tokenizer::BOS_ID;

fn artifacts() -> Option<Artifacts> {
    let dir = Artifacts::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Artifacts::load(&dir).unwrap())
    } else {
        eprintln!("skipping: run `make artifacts`");
        None
    }
}

fn sampling(n: usize) -> SamplingParams {
    SamplingParams { max_new_tokens: n, temperature: 0.0, top_k: 0, ignore_eos: true, seed: 9 }
}

fn collect_tokens(items: &[StageItem], req: u64) -> Vec<i32> {
    let mut out = vec![];
    for it in items.iter().filter(|i| i.req_id == req) {
        if let Some(t) = it.tensor("tokens") {
            out.extend_from_slice(t.as_i32().unwrap());
        }
    }
    out
}

#[test]
fn batched_decode_matches_solo_decode() {
    let Some(art) = artifacts() else { return };
    // Run 3 different prompts batched, then the middle one alone: greedy
    // outputs must be identical (continuous batching must not perturb
    // per-sequence numerics).
    let prompts: Vec<Vec<u32>> = vec![
        vec![BOS_ID, 10, 20, 30],
        vec![BOS_ID, 100, 200, 300, 400, 500],
        vec![BOS_ID, 9, 8, 7, 6, 5, 4],
    ];
    let mk_engine = |max_batch: usize| {
        ArEngine::new(
            &art,
            "mimo",
            ArEngineOptions { max_batch, stream_chunk: 0, ..Default::default() },
        )
        .unwrap()
    };
    let mut batched = mk_engine(4);
    for (i, p) in prompts.iter().enumerate() {
        batched.submit(token_job(i as u64, p, sampling(12)));
    }
    let items = batched.run_to_completion().unwrap();
    let batched_mid = collect_tokens(&items, 1);
    assert_eq!(batched_mid.len(), 12);

    let mut solo = mk_engine(1);
    solo.submit(token_job(1, &prompts[1], sampling(12)));
    let items = solo.run_to_completion().unwrap();
    assert_eq!(collect_tokens(&items, 1), batched_mid);
}

#[test]
fn chunked_prefill_matches_unchunked() {
    let Some(art) = artifacts() else { return };
    // 40-token prompt spans two chunks; output must be identical with
    // chunked prefill on/off.
    let prompt: Vec<u32> = std::iter::once(BOS_ID).chain((0..39).map(|i| 10 + i)).collect();
    let mut outs = vec![];
    for chunked in [true, false] {
        let mut e = ArEngine::new(
            &art,
            "mimo",
            ArEngineOptions {
                max_batch: 1,
                chunked_prefill: chunked,
                stream_chunk: 0,
                ..Default::default()
            },
        )
        .unwrap();
        e.submit(token_job(1, &prompt, sampling(10)));
        let items = e.run_to_completion().unwrap();
        outs.push(collect_tokens(&items, 1));
    }
    assert_eq!(outs[0], outs[1]);
}

#[test]
fn scan_decode_matches_stepwise() {
    let Some(art) = artifacts() else { return };
    let prompt: Vec<u32> = vec![BOS_ID, 42, 43, 44];
    let mut outs = vec![];
    for multi_step in [1usize, SCAN_STEPS] {
        let mut e = ArEngine::new(
            &art,
            "mimo",
            ArEngineOptions { max_batch: 1, multi_step, stream_chunk: 0, ..Default::default() },
        )
        .unwrap();
        e.submit(token_job(1, &prompt, sampling(SCAN_STEPS * 2)));
        let items = e.run_to_completion().unwrap();
        outs.push(collect_tokens(&items, 1));
    }
    assert_eq!(outs[0], outs[1], "fused scan must reproduce per-step greedy decode");
}

#[test]
fn streaming_emits_incremental_chunks() {
    let Some(art) = artifacts() else { return };
    let mut e = ArEngine::new(
        &art,
        "mimo",
        ArEngineOptions { max_batch: 1, stream_chunk: 4, ..Default::default() },
    )
    .unwrap();
    e.submit(token_job(1, &[BOS_ID, 3], sampling(14)));
    let items = e.run_to_completion().unwrap();
    assert!(items.len() >= 3, "expected streamed chunks, got {}", items.len());
    assert!(items.last().unwrap().finished);
    assert!(items[..items.len() - 1].iter().all(|i| !i.finished));
    let total: usize = items
        .iter()
        .map(|i| i.tensor("tokens").unwrap().len())
        .sum();
    assert_eq!(total, 14);
}

#[test]
fn hiddens_emitted_per_token() {
    let Some(art) = artifacts() else { return };
    let mut e = ArEngine::new(
        &art,
        "thinker25",
        ArEngineOptions { max_batch: 1, stream_chunk: 0, ..Default::default() },
    )
    .unwrap();
    e.submit(token_job(1, &[BOS_ID, 5, 6], sampling(6)));
    let items = e.run_to_completion().unwrap();
    let h = items.last().unwrap().tensor("hiddens").unwrap();
    assert_eq!(h.shape, vec![6, 256]); // d_model of thinker25
    assert!(h.as_f32().unwrap().iter().any(|&x| x != 0.0));
}

#[test]
fn conditioning_changes_talker_output() {
    let Some(art) = artifacts() else { return };
    let mk = || {
        ArEngine::new(
            &art,
            "talker25",
            ArEngineOptions {
                max_batch: 1,
                stream_chunk: 0,
                preprocess: Preprocess::UpstreamMean,
                ..Default::default()
            },
        )
        .unwrap()
    };
    // Same prompt, different upstream hidden streams -> different audio.
    let run_with = |bias: f32| {
        let mut e = mk();
        e.submit(embed_job(1, &[], 0, sampling(10)));
        let rows: Vec<f32> = (0..256).map(|i| bias + (i as f32) * 0.01).collect();
        e.push_upstream(1, &rows, 256, true);
        let items = e.run_to_completion().unwrap();
        collect_tokens(&items, 1)
    };
    let a = run_with(0.0);
    let b = run_with(5.0);
    assert_eq!(a.len(), 10);
    assert_ne!(a, b, "thinker conditioning must influence talker tokens");
}

#[test]
fn tiny_kv_pool_preempts_but_completes() {
    let Some(art) = artifacts() else { return };
    let mut e = ArEngine::new(
        &art,
        "mimo",
        ArEngineOptions {
            max_batch: 4,
            stream_chunk: 0,
            // Pool fits roughly one sequence: forces queueing/preemption.
            kv_blocks: 8,
            kv_block_size: 16,
            ..Default::default()
        },
    )
    .unwrap();
    for i in 0..4 {
        e.submit(token_job(i, &[BOS_ID, 50 + i as u32], sampling(24)));
    }
    let items = e.run_to_completion().unwrap();
    for i in 0..4 {
        assert_eq!(collect_tokens(&items, i).len(), 24, "req {i} incomplete");
    }
}

#[test]
fn eos_respected_when_not_ignored() {
    let Some(art) = artifacts() else { return };
    let mut e = ArEngine::new(
        &art,
        "mimo",
        ArEngineOptions { max_batch: 1, stream_chunk: 0, ..Default::default() },
    )
    .unwrap();
    let mut s = sampling(200);
    s.ignore_eos = false;
    e.submit(token_job(1, &[BOS_ID, 77], s));
    let items = e.run_to_completion().unwrap();
    let toks = collect_tokens(&items, 1);
    // Either the model hit EOS (sequence ends with it) or produced the cap.
    if toks.len() < 200 {
        assert_eq!(*toks.last().unwrap() as u32, omni_serve::tokenizer::EOS_ID);
    }
}
