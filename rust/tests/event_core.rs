//! Event-core directed wake tests (ISSUE 10, satellites c + f).
//!
//! Artifact-free: each test runs a stage-loop-shaped body under
//! [`drive`] + [`RealDriver`] on a worker thread, parks it on a
//! [`WakeSet`] mailbox, and then delivers one specific wake reason from
//! the main thread — a cancel tombstone, a drain command, a shutdown
//! that races the park, an edge close.  The property under test is
//! liveness: the parked worker observes the event and exits promptly,
//! with no hang and no missed shutdown.  Every wait goes through
//! `recv_timeout`, so a regression fails the assertion instead of
//! wedging the suite.  The edge-close test additionally pins the
//! flush-exactly-once contract for `TryRecv::Closed` drain paths
//! (neither double-flush nor never-flush).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use omni_serve::config::{ConnectorKind, RoutingKind};
use omni_serve::connector::router::wire;
use omni_serve::connector::TryRecv;
use omni_serve::engine::StageItem;
use omni_serve::event_core::{drive, RealDriver, Tick, WakeSet, WAKE_CANCEL, WAKE_CTL};
use omni_serve::orchestrator::RunClock;
use omni_serve::serving::Tombstones;

/// Generous bound for "promptly": a live wake resolves in microseconds
/// and even the parked backstop re-checks every 25 ms, so hitting this
/// means the wake hook is gone, not that CI is slow.
const WEDGE: Duration = Duration::from_secs(10);

/// Long enough for the worker to drain its startup work and park.
const SETTLE: Duration = Duration::from_millis(30);

#[test]
fn parked_worker_wakes_on_a_cancel_tombstone() {
    let wake = Arc::new(WakeSet::new());
    let stones = Arc::new(Tombstones::new());
    let (done_tx, done_rx) = mpsc::channel();

    let w = wake.clone();
    let s = stones.clone();
    let worker = thread::spawn(move || {
        let mut real = RealDriver::new(RunClock::new());
        let mut seen_gen = s.generation();
        let mut swept: Vec<u64> = Vec::new();
        drive(&mut real, &w, |_drv| {
            // The stage-loop sweep idiom: only rescan the tombstone set
            // when its generation moved.
            let gen = s.generation();
            if gen != seen_gen {
                seen_gen = gen;
                swept.extend(s.snapshot());
                if swept.contains(&7) {
                    return Ok(Tick::Exit);
                }
                return Ok(Tick::Progress);
            }
            Ok(Tick::Idle(None))
        })
        .unwrap();
        done_tx.send(swept).unwrap();
    });

    thread::sleep(SETTLE);
    stones.mark(7, 0.0);
    wake.wake(WAKE_CANCEL);

    let swept = done_rx
        .recv_timeout(WEDGE)
        .expect("parked worker never woke on the cancel tombstone");
    assert!(swept.contains(&7), "sweep missed the tombstoned request: {swept:?}");
    worker.join().unwrap();
}

#[test]
fn parked_worker_wakes_on_a_drain_command() {
    let wake = Arc::new(WakeSet::new());
    let draining = Arc::new(AtomicBool::new(false));
    let (done_tx, done_rx) = mpsc::channel();

    let w = wake.clone();
    let d = draining.clone();
    let worker = thread::spawn(move || {
        let mut real = RealDriver::new(RunClock::new());
        drive(&mut real, &w, |_drv| {
            if d.load(Ordering::SeqCst) {
                return Ok(Tick::Exit);
            }
            Ok(Tick::Idle(None))
        })
        .unwrap();
        done_tx.send(()).unwrap();
    });

    thread::sleep(SETTLE);
    draining.store(true, Ordering::SeqCst);
    wake.wake(WAKE_CTL);

    done_rx.recv_timeout(WEDGE).expect("parked worker never woke on the drain command");
    worker.join().unwrap();

    // Observability rides along: the park time and at least one park
    // outcome must have been recorded (satellite b's counters).
    let wc = wake.counters();
    assert!(wc.idle_ns > 0, "parked time went unrecorded");
    assert!(wc.wakeups + wc.spurious_wakeups >= 1, "no park outcome was counted: {wc:?}");
}

#[test]
fn shutdown_racing_the_park_is_never_missed() {
    // The wake fires while the worker is still busy (before its first
    // park).  WakeSet::wake sets the bit under the mutex, so the
    // eventual park must drain it immediately instead of sleeping — a
    // missed shutdown here is the classic lost-wakeup bug.
    let wake = Arc::new(WakeSet::new());
    let stop = Arc::new(AtomicBool::new(false));
    let (done_tx, done_rx) = mpsc::channel();

    let w = wake.clone();
    let st = stop.clone();
    let worker = thread::spawn(move || {
        // Simulate a long engine step: the stop lands mid-tick.
        thread::sleep(Duration::from_millis(20));
        let mut real = RealDriver::new(RunClock::new());
        drive(&mut real, &w, |_drv| {
            if st.load(Ordering::SeqCst) {
                return Ok(Tick::Exit);
            }
            Ok(Tick::Idle(None))
        })
        .unwrap();
        done_tx.send(()).unwrap();
    });

    stop.store(true, Ordering::SeqCst);
    wake.wake(WAKE_CTL);

    done_rx.recv_timeout(WEDGE).expect("worker missed a shutdown that raced its park");
    worker.join().unwrap();
}

#[test]
fn edge_close_wakes_the_parked_consumer_and_flushes_exactly_once() {
    let (mut txs, mut rxs) =
        wire(ConnectorKind::Inline, RoutingKind::Auto, "ev-close", None, 1, 1).unwrap();
    let wake = Arc::new(WakeSet::new());
    let mut rx = rxs.remove(0);
    rx.register_wake(wake.clone());
    let (done_tx, done_rx) = mpsc::channel();

    let w = wake.clone();
    let worker = thread::spawn(move || {
        let mut real = RealDriver::new(RunClock::new());
        let mut got: Vec<u64> = Vec::new();
        let mut flushes = 0u32;
        drive(&mut real, &w, |_drv| loop {
            match rx.try_recv()? {
                TryRecv::Item(it) => got.push(it.req_id),
                TryRecv::Empty => return Ok(Tick::Idle(None)),
                TryRecv::Closed => {
                    // The drain-and-flush arm: reached once, then the
                    // worker exits instead of polling a dead edge.
                    flushes += 1;
                    return Ok(Tick::Exit);
                }
            }
        })
        .unwrap();
        // Closed is sticky on the channel — the exactly-once property
        // lives in the loop structure, so prove the edge would keep
        // reporting Closed if the worker (wrongly) came back.
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Closed));
        done_tx.send((got, flushes)).unwrap();
    });

    let mut tx = txs.remove(0);
    tx.send(StageItem::new(1)).unwrap();
    tx.send(StageItem::new(2)).unwrap();
    thread::sleep(SETTLE); // worker drains both items, then parks
    drop(tx); // last producer gone: close wakes the parked consumer

    let (got, flushes) = done_rx
        .recv_timeout(WEDGE)
        .expect("parked consumer never woke on the edge close (flush never ran)");
    assert_eq!(got, vec![1, 2], "items lost across the park/close");
    assert_eq!(flushes, 1, "drain-and-flush must run exactly once, ran {flushes} times");
    worker.join().unwrap();
}
