//! End-to-end cancellation acceptance tests (ISSUE 5).
//!
//! Engine level (needs compiled artifacts; skipped in CI containers
//! without JAX): requests cancelled at randomized points — queued,
//! mid-prefill, mid-decode, and around a prefill→decode KV handoff —
//! must always leave `BlockManager::check_invariants` green, release
//! every KV block, and emit no further items.  Session level: cancelled
//! requests resolve with `Done { cancelled: true }`, per-stage queues
//! drain, and the pipeline keeps serving afterwards.

use std::time::Duration;

use omni_serve::config::{presets, StageRole};
use omni_serve::engine::ar::{token_job, ArEngine, ArEngineOptions};
use omni_serve::engine::SamplingParams;
use omni_serve::kv_transfer::{KvHandoff, KV_TENSOR};
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::runtime::Artifacts;
use omni_serve::serving::{
    OmniRequest, OutputDelta, ServingSession, SessionOptions, StreamRecv,
};
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::tokenizer::BOS_ID;
use omni_serve::trace::datasets;
use omni_serve::util::Prng;

fn artifacts() -> Option<Artifacts> {
    let dir = Artifacts::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Artifacts::load(&dir).unwrap())
    } else {
        eprintln!("skipping: run `make artifacts`");
        None
    }
}

fn sampling(n: usize, seed: u64) -> SamplingParams {
    SamplingParams { max_new_tokens: n, temperature: 0.0, top_k: 0, ignore_eos: true, seed }
}

// -------------------------------------------------------------------------
// Engine level: randomized cancel points against the real AR engine.
// -------------------------------------------------------------------------

#[test]
fn cancel_at_randomized_points_preserves_kv_invariants() {
    let Some(art) = artifacts() else { return };
    let mut rng = Prng::new(0xCA9CE1);
    for trial in 0..6u64 {
        let mut eng = ArEngine::new(
            &art,
            "mimo",
            ArEngineOptions { max_batch: 2, stream_chunk: 4, ..Default::default() },
        )
        .unwrap();
        let n_blocks = eng.block_manager().n_blocks();
        let n_reqs = rng.range(3, 5) as u64;
        for rid in 0..n_reqs {
            let len = rng.range(2, 40);
            let mut prompt = vec![BOS_ID];
            prompt.extend((0..len).map(|i| (i % 50 + 3) as u32));
            eng.submit(token_job(rid, &prompt, sampling(rng.range(4, 16), trial ^ rid)));
        }
        // Cancel each victim after a random number of engine steps: 0 =
        // still queued, small = mid-prefill (long prompts span several
        // chunks at max_batch 2), larger = mid-decode.
        let mut cancel_at: Vec<(u64, usize)> = Vec::new();
        for rid in 0..n_reqs {
            if rng.bool(0.7) {
                cancel_at.push((rid, rng.range(0, 12)));
            }
        }
        let mut cancelled: Vec<u64> = vec![];
        let mut steps = 0usize;
        loop {
            for &(rid, at) in &cancel_at {
                if at == steps {
                    eng.cancel(rid);
                    cancelled.push(rid);
                    eng.block_manager().check_invariants().unwrap();
                }
            }
            cancel_at.retain(|&(rid, _)| !cancelled.contains(&rid));
            if eng.idle() {
                break;
            }
            let items = eng.step().unwrap();
            steps += 1;
            for it in &items {
                assert!(
                    !cancelled.contains(&it.req_id),
                    "trial {trial}: cancelled request {} emitted an item after abort",
                    it.req_id
                );
            }
            eng.block_manager().check_invariants().unwrap();
            assert!(steps < 10_000, "trial {trial}: engine failed to drain");
        }
        // Every sequence — completed or cancelled — returned its blocks
        // (free, or parked refcount-0 in the prefix cache: both are
        // reclaimable; only a leaked refcount would not be).
        assert_eq!(
            eng.block_manager().reclaimable_blocks(),
            n_blocks,
            "trial {trial}: KV blocks leaked (cancelled: {cancelled:?})"
        );
        eng.block_manager().check_invariants().unwrap();
    }
}

#[test]
fn cancel_around_a_kv_handoff_preserves_invariants() {
    let Some(art) = artifacts() else { return };
    let prompt: Vec<u32> = {
        let mut p = vec![BOS_ID];
        p.extend((0..21).map(|i| (i * 3 % 40 + 2) as u32));
        p
    };
    // Prefill-role engine: export releases the exporter's blocks.
    let mut pre = ArEngine::new(
        &art,
        "mimo",
        ArEngineOptions { max_batch: 2, stream_chunk: 0, role: StageRole::Prefill, ..Default::default() },
    )
    .unwrap();
    let pre_blocks = pre.block_manager().n_blocks();
    pre.submit(token_job(7, &prompt, sampling(12, 3)));
    let items = pre.run_to_completion().unwrap();
    assert_eq!(
        pre.block_manager().reclaimable_blocks(),
        pre_blocks,
        "export must return the prefill pool (free or cached, never referenced)"
    );
    let h = KvHandoff::from_tensor(items[0].tensor(KV_TENSOR).unwrap()).unwrap();

    let mk_decode = || {
        ArEngine::new(
            &art,
            "mimo",
            ArEngineOptions { max_batch: 2, stream_chunk: 0, role: StageRole::Decode, ..Default::default() },
        )
        .unwrap()
    };
    // (a) Cancelled while the exported handoff waits, pre-import: the
    // waiting sequence holds no blocks yet.
    let mut dec = mk_decode();
    let dec_blocks = dec.block_manager().n_blocks();
    dec.submit_handoff(h.clone()).unwrap();
    assert!(dec.cancel(7), "queued handoff must be cancellable");
    assert!(dec.idle());
    assert_eq!(dec.block_manager().reclaimable_blocks(), dec_blocks);
    dec.block_manager().check_invariants().unwrap();

    // (b) Cancelled mid-decode, post-import: the imported blocks (and
    // the appended decode rows) are all released.
    let mut dec = mk_decode();
    dec.submit_handoff(h.clone()).unwrap();
    for _ in 0..3 {
        dec.step().unwrap();
    }
    assert!(dec.stats.kv_imports >= 1, "import must have happened before the cancel");
    assert!(dec.cancel(7));
    assert!(dec.idle());
    assert_eq!(dec.block_manager().reclaimable_blocks(), dec_blocks);
    dec.block_manager().check_invariants().unwrap();

    // (c) The engine still serves the same handoff cleanly afterwards.
    dec.submit_handoff(h).unwrap();
    let items = dec.run_to_completion().unwrap();
    assert!(items.iter().any(|i| i.finished && i.req_id == 7));
    assert_eq!(dec.block_manager().reclaimable_blocks(), dec_blocks);
    dec.block_manager().check_invariants().unwrap();
}

// -------------------------------------------------------------------------
// Session level: streams resolve with Done{cancelled}, queues drain,
// the pipeline stays healthy.
// -------------------------------------------------------------------------

fn session() -> Option<ServingSession> {
    let art = artifacts()?;
    let orch = Orchestrator::new(
        presets::mimo_audio(1),
        std::sync::Arc::new(art),
        Registry::builtin(),
        RunOptions::default(),
    )
    .unwrap();
    Some(ServingSession::start(&orch, SessionOptions::default()).unwrap())
}

fn pump(rs: &mut omni_serve::serving::ResponseStream) -> OutputDelta {
    loop {
        match rs.next_timeout(Duration::from_secs(30)) {
            StreamRecv::Delta(d) => return d,
            StreamRecv::Timeout => panic!("stream starved"),
            StreamRecv::Closed => panic!("stream closed early"),
        }
    }
}

#[test]
fn cancelled_requests_resolve_and_queues_drain() {
    let Some(session) = session() else { return };
    let wl = datasets::seedtts(11, 4, 0.0);

    // Victim A: cancelled while (most likely) still queued/prefilling.
    let mut a = session
        .submit_request(OmniRequest::from(wl.requests[0].clone()).streaming(true))
        .unwrap();
    assert!(a.cancel(), "first cancel claims the request");
    assert!(!a.cancel(), "second cancel is a no-op");

    // Victim B: cancelled mid-flight, after its first delta arrived.
    // (MiMo generates audio straight from the backbone, whose budget is
    // max_text_tokens — long enough to still be running when we cancel.)
    let mut big = wl.requests[1].clone();
    big.max_text_tokens = 512;
    big.max_audio_tokens = 512;
    let mut b = session.submit_request(OmniRequest::from(big).streaming(true)).unwrap();
    loop {
        match pump(&mut b) {
            OutputDelta::Done { .. } => panic!("victim completed before the cancel"),
            OutputDelta::StageDone { .. } => continue,
            _ => break, // first payload delta: request is mid-flight
        }
    }
    assert!(b.cancel());

    // Victim C: a deadline does the cancelling.
    let mut slow = wl.requests[2].clone();
    slow.max_text_tokens = 512;
    slow.max_audio_tokens = 512;
    let mut c = session
        .submit_request(OmniRequest::from(slow).streaming(true).deadline_s(0.01))
        .unwrap();

    // All three resolve with Done{cancelled: true}.
    for (label, rs) in [("a", &mut a), ("b", &mut b), ("c", &mut c)] {
        loop {
            match pump(rs) {
                OutputDelta::Done { cancelled, .. } => {
                    assert!(cancelled, "victim {label} must resolve as cancelled");
                    break;
                }
                _ => continue,
            }
        }
    }

    // The session fully drains (inflight hits zero without the victims
    // completing) and per-stage queues empty out.
    assert!(session.drain(Duration::from_secs(20)), "session failed to drain after cancels");
    let t0 = std::time::Instant::now();
    loop {
        let stats = session.stage_stats();
        if stats.iter().all(|s| s.queued == 0) {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(20), "stage queues never drained: {stats:?}");
        std::thread::sleep(Duration::from_millis(10));
    }

    // The pipeline still completes fresh work after the cancels.
    let mut d = session
        .submit_request(OmniRequest::from(wl.requests[3].clone()).streaming(true))
        .unwrap();
    let mut audio_chunks = 0usize;
    loop {
        match pump(&mut d) {
            OutputDelta::AudioChunk { .. } => audio_chunks += 1,
            OutputDelta::Done { cancelled, usage, .. } => {
                assert!(!cancelled);
                assert!(usage.audio_samples > 0, "completed TTS produced no audio");
                break;
            }
            _ => {}
        }
    }
    assert!(audio_chunks >= 1, "streaming request must deliver audio mid-flight");

    let summary = session.shutdown(Some("backbone")).unwrap();
    assert_eq!(summary.report.completed, 1, "only the healthy request completed");
    assert_eq!(summary.report.cancelled, 3, "all three victims recorded as cancelled");
}
