//! Overload-control acceptance tests (ISSUE 6).
//!
//! Property tests (artifact-free): the [`AdmissionController`] state
//! machine under randomized submit/reject/shed/cancel/complete
//! interleavings — shedding never touches started work, no admitted
//! request is silently dropped, the ledger's counters stay conserved —
//! and the WFQ scheduler's tenant shares stay within their weight
//! bounds under random floods.  Live-session tests (need compiled
//! artifacts; skipped in CI containers without JAX, like the other
//! session suites): the deadline-cancel vs shed race resolves every
//! stream with exactly one terminal event at randomized shed points.

use std::collections::BTreeSet;
use std::time::Duration;

use omni_serve::config::{presets, AdmissionConfig};
use omni_serve::engine::ar::token_job;
use omni_serve::engine::SamplingParams;
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::scheduler::{EngineView, FifoPolicy, StageScheduler};
use omni_serve::serving::admission::Decision;
use omni_serve::serving::{
    AdmissionController, OmniRequest, OutputDelta, ServingSession, SessionOptions, StreamRecv,
};
use omni_serve::stage_graph::transfers::{EngineCmd, Registry};
use omni_serve::trace::{datasets, Modality, Request};
use omni_serve::util::{propcheck, Prng};

fn req(id: u64, max_text: usize) -> Request {
    Request {
        id,
        arrival_s: 0.0,
        modality: Modality::Text,
        prompt_tokens: vec![1, 2, 3, 4],
        mm_frames: 0,
        seed: id,
        max_text_tokens: max_text,
        max_audio_tokens: 0,
        diffusion_steps: 0,
        ignore_eos: true,
    }
}

/// Deterministic pick from an ordered set (HashSet iteration order would
/// break seed replay).
fn pick(rng: &mut Prng, set: &BTreeSet<u64>) -> Option<u64> {
    if set.is_empty() {
        return None;
    }
    let i = rng.below(set.len() as u64) as usize;
    set.iter().nth(i).copied()
}

// ---------------------------------------------------------------------------
// Satellite: the admission state machine under randomized interleavings.
// ---------------------------------------------------------------------------

#[test]
fn admission_ledger_survives_randomized_interleavings() {
    propcheck::check("admission_interleavings", 192, |rng| {
        let horizon = 0.1 + rng.f64() * 2.0;
        let ctrl = AdmissionController::new(AdmissionConfig {
            shed_horizon_s: horizon,
            tenant_weights: vec![("acme".into(), 4.0), ("zeta".into(), 2.0)],
            ..Default::default()
        })
        .unwrap();
        let mut live: BTreeSet<u64> = BTreeSet::new();
        let mut started: BTreeSet<u64> = BTreeSet::new();
        let mut retired: BTreeSet<u64> = BTreeSet::new();
        let (mut admitted, mut rejected, mut shed_total) = (0u64, 0u64, 0u64);
        let mut next_id = 0u64;
        for _ in 0..rng.range(20, 120) {
            match rng.below(100) {
                // Submit: a fresh request with a random cost, maybe a
                // deadline, over a random lane count.
                0..=44 => {
                    next_id += 1;
                    let id = next_id;
                    let r = req(id, rng.range(1, 400));
                    let deadline = rng.bool(0.7).then(|| 0.05 + rng.f64() * 4.0);
                    match ctrl.decide(&r, deadline, 0.0, rng.range(1, 4)) {
                        Decision::Admit => {
                            admitted += 1;
                            assert!(ctrl.tracks(id), "admitted request must be tracked");
                            live.insert(id);
                        }
                        Decision::Reject { reason, retry_after_s } => {
                            rejected += 1;
                            assert!(deadline.is_some(), "deadline-less submits always admit");
                            assert!(!ctrl.tracks(id), "rejects must not enter the ledger");
                            assert!(!reason.is_empty());
                            assert!(retry_after_s > 0.0);
                        }
                    }
                }
                // A stage starts some queued request (the controller only
                // learns this lazily, through the shed sweep's closure).
                45..=59 => {
                    if let Some(id) = pick(rng, &live) {
                        started.insert(id);
                    }
                }
                // Completion or cancellation retires a live request; a
                // second resolve of anything retired must be a no-op.
                60..=79 => {
                    if let Some(id) = pick(rng, &live) {
                        ctrl.resolve(id, rng.bool(0.6).then(|| rng.f64() * 10.0));
                        assert!(!ctrl.tracks(id));
                        live.remove(&id);
                        retired.insert(id);
                    }
                    if let Some(id) = pick(rng, &retired) {
                        ctrl.resolve(id, Some(1.0));
                        assert!(!ctrl.tracks(id));
                    }
                }
                // Emergency shed sweep.
                _ => {
                    let lanes = rng.range(1, 4);
                    let victims = ctrl.shed(lanes, |id| started.contains(&id));
                    for id in &victims {
                        assert!(!started.contains(id), "shed must never touch started work");
                        assert!(live.remove(id), "shed victim {id} was not live");
                        assert!(!ctrl.tracks(*id));
                        retired.insert(*id);
                    }
                    shed_total += victims.len() as u64;
                    let st = ctrl.stats();
                    assert!(
                        st.backlog_s / lanes as f64 <= horizon + 1e-9,
                        "post-shed unstarted backlog {:.3}s over {lanes} lane(s) still \
                         exceeds the {horizon:.3}s horizon",
                        st.backlog_s
                    );
                }
            }
            // Conservation after every step: counters match the model and
            // every admitted request is live or retired, never lost.
            let st = ctrl.stats();
            assert_eq!(st.admitted, admitted);
            assert_eq!(st.rejected, rejected);
            assert_eq!(st.shed, shed_total);
            assert_eq!(
                st.admitted,
                live.len() as u64 + retired.len() as u64,
                "an admitted request went missing without resolve or shed"
            );
            assert!(st.backlog_s >= 0.0);
        }
        // Drain: resolving the survivors empties the ledger completely.
        for id in std::mem::take(&mut live) {
            ctrl.resolve(id, None);
        }
        assert_eq!(ctrl.stats().backlog_s, 0.0);
    });
}

// ---------------------------------------------------------------------------
// Satellite: WFQ tenant shares stay within weight bounds.
// ---------------------------------------------------------------------------

#[test]
fn wfq_shares_stay_within_weight_bounds_under_random_floods() {
    propcheck::check("wfq_tenant_shares", 128, |rng| {
        let pool = [1.0, 2.0, 4.0, 8.0];
        let n_tenants = rng.range(2, 4);
        let weights: Vec<f64> = (0..n_tenants).map(|_| *rng.choose(&pool)).collect();
        let k = rng.range(4, 12); // equal-cost jobs per tenant
        let mut s = StageScheduler::new(Box::new(FifoPolicy), 0);
        s.set_tenant_weights(weights.clone());
        // Random interleaved arrival order, all before any service: id
        // encodes (tenant, per-tenant sequence number).
        let mut arrivals: Vec<u32> = Vec::with_capacity(n_tenants * k);
        for t in 0..n_tenants as u32 {
            for _ in 0..k {
                arrivals.push(t);
            }
        }
        rng.shuffle(&mut arrivals);
        let mut seq = vec![0u64; n_tenants];
        for &t in &arrivals {
            let id = t as u64 * 1000 + seq[t as usize];
            seq[t as usize] += 1;
            let cmd = EngineCmd::SubmitAr(token_job(
                id,
                &[1, 2],
                SamplingParams { max_new_tokens: 1, ..Default::default() },
            ));
            s.enqueue_wfq(cmd, 0.0, 1, t);
        }
        let total = n_tenants * k;
        let view = EngineView { running: 0, max_batch: total, ..Default::default() };
        let order: Vec<u64> = s
            .ready(&view, 0.1)
            .iter()
            .map(|c| match c {
                EngineCmd::SubmitAr(j) => j.req_id,
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(order.len(), total, "WFQ reorders, never drops");
        // Per-tenant arrival order is preserved exactly.
        for t in 0..n_tenants as u64 {
            let mine: Vec<u64> = order.iter().copied().filter(|id| id / 1000 == t).collect();
            assert_eq!(mine, (0..k as u64).map(|j| t * 1000 + j).collect::<Vec<u64>>());
        }
        // SCFQ fairness: in every service prefix, any two tenants that
        // both still have queued work have received normalized service
        // (jobs / weight) within a couple of weighted quanta of each
        // other — a flood cannot run ahead of its share.
        let mut served = vec![0usize; n_tenants];
        for id in &order {
            served[(id / 1000) as usize] += 1;
            for a in 0..n_tenants {
                for b in (a + 1)..n_tenants {
                    if served[a] < k && served[b] < k {
                        let diff =
                            served[a] as f64 / weights[a] - served[b] as f64 / weights[b];
                        assert!(
                            diff.abs() <= 2.0 * (1.0 / weights[a] + 1.0 / weights[b]) + 1e-9,
                            "tenant {a} (w {}) at {} vs tenant {b} (w {}) at {}: \
                             normalized-service gap {diff:.3} in {order:?}",
                            weights[a],
                            served[a],
                            weights[b],
                            served[b]
                        );
                    }
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Regression (bugfix satellite): the deadline-cancel vs shed race must
// resolve every stream with EXACTLY one terminal event, at randomized
// shed points, with clean ledger/tombstone bookkeeping afterwards.
// Needs compiled artifacts; skipped otherwise.
// ---------------------------------------------------------------------------

fn artifacts() -> Option<std::sync::Arc<omni_serve::runtime::Artifacts>> {
    let dir = omni_serve::runtime::Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return None;
    }
    Some(std::sync::Arc::new(omni_serve::runtime::Artifacts::load(&dir).unwrap()))
}

#[test]
fn shed_and_deadline_cancel_race_yields_exactly_one_terminal_event() {
    let Some(artifacts) = artifacts() else { return };
    let mut rng = Prng::new(0x51ED);
    for trial in 0..4u64 {
        let orch = Orchestrator::new(
            presets::mimo_audio(1),
            artifacts.clone(),
            Registry::builtin(),
            RunOptions::default(),
        )
        .unwrap();
        let session = ServingSession::start(
            &orch,
            SessionOptions {
                admission: Some(AdmissionConfig {
                    // A near-zero horizon makes the shedder fire on almost
                    // any backlog, while the tight deadlines below race it
                    // (and explicit client cancels) to the same victims.
                    shed_horizon_s: 0.02,
                    ..Default::default()
                }),
                ..Default::default()
            },
        )
        .unwrap();
        let wl = datasets::seedtts(trial ^ 0x9E37, 8, 0.0);
        let mut streams = Vec::new();
        for r in &wl.requests {
            let mut r = r.clone();
            r.max_text_tokens = 96 + rng.range(0, 64);
            r.max_audio_tokens = 128;
            let mut oreq = OmniRequest::from(r).streaming(true);
            if rng.bool(0.7) {
                oreq = oreq.deadline_s(0.005 + rng.f64() * 0.1);
            }
            let mut rs = session.submit_request(oreq).unwrap();
            if rng.bool(0.2) {
                let _ = rs.cancel();
            }
            streams.push(rs);
        }
        let (mut completed, mut cancelled, mut rejected) = (0usize, 0usize, 0usize);
        for rs in &mut streams {
            let mut terminals = 0usize;
            loop {
                match rs.next_timeout(Duration::from_secs(30)) {
                    StreamRecv::Delta(OutputDelta::Done { cancelled: c, .. }) => {
                        terminals += 1;
                        if c {
                            cancelled += 1;
                        } else {
                            completed += 1;
                        }
                    }
                    StreamRecv::Delta(OutputDelta::Rejected { reason, retry_after_s, .. }) => {
                        terminals += 1;
                        rejected += 1;
                        assert!(!reason.is_empty(), "rejection must carry a reason");
                        assert!(retry_after_s > 0.0);
                    }
                    StreamRecv::Delta(_) => continue,
                    StreamRecv::Timeout => panic!("trial {trial}: stream starved"),
                    StreamRecv::Closed => break,
                }
            }
            assert_eq!(
                terminals, 1,
                "trial {trial}: a stream saw {terminals} terminal events (want exactly 1)"
            );
        }
        assert_eq!(
            completed + cancelled + rejected,
            wl.len(),
            "trial {trial}: every request reaches exactly one outcome"
        );

        // Bookkeeping after the storm: the session drains, stage queues
        // empty, and the recorder agrees with the per-stream outcomes.
        assert!(session.drain(Duration::from_secs(30)), "trial {trial}: session failed to drain");
        assert_eq!(session.inflight(), 0);
        let t0 = std::time::Instant::now();
        loop {
            let stats = session.stage_stats();
            if stats.iter().all(|s| s.queued == 0) {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(20),
                "trial {trial}: stage queues never drained: {stats:?}"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        let rep = session.live_report();
        assert_eq!(rep.offered, wl.len(), "every submit is offered load");
        assert_eq!(rep.completed, completed);
        assert_eq!(rep.cancelled, cancelled);
        assert_eq!(rep.rejected, rejected);
        let adm = session.admission_stats().unwrap();
        // The ledger may count a shed whose stream claim lost the race
        // (the request resolved through cancel/complete instead), so the
        // counters bound — rather than equal — the recorder's view.
        assert!(
            rejected as u64 <= adm.rejected + adm.shed,
            "trial {trial}: {rejected} rejected streams but the ledger saw only \
             {} rejects + {} sheds",
            adm.rejected,
            adm.shed
        );
        assert_eq!(adm.backlog_s, 0.0, "trial {trial}: drained session left ledger backlog");
        session.shutdown(Some("backbone")).unwrap();
    }
}
