//! The `OEVL` event-log wire format: seeded, ordered, checksummed —
//! the same frame idiom as [`crate::connector::wire`] (magic, version,
//! length-prefixed records, trailing FNV-1a over everything before it).
//!
//! Layout, little-endian:
//! `magic u32 | version u8 | seed u64 | lanes u32 | count u32 |`
//! per event: `tag u8 | fields` where
//! `1 = Arrive { id u64, t_us u64, cost_us u64 }`,
//! `2 = Start  { id u64, t_us u64, lane u32 }`,
//! `3 = Finish { id u64, t_us u64, lane u32 }`,
//! then `fnv1a u64` over the whole body.  Timestamps are integer
//! microseconds so encode(decode(x)) is bit-identical — no float
//! formatting anywhere near the replay contract.  Truncated or
//! corrupted frames decode to an error, never a panic.

use anyhow::{bail, Result};

const EVL_MAGIC: u32 = 0x4C56454F; // "OEVL"
const EVL_VERSION: u8 = 1;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF29CE484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// One recorded event.  Times and costs are integer microseconds of
/// virtual (or run-relative wall) time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEvent {
    /// A job entered the system with a known execution cost.
    Arrive { id: u64, t_us: u64, cost_us: u64 },
    /// The job began executing on `lane`.
    Start { id: u64, t_us: u64, lane: u32 },
    /// The job finished on `lane`.
    Finish { id: u64, t_us: u64, lane: u32 },
}

/// A seeded, ordered event recording — the unit of deterministic
/// replay.  Two logs are "identical" under plain `==`, and
/// [`EventLog::encode`] is a pure function of the contents, so
/// byte-level diffs and structural diffs agree.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EventLog {
    /// The seed that generated the run (recorded for reproduction; not
    /// consumed by replay, which re-drives from the events themselves).
    pub seed: u64,
    /// Executor lanes (replica slots) the run was driven with.
    pub lanes: u32,
    pub events: Vec<SimEvent>,
}

impl EventLog {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(21 + self.events.len() * 25 + 8);
        out.extend_from_slice(&EVL_MAGIC.to_le_bytes());
        out.push(EVL_VERSION);
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.lanes.to_le_bytes());
        out.extend_from_slice(&(self.events.len() as u32).to_le_bytes());
        for e in &self.events {
            match *e {
                SimEvent::Arrive { id, t_us, cost_us } => {
                    out.push(1);
                    out.extend_from_slice(&id.to_le_bytes());
                    out.extend_from_slice(&t_us.to_le_bytes());
                    out.extend_from_slice(&cost_us.to_le_bytes());
                }
                SimEvent::Start { id, t_us, lane } => {
                    out.push(2);
                    out.extend_from_slice(&id.to_le_bytes());
                    out.extend_from_slice(&t_us.to_le_bytes());
                    out.extend_from_slice(&lane.to_le_bytes());
                }
                SimEvent::Finish { id, t_us, lane } => {
                    out.push(3);
                    out.extend_from_slice(&id.to_le_bytes());
                    out.extend_from_slice(&t_us.to_le_bytes());
                    out.extend_from_slice(&lane.to_le_bytes());
                }
            }
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<EventLog> {
        // Checksum first: a flipped byte anywhere in the frame is
        // caught even where a structural check cannot see it.
        if bytes.len() < 8 {
            bail!("event log: frame too short ({} bytes)", bytes.len());
        }
        let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let declared = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        if fnv1a(body) != declared {
            bail!("event log: checksum mismatch (corrupt frame)");
        }
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > body.len() {
                bail!("event log: truncated at {} (+{n} > {})", *pos, body.len());
            }
            let s = &body[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let magic = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        if magic != EVL_MAGIC {
            bail!("event log: bad magic {magic:#x}");
        }
        let version = take(&mut pos, 1)?[0];
        if version != EVL_VERSION {
            bail!("event log: unsupported version {version}");
        }
        let seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let lanes = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        // Bound by the frame size before allocating (a corrupt count
        // must not OOM; each event is at least 21 bytes).
        if count > (body.len() - pos) / 21 {
            bail!("event log: {count} events cannot fit the remaining frame");
        }
        let mut events = Vec::with_capacity(count);
        for _ in 0..count {
            let tag = take(&mut pos, 1)?[0];
            let id = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
            let t_us = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
            events.push(match tag {
                1 => {
                    let cost_us = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
                    SimEvent::Arrive { id, t_us, cost_us }
                }
                2 => {
                    let lane = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
                    SimEvent::Start { id, t_us, lane }
                }
                3 => {
                    let lane = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
                    SimEvent::Finish { id, t_us, lane }
                }
                other => bail!("event log: bad event tag {other}"),
            });
        }
        if pos != body.len() {
            bail!("event log: {} trailing bytes after events", body.len() - pos);
        }
        Ok(EventLog { seed, lanes, events })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;
    use crate::util::Prng;

    fn sample(rng: &mut Prng) -> EventLog {
        let n = rng.range(0, 12);
        let events = (0..n)
            .map(|i| match rng.below(3) {
                0 => SimEvent::Arrive {
                    id: i as u64,
                    t_us: rng.below(1 << 40),
                    cost_us: rng.below(1 << 20),
                },
                1 => SimEvent::Start {
                    id: i as u64,
                    t_us: rng.below(1 << 40),
                    lane: rng.below(8) as u32,
                },
                _ => SimEvent::Finish {
                    id: i as u64,
                    t_us: rng.below(1 << 40),
                    lane: rng.below(8) as u32,
                },
            })
            .collect();
        EventLog { seed: rng.next_u64(), lanes: 1 + rng.below(7) as u32, events }
    }

    #[test]
    fn prop_log_roundtrips() {
        quick("event_log_roundtrip", |rng| {
            let log = sample(rng);
            let got = EventLog::decode(&log.encode()).unwrap();
            assert_eq!(got, log);
            // Encoding is a pure function: structural equality and
            // byte-level equality agree.
            assert_eq!(got.encode(), log.encode());
        });
    }

    #[test]
    fn log_rejects_every_truncation() {
        let mut rng = Prng::new(7);
        let bytes = sample(&mut rng).encode();
        for cut in 0..bytes.len() {
            assert!(EventLog::decode(&bytes[..cut]).is_err(), "prefix of {cut} bytes decoded");
        }
        assert!(EventLog::decode(&bytes).is_ok());
    }

    #[test]
    fn prop_log_rejects_bit_flips() {
        quick("event_log_corruption", |rng| {
            let mut bytes = sample(rng).encode();
            let i = rng.range(0, bytes.len() - 1);
            let flip = (rng.below(255) + 1) as u8;
            bytes[i] ^= flip;
            assert!(EventLog::decode(&bytes).is_err(), "flip at byte {i} slipped through");
        });
    }

    #[test]
    fn log_rejects_wrong_magic_and_version() {
        let mut rng = Prng::new(11);
        let log = sample(&mut rng);
        let mut bytes = log.encode();
        bytes[0] ^= 0xFF;
        // Recompute the checksum so only the magic check can reject it.
        let body_len = bytes.len() - 8;
        let sum = fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(EventLog::decode(&bytes).is_err());

        let mut bytes = log.encode();
        bytes[4] = 99; // version byte
        let sum = fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(EventLog::decode(&bytes).is_err());
    }
}
