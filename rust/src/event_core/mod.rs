//! Event-driven runtime core (ISSUE 10): parked-worker wakeups, a
//! unified real/sim driver, and deterministic trace replay.
//!
//! Three layers:
//!
//! * **[`WakeSet`]** ([`wake`]) — a condvar-backed wake mailbox one
//!   stage-replica thread parks on.  Every event source that used to be
//!   discovered by spin-polling (edge pushes, cancel tombstones,
//!   scale/drain commands, frontend submissions, collector sink items,
//!   edge closes) now ORs a reason bit into the mailbox and notifies,
//!   so the worker sleeps at zero CPU until there is work.  Wakes are
//!   never lost: a bit set while nobody is parked is drained by the
//!   next park.
//!
//! * **[`Driver`]** ([`driver`]) — the tick/event layering.  A loop
//!   body is a closure returning [`Tick`] (`Progress` / `Idle(deadline)`
//!   / `Exit`) and [`drive`] runs it against either clock:
//!   [`RealDriver`] (wall clock, condvar parks, real threads) for the
//!   live runtime and [`SimDriver`] (virtual clock, single-threaded,
//!   parks advance time) for `scheduler::sim` — the *same* loop body
//!   executes in both worlds, eliminating the sim/runtime drift hazard.
//!
//! * **[`EventLog`]** ([`log`]) + **[`replay`]** — deterministic replay.
//!   Events are recorded as seeded, ordered, checksummed `OEVL` wire
//!   frames (the `connector::wire` idiom) and `replay::replay` re-drives
//!   the core from a log bit-for-bit: same seed ⇒ identical log ⇒
//!   identical report, asserted by propcheck across seeds.

pub mod driver;
pub mod log;
pub mod replay;
pub mod wake;

pub use driver::{drive, Driver, RealDriver, SimDriver, Tick};
pub use log::{EventLog, SimEvent};
pub use replay::{record, record_polling, replay, ReplayReport};
pub use wake::{
    WakeCounters, WakeSet, WAKE_CANCEL, WAKE_CLOSE, WAKE_CTL, WAKE_EDGE, WAKE_FRONT, WAKE_SINK,
    WAKE_STEP, WAKE_TIMER,
};
