//! Condvar-backed wake mailbox ([`WakeSet`]): the parking primitive one
//! worker thread blocks on instead of spin-polling its inputs.
//!
//! A `WakeSet` is a 64-bit pending mask guarded by a mutex plus a
//! condvar.  Event sources OR a *reason bit* into the mask and notify;
//! a parked worker drains the whole mask on wake.  The protocol is
//! lost-wakeup safe by construction: [`WakeSet::wake`] records the bit
//! whether or not anybody is parked, and [`WakeSet::park`] checks the
//! mask *before* sleeping — a wake that races a park is observed either
//! by the pre-sleep check or by the notify.
//!
//! The set also keeps the idle-observability counters the run report
//! surfaces per stage: `wakeups` (parks that returned with work),
//! `spurious_wakeups` (timeouts and empty condvar wakes), and the total
//! nanoseconds spent parked.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// An upstream edge delivered an item.
pub const WAKE_EDGE: u64 = 1 << 0;
/// An engine step completed (used by sim harnesses; the live stage loop
/// steps its engine on the same thread, so no cross-thread wake).
pub const WAKE_STEP: u64 = 1 << 1;
/// A cancel tombstone was marked (sweep queued/in-flight work).
pub const WAKE_CANCEL: u64 = 1 << 2;
/// A control command: stop, retire, scale, or drain.
pub const WAKE_CTL: u64 = 1 << 3;
/// A deadline timer fired (park timed out at its requested deadline).
pub const WAKE_TIMER: u64 = 1 << 4;
/// The frontend submitted a request to this entry replica.
pub const WAKE_FRONT: u64 = 1 << 5;
/// An exit-stage item landed on the collector sink.
pub const WAKE_SINK: u64 = 1 << 6;
/// An edge endpoint closed (producer dropped or consumer removed) —
/// the parked peer must re-poll so `TryRecv::Closed` drain-and-flush
/// paths run instead of hanging.
pub const WAKE_CLOSE: u64 = 1 << 7;

/// Point-in-time snapshot of a [`WakeSet`]'s idle-observability
/// counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct WakeCounters {
    /// Parks that returned with at least one pending reason bit.
    pub wakeups: u64,
    /// Parks that returned empty (deadline/backstop timeout or an
    /// OS-level spurious condvar wake).
    pub spurious_wakeups: u64,
    /// Total time spent parked, in nanoseconds.
    pub idle_ns: u64,
}

/// Per-worker wake mailbox (see module docs).
#[derive(Debug, Default)]
pub struct WakeSet {
    pending: Mutex<u64>,
    cv: Condvar,
    wakeups: AtomicU64,
    spurious: AtomicU64,
    idle_ns: AtomicU64,
}

impl WakeSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// OR `mask` into the pending set and notify any parked worker.
    /// Safe to call from any thread, parked worker or not.
    pub fn wake(&self, mask: u64) {
        let mut p = self.pending.lock().unwrap();
        *p |= mask;
        // Notify under the lock so a parker between its pre-sleep check
        // and its wait cannot miss this (the mutex serializes us behind
        // either the check or the wait).
        self.cv.notify_all();
    }

    /// Block until a wake arrives or `timeout` elapses.  Drains and
    /// returns the pending mask; `0` means the park timed out (or the
    /// condvar woke spuriously) with nothing pending.
    pub fn park(&self, timeout: Duration) -> u64 {
        let t0 = Instant::now();
        let mut p = self.pending.lock().unwrap();
        if *p == 0 {
            let (guard, _timed_out) = self.cv.wait_timeout(p, timeout).unwrap();
            p = guard;
        }
        let mask = std::mem::replace(&mut *p, 0);
        drop(p);
        self.idle_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if mask != 0 {
            self.wakeups.fetch_add(1, Ordering::Relaxed);
        } else {
            self.spurious.fetch_add(1, Ordering::Relaxed);
        }
        mask
    }

    /// Non-blocking drain (the virtual-clock driver's "park": nothing
    /// ever sleeps in a single-threaded sim).  Counts a wakeup when the
    /// mask was non-empty, nothing otherwise — a timer advance is not a
    /// spurious wake.
    pub fn try_drain(&self) -> u64 {
        let mask = std::mem::replace(&mut *self.pending.lock().unwrap(), 0);
        if mask != 0 {
            self.wakeups.fetch_add(1, Ordering::Relaxed);
        }
        mask
    }

    pub fn counters(&self) -> WakeCounters {
        WakeCounters {
            wakeups: self.wakeups.load(Ordering::Relaxed),
            spurious_wakeups: self.spurious.load(Ordering::Relaxed),
            idle_ns: self.idle_ns.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn wake_before_park_is_not_lost() {
        let w = WakeSet::new();
        w.wake(WAKE_EDGE | WAKE_CANCEL);
        // The bits were recorded with nobody parked; the next park
        // returns them without sleeping.
        let mask = w.park(Duration::from_secs(5));
        assert_eq!(mask, WAKE_EDGE | WAKE_CANCEL);
        assert_eq!(w.counters().wakeups, 1);
    }

    #[test]
    fn park_times_out_empty_and_counts_spurious() {
        let w = WakeSet::new();
        let mask = w.park(Duration::from_millis(1));
        assert_eq!(mask, 0);
        let c = w.counters();
        assert_eq!(c.spurious_wakeups, 1);
        assert!(c.idle_ns > 0, "parked time must be accounted");
    }

    #[test]
    fn cross_thread_wake_unparks_promptly() {
        let w = Arc::new(WakeSet::new());
        let w2 = w.clone();
        let t = std::thread::spawn(move || w2.park(Duration::from_secs(30)));
        // Let the worker reach its park (any interleaving is correct —
        // the bit is sticky — this just exercises the condvar path too).
        std::thread::sleep(Duration::from_millis(20));
        w.wake(WAKE_CTL);
        let mask = t.join().unwrap();
        assert_eq!(mask, WAKE_CTL, "parked worker must see the control wake");
    }

    #[test]
    fn try_drain_clears_and_counts() {
        let w = WakeSet::new();
        assert_eq!(w.try_drain(), 0);
        w.wake(WAKE_TIMER);
        w.wake(WAKE_SINK);
        assert_eq!(w.try_drain(), WAKE_TIMER | WAKE_SINK);
        assert_eq!(w.try_drain(), 0, "drain must clear the mask");
        assert_eq!(w.counters().wakeups, 1);
    }
}
