//! The [`Driver`] trait and [`drive`] loop: one stage-loop body, two
//! clocks.
//!
//! A loop body is a closure over `&mut dyn Driver` returning a
//! [`Tick`]:
//!
//! * [`Tick::Progress`] — work was done; run the body again at once.
//! * [`Tick::Idle`]`(deadline)` — nothing to do; park on the worker's
//!   [`WakeSet`] until a wake or the *absolute* run-relative deadline
//!   (seconds).  `None` parks indefinitely (bounded by the real
//!   driver's liveness backstop).
//! * [`Tick::Exit`] — the loop is over.
//!
//! [`RealDriver`] reads the shared [`RunClock`] and really blocks;
//! [`SimDriver`] owns a virtual `f64` clock, never blocks, and treats a
//! deadline park as "advance time to the deadline" — so
//! `scheduler::sim` and the live runtime execute the *same* body with
//! identical semantics, which is the whole point: the two code paths
//! cannot drift apart because there is only one.

use anyhow::Result;

use crate::orchestrator::RunClock;

use super::wake::{WakeSet, WAKE_TIMER};

/// What one pass of a stage-loop body did (see module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Tick {
    /// Work happened; tick again immediately.
    Progress,
    /// Nothing to do; park until a wake or the absolute deadline
    /// (run-relative seconds).  `None` = no deadline.
    Idle(Option<f64>),
    /// The loop terminates.
    Exit,
}

/// Clock + parking behaviour a [`drive`] loop runs against.
pub trait Driver {
    /// Current run-relative time in seconds.
    fn now(&self) -> f64;

    /// Account `dt` seconds of work.  The virtual clock advances by
    /// exactly `dt`; the wall clock ignores it (real work already
    /// consumed the time).
    fn advance(&mut self, dt: f64);

    /// Park until a wake arrives or the absolute `deadline` passes.
    /// Returns the drained wake mask (`0` = timeout/spurious on the
    /// real driver; the sim driver reports [`WAKE_TIMER`] for a
    /// deadline advance).
    fn park(&mut self, wake: &WakeSet, deadline: Option<f64>) -> u64;
}

/// Run `tick` to completion under `drv`, parking on `wake` whenever the
/// body reports idle.  The body is fallible so live stage loops can
/// propagate engine/edge errors with `?`; sim bodies just wrap their
/// tick in `Ok`.
pub fn drive<F>(drv: &mut dyn Driver, wake: &WakeSet, mut tick: F) -> Result<()>
where
    F: FnMut(&mut dyn Driver) -> Result<Tick>,
{
    loop {
        match tick(drv)? {
            Tick::Progress => {}
            Tick::Idle(deadline) => {
                drv.park(wake, deadline);
            }
            Tick::Exit => return Ok(()),
        }
    }
}

/// How long an indefinite (`Idle(None)`) real park may sleep before
/// re-ticking anyway.  Every event source wakes its worker explicitly,
/// so this is a liveness backstop, not a polling interval: it bounds
/// the damage of any wake hook a future change forgets, and it is the
/// worst-case latency for conditions no hook covers by design (e.g. a
/// peer process dying without closing a channel).  Counted as a
/// spurious wakeup, so a hot backstop is visible in the stats.
pub const REAL_PARK_BACKSTOP: std::time::Duration = std::time::Duration::from_millis(25);

/// Wall-clock driver for live stage threads: `now` reads the shared
/// [`RunClock`], `advance` is a no-op, `park` really blocks on the
/// worker's [`WakeSet`].
#[derive(Debug, Clone)]
pub struct RealDriver {
    clock: RunClock,
}

impl RealDriver {
    pub fn new(clock: RunClock) -> Self {
        Self { clock }
    }
}

impl Driver for RealDriver {
    fn now(&self) -> f64 {
        self.clock.now()
    }

    fn advance(&mut self, _dt: f64) {}

    fn park(&mut self, wake: &WakeSet, deadline: Option<f64>) -> u64 {
        let timeout = match deadline {
            Some(d) => {
                let dt = d - self.clock.now();
                if dt <= 0.0 {
                    // Already past the deadline: report the timer
                    // without sleeping (the body re-checks time).
                    return WAKE_TIMER;
                }
                std::time::Duration::from_secs_f64(dt)
            }
            None => REAL_PARK_BACKSTOP,
        };
        wake.park(timeout)
    }
}

/// Virtual-clock driver for single-threaded simulation and replay:
/// `advance` moves time forward by exactly `dt`, and a deadline park
/// jumps the clock to the deadline — no thread ever sleeps.  A park
/// with neither a deadline nor a pending wake is a stalled simulation
/// (nothing can ever make progress again) and panics loudly rather
/// than spinning forever.
#[derive(Debug, Clone)]
pub struct SimDriver {
    now: f64,
}

impl SimDriver {
    pub fn new() -> Self {
        Self { now: 0.0 }
    }
}

impl Default for SimDriver {
    fn default() -> Self {
        Self::new()
    }
}

impl Driver for SimDriver {
    fn now(&self) -> f64 {
        self.now
    }

    fn advance(&mut self, dt: f64) {
        self.now += dt;
    }

    fn park(&mut self, wake: &WakeSet, deadline: Option<f64>) -> u64 {
        let pending = wake.try_drain();
        if pending != 0 {
            // An event was injected (sim harness): handle it at the
            // current virtual time; the deadline no longer applies.
            return pending;
        }
        match deadline {
            Some(d) => {
                if d > self.now {
                    self.now = d;
                }
                WAKE_TIMER
            }
            None => panic!(
                "SimDriver stalled at t={}: parked with no deadline and no pending wake",
                self.now
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::event_core::wake::{WAKE_EDGE, WAKE_STEP};

    #[test]
    fn sim_driver_park_advances_to_the_deadline_exactly() {
        let wake = WakeSet::new();
        let mut drv = SimDriver::new();
        drv.advance(1.25);
        assert_eq!(drv.now(), 1.25);
        assert_eq!(drv.park(&wake, Some(3.5)), WAKE_TIMER);
        assert_eq!(drv.now(), 3.5, "deadline park is an exact assignment");
        // A deadline in the past does not move time backwards.
        assert_eq!(drv.park(&wake, Some(2.0)), WAKE_TIMER);
        assert_eq!(drv.now(), 3.5);
    }

    #[test]
    fn sim_driver_pending_wake_preempts_the_deadline() {
        let wake = WakeSet::new();
        let mut drv = SimDriver::new();
        wake.wake(WAKE_STEP);
        assert_eq!(drv.park(&wake, Some(9.0)), WAKE_STEP);
        assert_eq!(drv.now(), 0.0, "an injected event is handled at the current time");
    }

    #[test]
    #[should_panic(expected = "SimDriver stalled")]
    fn sim_driver_panics_on_a_stalled_simulation() {
        let wake = WakeSet::new();
        let mut drv = SimDriver::new();
        drv.park(&wake, None);
    }

    #[test]
    fn drive_runs_the_same_body_under_both_drivers() {
        // One body, two worlds: count three work items separated by
        // idle-to-deadline gaps.  Under the sim driver this is instant
        // and lands at exactly t=0.3; under the real driver the parks
        // really sleep (timer wakes, nothing else is running).
        fn body(n: &mut u32) -> impl FnMut(&mut dyn Driver) -> Result<Tick> + '_ {
            move |drv| {
                if *n >= 3 {
                    return Ok(Tick::Exit);
                }
                *n += 1;
                Ok(Tick::Idle(Some(drv.now() + 0.1)))
            }
        }
        let wake = WakeSet::new();
        let mut sim = SimDriver::new();
        let mut n = 0;
        drive(&mut sim, &wake, body(&mut n)).unwrap();
        assert_eq!(n, 3);
        assert!((sim.now() - 0.3).abs() < 1e-12);

        let wake = WakeSet::new();
        let mut real = RealDriver::new(RunClock::new());
        let mut n = 0;
        let t0 = std::time::Instant::now();
        drive(&mut real, &wake, body(&mut n)).unwrap();
        assert_eq!(n, 3);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(250));
    }

    #[test]
    fn real_driver_deadline_park_wakes_early_on_an_event() {
        let wake = std::sync::Arc::new(WakeSet::new());
        let clock = RunClock::new();
        let w2 = wake.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            w2.wake(WAKE_EDGE);
        });
        let mut drv = RealDriver::new(clock);
        let t0 = std::time::Instant::now();
        let mask = drv.park(&wake, Some(drv.now() + 30.0));
        assert_eq!(mask, WAKE_EDGE);
        assert!(t0.elapsed() < std::time::Duration::from_secs(10), "woke well before deadline");
        t.join().unwrap();
    }

    #[test]
    fn real_driver_past_deadline_returns_without_sleeping() {
        let mut drv = RealDriver::new(RunClock::new());
        let wake = WakeSet::new();
        let t0 = std::time::Instant::now();
        assert_eq!(drv.park(&wake, Some(0.0)), WAKE_TIMER);
        assert!(t0.elapsed() < std::time::Duration::from_secs(1));
    }

    #[test]
    fn drive_propagates_a_body_error() {
        let wake = WakeSet::new();
        let mut drv = SimDriver::new();
        let err = drive(&mut drv, &wake, |_| anyhow::bail!("engine exploded")).unwrap_err();
        assert!(err.to_string().contains("engine exploded"));
    }
}
