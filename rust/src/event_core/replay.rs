//! Deterministic record/replay over the event core.
//!
//! [`record`] drives a seeded bursty trace through a FCFS lane executor
//! whose loop body runs under [`SimDriver`] — the same
//! [`drive`]/[`Tick`] body shape as the live stage loop — and records
//! every [`SimEvent`] into an [`EventLog`].  [`replay`] re-drives the
//! executor from a log's `Arrive` events and verifies the regenerated
//! stream matches the recording **bit-for-bit**; any divergence is an
//! error, not a warning.
//!
//! Everything is integer microseconds carried in `f64` (exact up to
//! 2^53), so the replay contract has no float-rounding escape hatch:
//! same seed ⇒ identical log ⇒ identical report, across every seed,
//! asserted by propcheck below and gated in CI.
//!
//! [`record_polling`] is the bench baseline: the identical executor,
//! except every dequeue pays the bounded-backoff sleep the old
//! spin-polling loops paid (uniform in `[50µs, 2ms]`, the retired
//! `util::Backoff` bounds).  Since each start is strictly delayed and
//! lane frees only move later, every queue wait is strictly larger —
//! the event-driven core wins on mean JCT and p95 queue-wait for
//! *every* seed, which is what the `bench --trace bursty-mixed
//! --event-core` gate asserts.

use anyhow::{ensure, Result};

use crate::trace::datasets;
use crate::util::Prng;

use super::driver::{drive, Driver, SimDriver, Tick};
use super::log::{EventLog, SimEvent};
use super::wake::WakeSet;

/// Fixed dispatch overhead charged per request, microseconds.
pub const BASE_COST_US: u64 = 2_000;
/// Marginal cost per input/output token, microseconds.
pub const PER_TOKEN_US: u64 = 50;

/// Price a request's execution cost from its token budgets (shared by
/// the sim recorder and the serving-session `replay_record` tee, so a
/// captured serving trace replays against the same cost model).
pub fn price_request_us(input_tokens: usize, text_tokens: usize, audio_tokens: usize) -> u64 {
    BASE_COST_US + PER_TOKEN_US * (input_tokens + text_tokens + audio_tokens) as u64
}

#[derive(Debug, Clone, Copy)]
struct Job {
    id: u64,
    arrival_us: u64,
    cost_us: u64,
}

/// What a recorded or replayed run measured.  All fields are integer
/// microseconds, so `==` is the bit-identical comparison the replay
/// acceptance gate diffs.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ReplayReport {
    pub lanes: u32,
    pub completed: u64,
    /// Per-job queue wait (start − arrival), dispatch order.
    pub waits_us: Vec<u64>,
    /// Per-job completion time (finish − arrival), dispatch order.
    pub jcts_us: Vec<u64>,
    pub makespan_us: u64,
}

impl ReplayReport {
    pub fn mean_jct_s(&self) -> f64 {
        if self.jcts_us.is_empty() {
            return 0.0;
        }
        self.jcts_us.iter().map(|&x| x as f64).sum::<f64>() / self.jcts_us.len() as f64 / 1e6
    }

    pub fn p95_wait_s(&self) -> f64 {
        if self.waits_us.is_empty() {
            return 0.0;
        }
        let mut sorted = self.waits_us.clone();
        sorted.sort_unstable();
        // Nearest-rank, the util::stats::Summary::percentile convention,
        // in pure integer math so equal reports give equal percentiles.
        let rank = (95 * (sorted.len() - 1) + 50) / 100;
        sorted[rank] as f64 / 1e6
    }

    /// Canonical one-line rendering — what `omni-serve replay` prints
    /// and what the CI record-then-replay step diffs.  Built from the
    /// integer fields only, so equal reports always print equal lines.
    pub fn line(&self) -> String {
        format!(
            "replay report: lanes={} completed={} mean_jct={:.6}s p95_wait={:.6}s makespan={:.6}s",
            self.lanes,
            self.completed,
            self.mean_jct_s(),
            self.p95_wait_s(),
            self.makespan_us as f64 / 1e6,
        )
    }
}

/// FCFS lane executor: jobs start in list order, each on the
/// earliest-free lane (lowest index on ties), paying `dequeue_delay_us`
/// extra microseconds between "lane available" and "work starts" (0 for
/// the event-driven core; the polling baseline's backoff sleep
/// otherwise).  The loop body runs under [`drive`] + [`SimDriver`] —
/// park-to-arrival and park-to-lane-free are `Tick::Idle` deadlines,
/// exactly like a live worker parked on its [`WakeSet`].
fn execute(
    jobs: &[Job],
    lanes: u32,
    mut dequeue_delay_us: impl FnMut() -> u64,
) -> (Vec<SimEvent>, ReplayReport) {
    assert!(lanes >= 1, "executor needs at least one lane");
    let mut events: Vec<SimEvent> = jobs
        .iter()
        .map(|j| SimEvent::Arrive { id: j.id, t_us: j.arrival_us, cost_us: j.cost_us })
        .collect();
    let mut lane_free = vec![0f64; lanes as usize];
    let mut waits = Vec::with_capacity(jobs.len());
    let mut jcts = Vec::with_capacity(jobs.len());
    let mut next = 0usize;
    let wake = WakeSet::new();
    let mut drv = SimDriver::new();
    drive(&mut drv, &wake, |drv| {
        if next >= jobs.len() {
            return Ok(Tick::Exit);
        }
        let j = jobs[next];
        let arrival = j.arrival_us as f64;
        if drv.now() < arrival {
            // Nothing to do until the next job arrives: park to its
            // arrival (a live worker would park on WAKE_FRONT here).
            return Ok(Tick::Idle(Some(arrival)));
        }
        let (lane, free) = lane_free
            .iter()
            .copied()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
            .expect("at least one lane");
        if free > drv.now() {
            // All lanes busy: park until the earliest one frees (a live
            // worker would park on WAKE_STEP).
            return Ok(Tick::Idle(Some(free)));
        }
        let start = drv.now() + dequeue_delay_us() as f64;
        events.push(SimEvent::Start { id: j.id, t_us: start as u64, lane: lane as u32 });
        let finish = start + j.cost_us as f64;
        lane_free[lane] = finish;
        waits.push((start - arrival) as u64);
        jcts.push((finish - arrival) as u64);
        events.push(SimEvent::Finish { id: j.id, t_us: finish as u64, lane: lane as u32 });
        next += 1;
        Ok(Tick::Progress)
    })
    .expect("replay executor body is infallible");
    let makespan_us = lane_free.iter().copied().fold(0f64, f64::max) as u64;
    let report = ReplayReport {
        lanes,
        completed: jobs.len() as u64,
        waits_us: waits,
        jcts_us: jcts,
        makespan_us,
    };
    (events, report)
}

fn jobs_from_trace(seed: u64, n: usize) -> Vec<Job> {
    let wl = datasets::bursty_mixed(seed, n, 2.0);
    let mut jobs: Vec<Job> = wl
        .requests
        .iter()
        .map(|r| Job {
            id: r.id,
            arrival_us: (r.arrival_s * 1e6).round() as u64,
            cost_us: price_request_us(
                r.total_input_tokens(),
                r.max_text_tokens,
                r.max_audio_tokens,
            ),
        })
        .collect();
    jobs.sort_by(|a, b| (a.arrival_us, a.id).cmp(&(b.arrival_us, b.id)));
    jobs
}

/// Record a seeded bursty trace driven by the event core: returns the
/// full [`EventLog`] and the run's [`ReplayReport`].
pub fn record(seed: u64, n: usize, lanes: u32) -> (EventLog, ReplayReport) {
    let jobs = jobs_from_trace(seed, n);
    let (events, report) = execute(&jobs, lanes, || 0);
    (EventLog { seed, lanes, events }, report)
}

/// The polling baseline: the identical trace and executor, except every
/// dequeue pays the bounded-backoff sleep the retired spin loops paid
/// (uniform in `[50µs, 2ms]` — `util::Backoff`'s MIN/MAX bounds).
pub fn record_polling(seed: u64, n: usize, lanes: u32) -> ReplayReport {
    let jobs = jobs_from_trace(seed, n);
    let mut rng = Prng::new(seed ^ 0xB0FF);
    let (_, report) = execute(&jobs, lanes, || 50 + rng.below(1951));
    report
}

/// Re-drive the executor from a log's `Arrive` events and verify the
/// regenerated event stream matches the recording bit-for-bit.  A log
/// with only `Arrive` events (a serving-session capture, which records
/// arrivals but executes on real engines) skips the stream comparison
/// and just reports the deterministic re-execution.
pub fn replay(log: &EventLog) -> Result<ReplayReport> {
    ensure!(log.lanes >= 1, "event log has no lanes");
    let jobs: Vec<Job> = log
        .events
        .iter()
        .filter_map(|e| match *e {
            SimEvent::Arrive { id, t_us, cost_us } => {
                Some(Job { id, arrival_us: t_us, cost_us })
            }
            _ => None,
        })
        .collect();
    ensure!(!jobs.is_empty(), "event log has no arrivals");
    let (events, report) = execute(&jobs, log.lanes, || 0);
    let recorded_execution = log.events.iter().any(|e| !matches!(e, SimEvent::Arrive { .. }));
    if recorded_execution {
        ensure!(
            events == log.events,
            "replay diverged from the recorded event stream \
             ({} regenerated vs {} recorded events)",
            events.len(),
            log.events.len()
        );
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;

    #[test]
    fn prop_same_seed_identical_log_and_report() {
        // The ISSUE's determinism propcheck: a bursty trace recorded
        // twice from the same seed produces identical EventLogs (down
        // to the encoded bytes) and identical reports.
        quick("replay_same_seed_same_log", |rng| {
            let seed = rng.next_u64();
            let (log_a, rep_a) = record(seed, 32, 3);
            let (log_b, rep_b) = record(seed, 32, 3);
            assert_eq!(log_a, log_b);
            assert_eq!(log_a.encode(), log_b.encode());
            assert_eq!(rep_a, rep_b);
        });
    }

    #[test]
    fn recorded_trace_replays_bit_identical_across_32_seeds() {
        for seed in 0..32u64 {
            let (log, report) = record(seed, 64, 3);
            // Through the wire format and back: still the same log.
            let decoded = EventLog::decode(&log.encode()).unwrap();
            assert_eq!(decoded, log, "seed {seed}: wire roundtrip changed the log");
            // Replay regenerates the exact event stream and report.
            let replayed = replay(&decoded).unwrap();
            assert_eq!(replayed, report, "seed {seed}: replay report diverged");
            assert_eq!(replayed.line(), report.line(), "seed {seed}: printed lines differ");
        }
    }

    #[test]
    fn replay_rejects_a_tampered_log() {
        let (mut log, _) = record(3, 16, 2);
        // Shift one Start event by a microsecond: the regenerated
        // stream can no longer match.
        let pos = log.events.iter().position(|e| matches!(e, SimEvent::Start { .. })).unwrap();
        if let SimEvent::Start { id, t_us, lane } = log.events[pos] {
            log.events[pos] = SimEvent::Start { id, t_us: t_us + 1, lane };
        }
        assert!(replay(&log).is_err(), "a tampered log must not replay clean");
    }

    #[test]
    fn replay_accepts_an_arrivals_only_capture() {
        let (log, report) = record(9, 24, 2);
        let arrivals_only = EventLog {
            seed: log.seed,
            lanes: log.lanes,
            events: log
                .events
                .iter()
                .copied()
                .filter(|e| matches!(e, SimEvent::Arrive { .. }))
                .collect(),
        };
        // A serving capture has no Start/Finish events; replay still
        // re-executes deterministically and reports the same numbers.
        let replayed = replay(&arrivals_only).unwrap();
        assert_eq!(replayed, report);
    }

    #[test]
    fn event_core_beats_the_polling_baseline_on_every_seed() {
        // The structural bench-gate property: the polling executor adds
        // a strictly positive dequeue delay per job, so every queue
        // wait is strictly larger — mean JCT no worse and p95 wait
        // strictly better for the event-driven core, on all 32 seeds.
        for seed in 0..32u64 {
            let (_, ev) = record(seed, 64, 3);
            let poll = record_polling(seed, 64, 3);
            assert!(
                ev.mean_jct_s() <= poll.mean_jct_s(),
                "seed {seed}: event-core mean JCT {} worse than polling {}",
                ev.mean_jct_s(),
                poll.mean_jct_s()
            );
            assert!(
                ev.p95_wait_s() < poll.p95_wait_s(),
                "seed {seed}: event-core p95 wait {} not better than polling {}",
                ev.p95_wait_s(),
                poll.p95_wait_s()
            );
        }
    }

    #[test]
    fn fcfs_executor_is_exact_on_a_tiny_hand_checked_case() {
        // Two lanes, three jobs: j0 and j1 run immediately; j2 waits
        // for the earlier finish (lane 0 at t=1000).
        let jobs = [
            Job { id: 0, arrival_us: 0, cost_us: 1000 },
            Job { id: 1, arrival_us: 0, cost_us: 3000 },
            Job { id: 2, arrival_us: 500, cost_us: 100 },
        ];
        let (events, rep) = execute(&jobs, 2, || 0);
        assert_eq!(rep.waits_us, vec![0, 0, 500]);
        assert_eq!(rep.jcts_us, vec![1000, 3000, 600]);
        assert_eq!(rep.makespan_us, 3000);
        assert_eq!(
            &events[3..],
            &[
                SimEvent::Start { id: 0, t_us: 0, lane: 0 },
                SimEvent::Finish { id: 0, t_us: 1000, lane: 0 },
                SimEvent::Start { id: 1, t_us: 0, lane: 1 },
                SimEvent::Finish { id: 1, t_us: 3000, lane: 1 },
                SimEvent::Start { id: 2, t_us: 1000, lane: 0 },
                SimEvent::Finish { id: 2, t_us: 1100, lane: 0 },
            ]
        );
    }
}
