//! Built-in pipeline presets mirroring the paper's evaluated models
//! (§4.1), with the paper's 2-device placement for the Omni pipelines:
//! Thinker tensor-parallel across both accelerators, Talker on device 1,
//! Vocoder on device 0.
//!
//! Batch caps are tuned for the CPU-PJRT testbed (see EXPERIMENTS.md
//! §Perf / ablation `batching`): XLA's CPU backend already uses all cores
//! within a single call, so intra-stage batching saturates at ~2; the
//! disaggregation win on this testbed comes from inter-stage overlap,
//! streaming, and fused multi-step decode.  On real accelerators raise
//! `max_batch` to the compiled bucket limit (8).

use super::{
    ClusterConfig, ConnectorKind, DiffusionParams, EdgeConfig, NodeSpec, PipelineConfig,
    PlacementPolicy, RoutingKind, SchedParams, ShareConfig, StageConfig, StageKind, StageRole,
    TransportConfig,
};

fn edge(from: &str, to: &str, transfer: &str) -> EdgeConfig {
    EdgeConfig {
        from: from.into(),
        to: to.into(),
        transfer: transfer.into(),
        connector: ConnectorKind::Inline,
        routing: RoutingKind::Auto,
    }
}

/// Qwen2.5-Omni sim: Thinker(7B-sim) -> Talker -> DiT Vocoder.
pub fn qwen25_omni() -> PipelineConfig {
    PipelineConfig {
        name: "qwen2.5-omni-sim".into(),
        stages: vec![
            StageConfig::new("thinker", "thinker25", StageKind::Ar)
                .on_devices(&[0, 1])
                .with_batch(2),
            StageConfig::new("talker", "talker25", StageKind::Ar)
                .on_devices(&[1])
                .with_batch(2)
                .with_multi_step(crate::engine::ar::SCAN_STEPS),
            StageConfig::new("vocoder", "voc_dit25", StageKind::Dit)
                .on_devices(&[0])
                .with_batch(2)
                .with_diffusion(DiffusionParams {
                    steps: 10,
                    cfg_scale: 1.0,
                    stepcache_threshold: 0.15,
                }),
        ],
        edges: vec![
            edge("thinker", "talker", "thinker2talker"),
            edge("talker", "vocoder", "talker2vocoder"),
        ],
        n_devices: 2,
        device_bytes: crate::device::DEFAULT_DEVICE_BYTES,
        autoscaler: None,
        admission: None,
        cache: None,
        transport: TransportConfig::default(),
        cluster: None,
        share: None,
        runtime: None,
    }
}

/// Qwen3-Omni sim: larger Thinker (30B-sim), CNN vocoder.
pub fn qwen3_omni() -> PipelineConfig {
    PipelineConfig {
        name: "qwen3-omni-sim".into(),
        stages: vec![
            StageConfig::new("thinker", "thinker3", StageKind::Ar)
                .on_devices(&[0, 1])
                .with_batch(2),
            StageConfig::new("talker", "talker3", StageKind::Ar)
                .on_devices(&[1])
                .with_batch(2)
                // Fused multi-step decode on the longest stage (§Perf):
                // amortizes dispatch + KV round-trips over 8 tokens.
                .with_multi_step(crate::engine::ar::SCAN_STEPS),
            StageConfig::new("vocoder", "voc_cnn3", StageKind::CnnVocoder)
                .on_devices(&[0])
                .with_batch(4),
        ],
        edges: vec![
            edge("thinker", "talker", "thinker2talker"),
            edge("talker", "vocoder", "talker2vocoder"),
        ],
        n_devices: 2,
        device_bytes: crate::device::DEFAULT_DEVICE_BYTES,
        autoscaler: None,
        admission: None,
        cache: None,
        transport: TransportConfig::default(),
        cluster: None,
        share: None,
        runtime: None,
    }
}

/// Qwen3-Omni with the Talker stage replicated 2x (paper §3.3 "flexible
/// GPU allocation": the Talker dominates end-to-end time on speech
/// traces, so it gets two engine replicas; the Thinker→Talker edge uses
/// cache-aware routing — affinity-grade stickiness so each request's
/// streamed conditioning and KV state stay on one replica, with the
/// first pick steered to the replica whose prefix cache already covers
/// the prompt).  The device budget is doubled so the extra replica's
/// weights pass memory admission on the scaled testbed.
pub fn qwen3_omni_replicated() -> PipelineConfig {
    let mut p = qwen3_omni();
    p.name = "qwen3-omni-sim-rep2".into();
    let talker = p.stages.iter_mut().find(|s| s.name == "talker").unwrap();
    talker.replicas = 2;
    p.edges[0].routing = RoutingKind::CacheAware;
    p.device_bytes = 2 * crate::device::DEFAULT_DEVICE_BYTES;
    p
}

/// Qwen3-Omni with full E/P/D disaggregation (paper §3.4): the
/// multimodal encoder, the Thinker's prefill phase, and the Thinker's
/// decode phase each run as their OWN stage, so the compute-bound
/// prefill pool and the latency-critical decode pool scale
/// independently.  Prefill streams each finished sequence's KV state
/// downstream as a [`crate::kv_transfer::KvHandoff`] over the
/// `kv2decode` edge; the decode stage imports it (deduplicating
/// already-resident prefix blocks) and continuous-batches decode steps.
/// The decode stage's `queue_depth` bounds its admission queue, so a
/// backed-up decode pool backpressures handoffs into the connector
/// instead of hoarding them.  The device budget is doubled because the
/// Thinker weights are resident in both pools.
pub fn qwen3_omni_epd() -> PipelineConfig {
    let mut p = qwen3_omni();
    p.name = "qwen3-omni-sim-epd".into();
    p.stages.retain(|s| s.name != "thinker");
    let mut stages = vec![
        StageConfig::new("encoder", "enc3", StageKind::Encoder)
            .on_devices(&[0])
            .with_batch(4),
        StageConfig::new("prefill", "thinker3", StageKind::Ar)
            .with_role(StageRole::Prefill)
            .on_devices(&[0, 1])
            .with_batch(2),
        StageConfig::new("decode", "thinker3", StageKind::Ar)
            .with_role(StageRole::Decode)
            .on_devices(&[0, 1])
            .with_batch(2)
            .with_sched(SchedParams { queue_depth: 8, ..Default::default() }),
    ];
    stages.append(&mut p.stages); // talker, vocoder keep their config
    p.stages = stages;
    p.edges = vec![
        edge("encoder", "prefill", "embeds2prompt"),
        edge("prefill", "decode", "kv2decode"),
        edge("decode", "talker", "thinker2talker"),
        edge("talker", "vocoder", "talker2vocoder"),
    ];
    p.device_bytes = 2 * crate::device::DEFAULT_DEVICE_BYTES;
    p
}

/// Qwen3-Omni E/P/D spread over a 3-node cluster (paper §3.4 at
/// deployment scale): every stage replicated 2x, placed by the
/// transfer-cost-aware engine so the heavy prefill→decode KV edge stays
/// node-local while the light decode→talker / talker→vocoder streams may
/// cross the interconnect.  The link numbers model a commodity 10 Gbit/s
/// datacenter network.
pub fn qwen3_omni_cluster() -> PipelineConfig {
    let mut p = qwen3_omni_epd();
    p.name = "qwen3-omni-sim-cluster".into();
    for s in &mut p.stages {
        s.replicas = 2;
    }
    p.n_devices = 6;
    p.cluster = Some(ClusterConfig {
        nodes: vec![
            NodeSpec { id: "n0".into(), gpus: 2, device_bytes: p.device_bytes },
            NodeSpec { id: "n1".into(), gpus: 2, device_bytes: p.device_bytes },
            NodeSpec { id: "n2".into(), gpus: 2, device_bytes: p.device_bytes },
        ],
        placement: PlacementPolicy::TransferAware,
        link_gbps: 10.0,
        link_latency_ms: 2.0,
    });
    p
}

/// Qwen3-Omni with a branching any-to-any fan-out (paper §3.2's "any"
/// output side): one prompt's prefill feeds BOTH an image branch
/// (Thinker hidden states conditioning a DiT generator) and a speech
/// branch (Talker -> CNN vocoder) in parallel.  The request completes
/// when every branch exit has delivered, and each branch's finish is
/// surfaced to streaming clients as a per-branch marker.
///
/// The preset is also the showcase for fractional GPU sharing
/// ([`crate::gpu_share`]): the encoder and the vocoder are light,
/// bursty stages, so instead of pinning a whole device each they run as
/// 300-milli slots co-resident on device 0 under the per-device
/// time-slice scheduler — the capacity freed is what pays for the extra
/// image branch at equal hardware.
pub fn qwen3_omni_branching() -> PipelineConfig {
    PipelineConfig {
        name: "qwen3-omni-sim-branching".into(),
        stages: vec![
            StageConfig::new("encoder", "enc3", StageKind::Encoder)
                .on_devices(&[0])
                .with_batch(4)
                .with_fraction(300),
            StageConfig::new("thinker", "thinker3", StageKind::Ar)
                .on_devices(&[1])
                .with_batch(2),
            StageConfig::new("imagegen", "qwen_image", StageKind::Dit)
                .on_devices(&[2])
                .with_batch(1)
                .with_diffusion(DiffusionParams {
                    steps: 20,
                    cfg_scale: 3.0,
                    stepcache_threshold: 0.15,
                }),
            StageConfig::new("talker", "talker3", StageKind::Ar)
                .on_devices(&[1])
                .with_batch(2)
                .with_multi_step(crate::engine::ar::SCAN_STEPS),
            StageConfig::new("vocoder", "voc_cnn3", StageKind::CnnVocoder)
                .on_devices(&[0])
                .with_batch(4)
                .with_fraction(300),
        ],
        edges: vec![
            edge("encoder", "thinker", "embeds2prompt"),
            edge("thinker", "imagegen", "hidden2cond"),
            edge("thinker", "talker", "thinker2talker"),
            edge("talker", "vocoder", "talker2vocoder"),
        ],
        n_devices: 3,
        // Thinker and Talker weights co-reside on device 1.
        device_bytes: 2 * crate::device::DEFAULT_DEVICE_BYTES,
        autoscaler: None,
        admission: None,
        cache: None,
        transport: TransportConfig::default(),
        cluster: None,
        share: Some(ShareConfig::default()),
        runtime: None,
    }
}

/// BAGEL sim: understanding expert (AR) -> generation expert (DiT).
/// `i2i` switches the generation expert to the longer image-conditioned
/// variant (ref-image tokens concatenated into the latent sequence).
pub fn bagel(i2i: bool) -> PipelineConfig {
    let gen_model = if i2i { "bagel_i2i" } else { "bagel_t2i" };
    PipelineConfig {
        name: format!("bagel-sim-{}", if i2i { "i2i" } else { "t2i" }),
        stages: vec![
            StageConfig::new("understand", "bagel_und", StageKind::Ar)
                .on_devices(&[0])
                .with_batch(2),
            StageConfig::new("generate", gen_model, StageKind::Dit)
                .on_devices(&[0])
                .with_batch(1)
                .with_diffusion(DiffusionParams {
                    steps: 24,
                    cfg_scale: 3.0,
                    stepcache_threshold: 0.15,
                }),
        ],
        edges: vec![edge("understand", "generate", "hidden2cond")],
        n_devices: 1,
        device_bytes: crate::device::DEFAULT_DEVICE_BYTES,
        autoscaler: None,
        admission: None,
        cache: None,
        transport: TransportConfig::default(),
        cluster: None,
        share: None,
        runtime: None,
    }
}

/// MiMo-Audio sim: AR backbone -> patch decoder.  `multi_step > 1` is the
/// "with execution-graph compilation" configuration from §4.2.
pub fn mimo_audio(multi_step: usize) -> PipelineConfig {
    PipelineConfig {
        name: format!("mimo-audio-sim-ms{multi_step}"),
        stages: vec![
            StageConfig::new("backbone", "mimo", StageKind::Ar)
                .on_devices(&[0])
                .with_batch(2)
                .with_multi_step(multi_step),
            StageConfig::new("patch_dec", "mimo_codec", StageKind::PatchDecoder)
                .on_devices(&[0])
                .with_batch(4),
        ],
        edges: vec![edge("backbone", "patch_dec", "tokens2patches")],
        n_devices: 1,
        device_bytes: crate::device::DEFAULT_DEVICE_BYTES,
        autoscaler: None,
        admission: None,
        cache: None,
        transport: TransportConfig::default(),
        cluster: None,
        share: None,
        runtime: None,
    }
}

/// Single-stage DiT pipelines for Fig. 8 (Qwen-Image, Qwen-Image-Edit,
/// Wan2.2 T2V/I2V).
pub fn dit_single(model: &str, steps: usize, stepcache: f32) -> PipelineConfig {
    PipelineConfig {
        name: format!("{model}-pipeline"),
        stages: vec![StageConfig::new("dit", model, StageKind::Dit)
            .on_devices(&[0])
            .with_batch(1)
            .with_diffusion(DiffusionParams {
                steps,
                cfg_scale: 3.0,
                stepcache_threshold: stepcache,
            })],
        edges: vec![],
        n_devices: 1,
        device_bytes: crate::device::DEFAULT_DEVICE_BYTES,
        autoscaler: None,
        admission: None,
        cache: None,
        transport: TransportConfig::default(),
        cluster: None,
        share: None,
        runtime: None,
    }
}

/// Every preset, for `omni-serve graph --list` and tests.
pub fn all() -> Vec<PipelineConfig> {
    vec![
        qwen25_omni(),
        qwen3_omni(),
        qwen3_omni_replicated(),
        qwen3_omni_epd(),
        qwen3_omni_cluster(),
        qwen3_omni_branching(),
        bagel(false),
        bagel(true),
        mimo_audio(1),
        mimo_audio(crate::engine::ar::SCAN_STEPS),
        dit_single("qwen_image", 20, 0.15),
        dit_single("qwen_image_edit", 20, 0.15),
        dit_single("wan22_t2v", 20, 0.15),
        dit_single("wan22_i2v", 20, 0.15),
    ]
}

pub fn by_name(name: &str) -> Option<PipelineConfig> {
    match name {
        "qwen2.5-omni" | "qwen25-omni" => Some(qwen25_omni()),
        "qwen3-omni" => Some(qwen3_omni()),
        "qwen3-omni-rep2" => Some(qwen3_omni_replicated()),
        "qwen3-omni-epd" => Some(qwen3_omni_epd()),
        "qwen3-omni-cluster" => Some(qwen3_omni_cluster()),
        "qwen3-omni-branching" => Some(qwen3_omni_branching()),
        "bagel-t2i" => Some(bagel(false)),
        "bagel-i2i" => Some(bagel(true)),
        "mimo-audio" => Some(mimo_audio(1)),
        "mimo-audio-compiled" => Some(mimo_audio(crate::engine::ar::SCAN_STEPS)),
        "qwen-image" => Some(dit_single("qwen_image", 20, 0.15)),
        "qwen-image-edit" => Some(dit_single("qwen_image_edit", 20, 0.15)),
        "wan22-t2v" => Some(dit_single("wan22_t2v", 20, 0.15)),
        "wan22-i2v" => Some(dit_single("wan22_i2v", 20, 0.15)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        for p in all() {
            p.validate().unwrap_or_else(|e| panic!("{}: {e}", p.name));
        }
    }

    #[test]
    fn paper_placement_for_omni() {
        let p = qwen3_omni();
        assert_eq!(p.stage("thinker").unwrap().devices, vec![0, 1]); // TP2
        assert_eq!(p.stage("talker").unwrap().devices, vec![1]);
        assert_eq!(p.stage("vocoder").unwrap().devices, vec![0]);
    }

    #[test]
    fn by_name_resolves() {
        assert!(by_name("qwen3-omni").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn epd_preset_splits_prefill_and_decode() {
        let p = qwen3_omni_epd();
        p.validate().unwrap();
        assert_eq!(p.stage("prefill").unwrap().role, StageRole::Prefill);
        assert_eq!(p.stage("decode").unwrap().role, StageRole::Decode);
        assert_eq!(p.stage("prefill").unwrap().model, p.stage("decode").unwrap().model);
        assert!(p.stage("thinker").is_none(), "the fused thinker is gone");
        // The KV-transfer edge connects the pools.
        assert!(p
            .edges
            .iter()
            .any(|e| e.from == "prefill" && e.to == "decode" && e.transfer == "kv2decode"));
        // Decode admission is bounded (handoff backpressure to prefill).
        assert!(p.stage("decode").unwrap().sched.queue_depth > 0);
    }

    #[test]
    fn cluster_preset_declares_topology() {
        let p = qwen3_omni_cluster();
        p.validate().unwrap();
        let c = p.cluster.as_ref().unwrap();
        assert_eq!(c.nodes.len(), 3);
        assert_eq!(c.total_gpus(), p.n_devices);
        assert_eq!(c.placement, PlacementPolicy::TransferAware);
        assert!(p.stages.iter().all(|s| s.replicas == 2));
    }

    #[test]
    fn branching_preset_fans_out_with_fractional_slots() {
        let p = qwen3_omni_branching();
        p.validate().unwrap();
        // One prefill, two output branches.
        let outs: Vec<&str> = p
            .edges
            .iter()
            .filter(|e| e.from == "thinker")
            .map(|e| e.to.as_str())
            .collect();
        assert_eq!(outs, vec!["imagegen", "talker"]);
        // Encoder and vocoder share device 0 as 300-milli slots.
        assert_eq!(p.stage("encoder").unwrap().compute_milli, 300);
        assert_eq!(p.stage("vocoder").unwrap().compute_milli, 300);
        assert_eq!(p.stage("encoder").unwrap().devices, vec![0]);
        assert_eq!(p.stage("vocoder").unwrap().devices, vec![0]);
        assert!(p.share.is_some());
        // The heavy stages keep whole devices.
        assert_eq!(p.stage("thinker").unwrap().compute_milli, 1000);
        assert_eq!(p.stage("imagegen").unwrap().compute_milli, 1000);
        assert!(by_name("qwen3-omni-branching").is_some());
    }

    #[test]
    fn replicated_preset_scales_the_talker() {
        let p = qwen3_omni_replicated();
        p.validate().unwrap();
        assert_eq!(p.stage("talker").unwrap().replicas, 2);
        assert_eq!(p.stage("thinker").unwrap().replicas, 1);
        assert_eq!(p.edges[0].routing, RoutingKind::CacheAware);
    }
}
