//! Configuration system (paper Fig. 3(c)): per-stage runtime settings —
//! parallelism, device placement, memory budgets, batching, streaming —
//! tunable without touching model code.
//!
//! Configs load from JSON ([`loader`]) or from the built-in presets that
//! mirror the paper's evaluated models ([`presets`]).

pub mod loader;
pub mod presets;

use anyhow::{bail, Result};

use crate::kv_cache::EvictionPolicy;

/// What kind of engine serves a stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    /// Autoregressive LLM stage (vLLM-like engine).
    Ar,
    /// Diffusion-transformer stage (diffusion engine).
    Dit,
    /// Lightweight CNN vocoder stage.
    CnnVocoder,
    /// MiMo patch decoder stage.
    PatchDecoder,
    /// Standalone multimodal encoder stage (EPD disaggregation, §3.4).
    Encoder,
}

impl StageKind {
    pub fn name(self) -> &'static str {
        match self {
            StageKind::Ar => "ar",
            StageKind::Dit => "dit",
            StageKind::CnnVocoder => "cnn_vocoder",
            StageKind::PatchDecoder => "patch_decoder",
            StageKind::Encoder => "encoder",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "ar" => StageKind::Ar,
            "dit" => StageKind::Dit,
            "cnn_vocoder" => StageKind::CnnVocoder,
            "patch_decoder" => StageKind::PatchDecoder,
            "encoder" => StageKind::Encoder,
            other => bail!("unknown stage kind `{other}`"),
        })
    }
}

/// Which phase of autoregressive serving an AR stage runs (paper §3.4 —
/// prefill/decode disaggregation).  A `Prefill` stage runs chunked
/// prefill, samples the first token, and exports the sequence's KV state
/// as a [`crate::kv_transfer::KvHandoff`] downstream; a `Decode` stage
/// imports handoffs and continuous-batches decode steps.  `Fused` (the
/// default) is the classic both-phases-in-one-engine behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageRole {
    Fused,
    Prefill,
    Decode,
}

impl StageRole {
    pub fn name(self) -> &'static str {
        match self {
            StageRole::Fused => "fused",
            StageRole::Prefill => "prefill",
            StageRole::Decode => "decode",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "fused" => StageRole::Fused,
            "prefill" => StageRole::Prefill,
            "decode" => StageRole::Decode,
            other => bail!("unknown stage role `{other}`"),
        })
    }
}

/// Which batching policy schedules a stage's admission queue
/// (see [`crate::scheduler::policy`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicyKind {
    /// Pick by stage kind: AR stages get continuous batching, DiT stages
    /// get step-level batching, everything else FIFO.  The default.
    Auto,
    /// Strict arrival order with drain-then-refill batches (static
    /// batching; the natural fit for encoder/vocoder stages and the
    /// baseline the scheduler bench compares against).
    Fifo,
    /// Continuous batching: sequences join whenever a slot is free and
    /// the `max_batch_tokens` budget allows; AR stages only.
    Continuous,
    /// Step-level batching: requests grouped into denoise-step-aligned
    /// cohorts; DiT stages only.
    StepLevel,
}

impl SchedPolicyKind {
    pub fn name(self) -> &'static str {
        match self {
            SchedPolicyKind::Auto => "auto",
            SchedPolicyKind::Fifo => "fifo",
            SchedPolicyKind::Continuous => "continuous",
            SchedPolicyKind::StepLevel => "step_level",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => SchedPolicyKind::Auto,
            "fifo" => SchedPolicyKind::Fifo,
            "continuous" => SchedPolicyKind::Continuous,
            "step_level" | "step-level" => SchedPolicyKind::StepLevel,
            other => bail!("unknown sched policy `{other}`"),
        })
    }

    /// Resolve [`SchedPolicyKind::Auto`] by stage kind; explicit choices
    /// pass through unchanged.  Never returns `Auto`.
    pub fn resolve(self, kind: StageKind) -> Self {
        match self {
            SchedPolicyKind::Auto => match kind {
                StageKind::Ar => SchedPolicyKind::Continuous,
                StageKind::Dit => SchedPolicyKind::StepLevel,
                _ => SchedPolicyKind::Fifo,
            },
            explicit => explicit,
        }
    }
}

/// Per-stage scheduling parameters (paper §3.3 "per-stage request
/// batching").  All defaults reproduce the pre-scheduler behaviour, so
/// existing configs keep working unchanged.
#[derive(Debug, Clone)]
pub struct SchedParams {
    /// Batching policy; [`SchedPolicyKind::Auto`] (default) picks by
    /// stage kind.
    pub policy: SchedPolicyKind,
    /// Continuous batching only: cap on the summed token commitment
    /// (prompt + generation budget) of in-flight sequences.  0 (default)
    /// = no budget, admission is slot-bound only.
    pub max_batch_tokens: usize,
    /// Admission-queue depth cap.  When the stage's pending queue reaches
    /// this many submissions the stage thread stops pulling from its
    /// connectors, so excess items wait in the connector channel instead
    /// of this stage's queue.  Note this bounds *this stage's* admission
    /// queue only — connector channels are unbounded and producers never
    /// block, so it shapes admission order/timing rather than slowing the
    /// producer.  Conditioning rows still in the channel are delayed with
    /// everything else (engines never block on them, so this affects
    /// freshness, not liveness).  0 (default) = unbounded.
    pub queue_depth: usize,
    /// Step-level batching only: a new request may join while every
    /// running lane is at most this many denoise steps into its schedule.
    pub step_window: usize,
}

impl Default for SchedParams {
    fn default() -> Self {
        Self { policy: SchedPolicyKind::Auto, max_batch_tokens: 0, queue_depth: 0, step_window: 2 }
    }
}

/// Diffusion-stage runtime parameters.
#[derive(Debug, Clone)]
pub struct DiffusionParams {
    /// Denoising steps per job.
    pub steps: usize,
    /// Classifier-free guidance scale.
    pub cfg_scale: f32,
    /// TeaCache-style step-cache threshold on the relative change of the
    /// modulation embedding; 0.0 disables caching.
    pub stepcache_threshold: f32,
}

impl Default for DiffusionParams {
    fn default() -> Self {
        Self { steps: 20, cfg_scale: 3.0, stepcache_threshold: 0.0 }
    }
}

/// How a replicated edge routes items across its consumer's engine
/// replicas (paper §3.3 "flexible GPU allocation": hot stages get more
/// replicas; the edge layer decides which replica serves which item).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingKind {
    /// Pick by consumer shape: replicated consumers get [`RoutingKind::Affinity`]
    /// (always safe — transfers and AR engines keep per-request state),
    /// single-replica consumers get the trivial [`RoutingKind::RoundRobin`].
    /// The default.
    Auto,
    /// Per-item rotation.  Maximum spread, but splits a request's item
    /// stream across replicas — only valid when every item is independent
    /// (requests that arrive as one finished item).
    RoundRobin,
    /// Per-item pick of the replica with the smallest load signal
    /// (connector in-flight count + the consumer's published
    /// admission-queue depth, i.e. [`crate::scheduler::SchedStats`]
    /// feedback).  Same independence caveat as round-robin.
    LeastDepth,
    /// Per-request stickiness: every item of a request lands on the same
    /// replica (`req_id % replicas` — deterministic across producer
    /// replicas and edges), so stateful AR replicas keep their
    /// KV/sequence state and chunk-accumulating transfers see the whole
    /// stream.  Required for replicated AR consumers.
    Affinity,
    /// Affinity stickiness with a cache-directed first pick (ISSUE 7):
    /// a request's first item routes to the replica whose advertised
    /// prefix-cache cover includes the request's prompt signature — the
    /// replica that can skip the prefill — falling back to the smallest
    /// load signal when no replica covers it.  Later items follow the
    /// sticky map, so stateful AR consumers stay safe.
    CacheAware,
}

impl RoutingKind {
    pub fn name(self) -> &'static str {
        match self {
            RoutingKind::Auto => "auto",
            RoutingKind::RoundRobin => "round_robin",
            RoutingKind::LeastDepth => "least_depth",
            RoutingKind::Affinity => "affinity",
            RoutingKind::CacheAware => "cache_aware",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => RoutingKind::Auto,
            "round_robin" | "round-robin" => RoutingKind::RoundRobin,
            "least_depth" | "least-depth" => RoutingKind::LeastDepth,
            "affinity" => RoutingKind::Affinity,
            "cache_aware" | "cache-aware" => RoutingKind::CacheAware,
            other => bail!("unknown routing kind `{other}`"),
        })
    }

    /// Resolve [`RoutingKind::Auto`] for a consumer with `replicas`
    /// engine replicas; explicit choices pass through.  Never returns
    /// `Auto`.
    pub fn resolve(self, replicas: usize) -> Self {
        match self {
            RoutingKind::Auto => {
                if replicas > 1 {
                    RoutingKind::Affinity
                } else {
                    RoutingKind::RoundRobin
                }
            }
            explicit => explicit,
        }
    }
}

/// Per-stage configuration (paper Fig. 3(b)/(c)).
#[derive(Debug, Clone)]
pub struct StageConfig {
    /// Stage name within the pipeline ("thinker", "talker", "vocoder").
    pub name: String,
    /// Manifest model served by this stage ("thinker3", "voc_cnn3", ...).
    pub model: String,
    pub kind: StageKind,
    /// Serving phase for AR stages (paper §3.4 P/D disaggregation):
    /// [`StageRole::Fused`] (default) runs prefill + decode in one
    /// engine; `Prefill`/`Decode` split them into independently scaled
    /// pools connected by a KV-transfer edge.
    pub role: StageRole,
    /// Device placement.  More than one device = tensor parallel
    /// (memory-sharded in the device model; see DESIGN.md §6).
    pub devices: Vec<usize>,
    /// Engine replicas serving this stage (paper §3.3 "flexible GPU
    /// allocation": hot stages get more replicas than cold ones).  Each
    /// replica is its own engine thread with its own device group of the
    /// same TP degree as `devices`; replica 0 uses `devices`, further
    /// replicas are packed onto the least-loaded devices by the
    /// allocator.  Default 1 (the pre-replication behaviour).
    pub replicas: usize,
    /// Maximum scheduler batch (must be <= the largest compiled bucket).
    pub max_batch: usize,
    /// Fraction of the stage's device budget reserved for KV cache (AR).
    pub kv_memory_frac: f64,
    /// Enable chunked prefill (AR stages).
    pub chunked_prefill: bool,
    /// Decode steps fused per scheduler iteration: 1 = classic continuous
    /// batching; >1 uses the AOT `scan` executable ("execution-graph
    /// compilation" mode).
    pub multi_step: usize,
    /// Streaming granularity: emit partial outputs to the next stage every
    /// `stream_chunk` tokens (0 = only at stage completion).
    pub stream_chunk: usize,
    /// Diffusion parameters (DiT stages only).
    pub diffusion: DiffusionParams,
    /// Scheduling parameters (batching policy, token budget, queue depth).
    pub sched: SchedParams,
    /// Compute share per replica in milli-GPUs (fractional GPU sharing;
    /// see [`crate::gpu_share`]).  1000 (the default) is a whole device —
    /// the pre-sharing behaviour.  Smaller values let several stages
    /// co-reside on one device under the per-device time-slice scheduler,
    /// subject to the pipeline's [`ShareConfig`].
    pub compute_milli: u32,
}

impl StageConfig {
    pub fn new(name: &str, model: &str, kind: StageKind) -> Self {
        Self {
            name: name.into(),
            model: model.into(),
            kind,
            role: StageRole::Fused,
            devices: vec![0],
            replicas: 1,
            max_batch: 4,
            kv_memory_frac: 0.5,
            chunked_prefill: true,
            multi_step: 1,
            stream_chunk: 16,
            diffusion: DiffusionParams::default(),
            sched: SchedParams::default(),
            compute_milli: crate::gpu_share::DEVICE_MILLI,
        }
    }

    pub fn on_devices(mut self, devices: &[usize]) -> Self {
        self.devices = devices.to_vec();
        self
    }

    pub fn with_role(mut self, r: StageRole) -> Self {
        self.role = r;
        self
    }

    pub fn with_replicas(mut self, r: usize) -> Self {
        self.replicas = r;
        self
    }

    pub fn with_batch(mut self, b: usize) -> Self {
        self.max_batch = b;
        self
    }

    pub fn with_multi_step(mut self, k: usize) -> Self {
        self.multi_step = k;
        self
    }

    pub fn with_stream_chunk(mut self, c: usize) -> Self {
        self.stream_chunk = c;
        self
    }

    pub fn with_diffusion(mut self, d: DiffusionParams) -> Self {
        self.diffusion = d;
        self
    }

    pub fn with_sched(mut self, s: SchedParams) -> Self {
        self.sched = s;
        self
    }

    pub fn with_policy(mut self, p: SchedPolicyKind) -> Self {
        self.sched.policy = p;
        self
    }

    pub fn with_max_batch_tokens(mut self, t: usize) -> Self {
        self.sched.max_batch_tokens = t;
        self
    }

    /// Serve each replica on a fractional slot of `milli` milli-GPUs
    /// (1000 = whole device).  Requires the pipeline's `share` block.
    pub fn with_fraction(mut self, milli: u32) -> Self {
        self.compute_milli = milli;
        self
    }
}

/// Connector selection per edge (paper §3.4, Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnectorKind {
    /// In-process queue (control plane + payload inline).
    Inline,
    /// POSIX shared memory for payloads, inline queue for metadata.
    Shm,
    /// Mooncake-like TCP put/get store with metadata control plane.
    Tcp,
}

impl ConnectorKind {
    pub fn name(self) -> &'static str {
        match self {
            ConnectorKind::Inline => "inline",
            ConnectorKind::Shm => "shm",
            ConnectorKind::Tcp => "tcp",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "inline" => ConnectorKind::Inline,
            "shm" => ConnectorKind::Shm,
            "tcp" => ConnectorKind::Tcp,
            other => bail!("unknown connector kind `{other}`"),
        })
    }
}

/// Elastic autoscaler settings (paper §3 "flexible GPU allocation" under
/// live traffic — see [`crate::serving`]).  The autoscaler samples every
/// stage replica's published scheduler load and moves replicas toward the
/// bottleneck stage within a global GPU budget.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalerConfig {
    /// Per-stage replica floor (never drain below this).
    pub min_replicas: usize,
    /// Per-stage replica ceiling.
    pub max_replicas: usize,
    /// Global budget in device *slots* (Σ over replicas of their TP
    /// degree).  0 = no slot cap; device-memory admission still applies.
    pub gpu_budget: usize,
    /// Scale a stage up when its mean pending-queue depth per live
    /// replica reaches this.
    pub scale_up_queue: f64,
    /// Scale a stage down when its mean pending-queue depth per live
    /// replica is below this AND a replica sits idle.
    pub scale_down_queue: f64,
    /// Control-loop sampling interval.
    pub interval_s: f64,
    /// Minimum seconds between two scale decisions for the same stage.
    pub cooldown_s: f64,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        Self {
            min_replicas: 1,
            max_replicas: 4,
            gpu_budget: 0,
            scale_up_queue: 2.0,
            scale_down_queue: 0.25,
            interval_s: 0.05,
            cooldown_s: 0.25,
        }
    }
}

impl AutoscalerConfig {
    pub fn validate(&self) -> Result<()> {
        if self.min_replicas == 0 {
            bail!("autoscaler min_replicas must be >= 1");
        }
        if self.max_replicas < self.min_replicas {
            bail!(
                "autoscaler max_replicas ({}) < min_replicas ({})",
                self.max_replicas,
                self.min_replicas
            );
        }
        if self.interval_s <= 0.0 {
            bail!("autoscaler interval_s must be > 0");
        }
        if self.scale_down_queue > self.scale_up_queue {
            bail!("autoscaler scale_down_queue must not exceed scale_up_queue");
        }
        Ok(())
    }
}

/// SLO-aware overload control at the serving boundary (see
/// [`crate::serving::admission`]): per-request cost estimation at submit
/// time, early rejection of requests whose deadline is unmeetable, and
/// emergency shedding of queued (never in-flight) work when the
/// projected backlog exceeds the horizon.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionConfig {
    /// Safety factor on the projected completion time before comparing
    /// against the deadline: reject when `projection * slack` exceeds
    /// it.  > 1.0 rejects earlier (conservative), < 1.0 admits
    /// optimistically.
    pub slack: f64,
    /// Projected-backlog horizon in seconds: when the queued (not yet
    /// started) work ahead of the entry stage projects past this, the
    /// collector sheds queued requests oldest-deadline-first until the
    /// projection fits again.
    pub shed_horizon_s: f64,
    /// `retry_after` hint carried in the structured `Rejected` event.
    pub retry_after_s: f64,
    /// Per-tenant weighted-fair-queueing weights, applied within each
    /// priority class of every stage's admission queue.  Tenants not
    /// listed (and requests with no tenant) weigh 1.0.
    pub tenant_weights: Vec<(String, f64)>,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self {
            slack: 1.0,
            shed_horizon_s: 4.0,
            retry_after_s: 0.5,
            tenant_weights: Vec::new(),
        }
    }
}

impl AdmissionConfig {
    pub fn validate(&self) -> Result<()> {
        if !(self.slack.is_finite() && self.slack > 0.0) {
            bail!("admission slack must be a positive number, got {}", self.slack);
        }
        if !(self.shed_horizon_s.is_finite() && self.shed_horizon_s > 0.0) {
            bail!("admission shed_horizon_s must be > 0, got {}", self.shed_horizon_s);
        }
        if !(self.retry_after_s.is_finite() && self.retry_after_s >= 0.0) {
            bail!("admission retry_after_s must be >= 0, got {}", self.retry_after_s);
        }
        for (name, w) in &self.tenant_weights {
            if name.is_empty() {
                bail!("admission tenant_weights entries need a non-empty tenant name");
            }
            if !(w.is_finite() && *w > 0.0) {
                bail!("admission tenant `{name}` weight must be > 0, got {w}");
            }
        }
        Ok(())
    }

    /// Weight of a tenant (1.0 when unlisted / anonymous).
    pub fn tenant_weight(&self, tenant: &str) -> f64 {
        self.tenant_weights
            .iter()
            .find(|(n, _)| n == tenant)
            .map(|(_, w)| *w)
            .unwrap_or(1.0)
    }
}

/// Cross-request caching knobs (ISSUE 7): the global KV prefix cache in
/// every AR stage's [`crate::kv_cache::BlockManager`] and the
/// content-addressed encoder-output cache.  `None` on the pipeline means
/// the defaults below (both caches ON) — set an explicit config to turn
/// them off or tune eviction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheConfig {
    /// Keep released hashed KV blocks resident so later requests sharing
    /// the prompt prefix skip prefill.  Off restores release-means-free.
    pub prefix_cache: bool,
    /// Which refcount-0 cached block to reclaim under memory pressure.
    pub eviction: EvictionPolicy,
    /// Encoder-output cache bound in entries; 0 disables it.
    pub encoder_cache_capacity: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self {
            prefix_cache: true,
            eviction: EvictionPolicy::Lru,
            encoder_cache_capacity: crate::engine::encoder::DEFAULT_CACHE_CAPACITY,
        }
    }
}

impl CacheConfig {
    pub fn validate(&self) -> Result<()> {
        // Every combination is currently meaningful (a disabled prefix
        // cache simply ignores the eviction policy); validation exists so
        // future knobs have a home and loaders fail uniformly.
        Ok(())
    }
}

/// Liveness knobs for the payload transports (ISSUE 8).  The TCP store's
/// blocking GET path emits a heartbeat byte every `heartbeat_s` while a
/// consumer waits; a consumer that hears nothing — no heartbeat, no data
/// — for `read_timeout_s` declares the peer dead and surfaces a
/// structured error naming the edge instead of hanging forever.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransportConfig {
    /// Seconds between server-side heartbeat bytes on a blocked GET.
    pub heartbeat_s: f64,
    /// Seconds of total silence after which the receiving side declares
    /// the peer dead.  Must exceed `heartbeat_s`, or a perfectly healthy
    /// peer would be declared dead between two heartbeats.
    pub read_timeout_s: f64,
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self { heartbeat_s: 0.25, read_timeout_s: 5.0 }
    }
}

impl TransportConfig {
    pub fn validate(&self) -> Result<()> {
        if !(self.heartbeat_s.is_finite() && self.heartbeat_s > 0.0) {
            bail!("transport heartbeat_s must be > 0, got {}", self.heartbeat_s);
        }
        if !(self.read_timeout_s.is_finite() && self.read_timeout_s > self.heartbeat_s) {
            bail!(
                "transport read_timeout_s ({}) must exceed heartbeat_s ({})",
                self.read_timeout_s,
                self.heartbeat_s
            );
        }
        Ok(())
    }
}

/// One node of a multi-node deployment (ISSUE 8): an `omni-serve agent`
/// process contributing `gpus` device slots of `device_bytes` each.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeSpec {
    /// Node identity (matches the agent's `--node-id`).
    pub id: String,
    /// Device slots this node contributes to the cluster pool.
    pub gpus: usize,
    /// Per-device memory budget in bytes.
    pub device_bytes: usize,
}

/// How the cluster allocator assigns stage replicas to nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Co-locate the endpoints of byte-heavy edges (prefill→decode KV
    /// handoffs) on one node and let light edges (talker→vocoder codes)
    /// stream cross-node.  The default.
    TransferAware,
    /// Scatter replicas across nodes in declaration order, ignoring edge
    /// transfer volumes — the naive baseline the placement bench beats.
    RoundRobin,
}

impl PlacementPolicy {
    pub fn name(self) -> &'static str {
        match self {
            PlacementPolicy::TransferAware => "transfer_aware",
            PlacementPolicy::RoundRobin => "round_robin",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "transfer_aware" | "transfer-aware" => PlacementPolicy::TransferAware,
            "round_robin" | "round-robin" => PlacementPolicy::RoundRobin,
            other => bail!("unknown placement policy `{other}`"),
        })
    }
}

/// Multi-node deployment topology (ISSUE 8): the nodes contributing
/// device slots, the placement policy assigning stage replicas to them,
/// and the cross-node link model the placement cost (and the link-aware
/// simulation) prices transfers with.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub nodes: Vec<NodeSpec>,
    pub placement: PlacementPolicy,
    /// Cross-node link bandwidth in Gbit/s.
    pub link_gbps: f64,
    /// Cross-node link latency in milliseconds.
    pub link_latency_ms: f64,
}

impl Default for ClusterConfig {
    /// Field defaults for partial config blocks (a commodity 10 Gbit/s /
    /// 2 ms interconnect).  The empty node list does NOT validate — a
    /// topology must always spell out its nodes.
    fn default() -> Self {
        Self {
            nodes: Vec::new(),
            placement: PlacementPolicy::TransferAware,
            link_gbps: 10.0,
            link_latency_ms: 2.0,
        }
    }
}

impl ClusterConfig {
    pub fn validate(&self) -> Result<()> {
        if self.nodes.is_empty() {
            bail!("cluster has no nodes");
        }
        let mut seen = std::collections::HashSet::new();
        for n in &self.nodes {
            if n.id.is_empty() {
                bail!("cluster node needs a non-empty id");
            }
            if !seen.insert(&n.id) {
                bail!("duplicate cluster node id `{}`", n.id);
            }
            if n.gpus == 0 {
                bail!("cluster node `{}` contributes no device slots", n.id);
            }
            if n.device_bytes == 0 {
                bail!("cluster node `{}` device_bytes must be > 0", n.id);
            }
        }
        if !(self.link_gbps.is_finite() && self.link_gbps > 0.0) {
            bail!("cluster link_gbps must be > 0, got {}", self.link_gbps);
        }
        if !(self.link_latency_ms.is_finite() && self.link_latency_ms >= 0.0) {
            bail!("cluster link_latency_ms must be >= 0, got {}", self.link_latency_ms);
        }
        Ok(())
    }

    /// Total device slots across nodes.
    pub fn total_gpus(&self) -> usize {
        self.nodes.iter().map(|n| n.gpus).sum()
    }

    /// Cross-node link as (bytes/s, latency seconds) — what the
    /// placement cost and the link-aware sim actually consume.
    pub fn link(&self) -> (f64, f64) {
        (self.link_gbps * 1e9 / 8.0, self.link_latency_ms / 1e3)
    }
}

/// Fractional GPU sharing knobs (see [`crate::gpu_share`]): the
/// per-device time-slice scheduler's quantum and the packing limits for
/// fractional slots.  `None` on the pipeline keeps whole-GPU allocation
/// (every `compute_milli` must then be 1000, the default).
#[derive(Debug, Clone, PartialEq)]
pub struct ShareConfig {
    /// Turn length of a whole-device (1000 milli) slot under the
    /// per-device weighted-round-robin scheduler, in milliseconds.  A
    /// fractional slot's turn is `quantum_ms * compute_milli / 1000`.
    /// 0 passes the turn at every step boundary.
    pub quantum_ms: f64,
    /// Resident-slot cap per device (stages co-located on one device);
    /// 0 = unbounded.
    pub max_slots_per_device: usize,
    /// Smallest carvable compute share in milli-GPUs.
    pub min_compute_milli: u32,
}

impl Default for ShareConfig {
    fn default() -> Self {
        Self { quantum_ms: 5.0, max_slots_per_device: 4, min_compute_milli: 50 }
    }
}

impl ShareConfig {
    pub fn validate(&self) -> Result<()> {
        if !(self.quantum_ms.is_finite() && self.quantum_ms >= 0.0) {
            bail!("share quantum_ms must be >= 0, got {}", self.quantum_ms);
        }
        if self.min_compute_milli == 0 || self.min_compute_milli > crate::gpu_share::DEVICE_MILLI {
            bail!(
                "share min_compute_milli must be in 1..={}, got {}",
                crate::gpu_share::DEVICE_MILLI,
                self.min_compute_milli
            );
        }
        Ok(())
    }
}

/// Which clock the event-core [`crate::event_core::Driver`] runs stage
/// loops against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriverKind {
    /// Wall clock, real threads parked on wake mailboxes (live serving).
    Real,
    /// Virtual clock, single-threaded (simulation and trace replay only;
    /// a live session refuses to start under it).
    Sim,
}

impl DriverKind {
    pub fn name(&self) -> &'static str {
        match self {
            DriverKind::Real => "real",
            DriverKind::Sim => "sim",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "real" => Ok(DriverKind::Real),
            "sim" => Ok(DriverKind::Sim),
            other => bail!("unknown driver `{other}` (expected real|sim)"),
        }
    }
}

/// Event-core runtime knobs: driver selection and deterministic trace
/// recording (see [`crate::event_core`]).  `None` on the pipeline means
/// the defaults — real driver, no recording.
#[derive(Debug, Clone, PartialEq)]
pub struct RuntimeConfig {
    /// Clock/parking backend for stage loops.
    pub driver: DriverKind,
    /// Record every request arrival into a checksummed event log,
    /// written to `replay_path` at session shutdown and replayable with
    /// `omni-serve replay <log>`.
    pub replay_record: bool,
    /// Where the recorded event log is written.
    pub replay_path: String,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        Self { driver: DriverKind::Real, replay_record: false, replay_path: "replay.evl".into() }
    }
}

impl RuntimeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.replay_record && self.replay_path.is_empty() {
            bail!("runtime replay_record is on but replay_path is empty");
        }
        Ok(())
    }
}

/// An edge of the stage graph: a named transfer function plus transport.
#[derive(Debug, Clone)]
pub struct EdgeConfig {
    pub from: String,
    pub to: String,
    /// Name of a registered transfer function (see
    /// [`crate::stage_graph::transfers`]).
    pub transfer: String,
    pub connector: ConnectorKind,
    /// How items are routed across the consumer stage's replicas
    /// (irrelevant when the consumer has a single replica).
    pub routing: RoutingKind,
}

/// A full pipeline: stage graph + resources.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub name: String,
    pub stages: Vec<StageConfig>,
    pub edges: Vec<EdgeConfig>,
    /// Simulated accelerator pool.
    pub n_devices: usize,
    pub device_bytes: usize,
    /// Elastic autoscaler settings; `None` = static replica counts (the
    /// pre-serving-runtime behaviour, and the default for every preset).
    pub autoscaler: Option<AutoscalerConfig>,
    /// SLO-aware admission control + shedding; `None` = queue everything
    /// (deadlines still cancel late, but nothing is rejected early).
    pub admission: Option<AdmissionConfig>,
    /// Cross-request prefix / encoder caching; `None` = defaults (both
    /// caches on, LRU eviction).
    pub cache: Option<CacheConfig>,
    /// Transport liveness knobs for shm/tcp edges (heartbeats, peer-dead
    /// timeouts).  The defaults are right for single-process runs.
    pub transport: TransportConfig,
    /// Multi-node deployment topology; `None` = single-process (every
    /// stage thread in this process, the pre-cluster behaviour).
    pub cluster: Option<ClusterConfig>,
    /// Fractional GPU sharing; `None` = whole-GPU allocation only (the
    /// pre-sharing behaviour, and the default for most presets).
    pub share: Option<ShareConfig>,
    /// Event-core runtime knobs (driver, trace recording); `None` =
    /// real driver, no recording.
    pub runtime: Option<RuntimeConfig>,
}

impl PipelineConfig {
    /// Structural validation (placement bounds, edge endpoints, name
    /// uniqueness).  Graph-level checks (acyclicity, entry/exit stages)
    /// happen in [`crate::stage_graph`].
    pub fn validate(&self) -> Result<()> {
        if self.stages.is_empty() {
            bail!("pipeline `{}` has no stages", self.name);
        }
        let mut seen = std::collections::HashSet::new();
        for s in &self.stages {
            if !seen.insert(&s.name) {
                bail!("duplicate stage name `{}`", s.name);
            }
            if s.devices.is_empty() {
                bail!("stage `{}` has no device placement", s.name);
            }
            for &d in &s.devices {
                if d >= self.n_devices {
                    bail!("stage `{}` placed on device {d} but pool has {}", s.name, self.n_devices);
                }
            }
            if s.max_batch == 0 {
                bail!("stage `{}` max_batch must be >= 1", s.name);
            }
            if s.replicas == 0 {
                bail!("stage `{}` replicas must be >= 1", s.name);
            }
            if s.multi_step == 0 {
                bail!("stage `{}` multi_step must be >= 1", s.name);
            }
            if !(0.0..=1.0).contains(&s.kv_memory_frac) {
                bail!("stage `{}` kv_memory_frac out of [0,1]", s.name);
            }
            if s.role != StageRole::Fused && s.kind != StageKind::Ar {
                bail!(
                    "stage `{}`: role `{}` requires an AR stage, got `{}`",
                    s.name,
                    s.role.name(),
                    s.kind.name()
                );
            }
            if s.compute_milli == 0 || s.compute_milli > crate::gpu_share::DEVICE_MILLI {
                bail!(
                    "stage `{}` compute_milli must be in 1..={}, got {}",
                    s.name,
                    crate::gpu_share::DEVICE_MILLI,
                    s.compute_milli
                );
            }
            if s.compute_milli < crate::gpu_share::DEVICE_MILLI && self.share.is_none() {
                bail!(
                    "stage `{}` requests a fractional slot ({} milli) but the pipeline \
                     has no `share` block",
                    s.name,
                    s.compute_milli
                );
            }
            // A fractional slot is carved out of ONE device; tensor
            // parallelism splits a model across whole devices.
            if s.compute_milli < crate::gpu_share::DEVICE_MILLI && s.devices.len() != 1 {
                bail!(
                    "stage `{}` is fractional ({} milli) but has a TP group of {} devices \
                     — fractional slots are single-device",
                    s.name,
                    s.compute_milli,
                    s.devices.len()
                );
            }
        }
        if let Some(a) = &self.autoscaler {
            a.validate()?;
        }
        if let Some(a) = &self.admission {
            a.validate()?;
        }
        if let Some(c) = &self.cache {
            c.validate()?;
        }
        self.transport.validate()?;
        if let Some(c) = &self.cluster {
            c.validate()?;
        }
        if let Some(r) = &self.runtime {
            r.validate()?;
        }
        if let Some(sh) = &self.share {
            sh.validate()?;
            // Per-device compute ledger for the *configured* placements
            // (further replicas pack through the allocator's ledger).
            // Whole-GPU stages keep time-multiplexing as before; the
            // ledger binds once any resident of a device is fractional.
            for d in 0..self.n_devices {
                let residents: Vec<&StageConfig> =
                    self.stages.iter().filter(|s| s.devices.contains(&d)).collect();
                if !residents.iter().any(|s| s.compute_milli < crate::gpu_share::DEVICE_MILLI) {
                    continue;
                }
                let milli: u32 = residents.iter().map(|s| s.compute_milli).sum();
                if milli > crate::gpu_share::DEVICE_MILLI {
                    bail!(
                        "device {d} compute over-subscribed: stages {:?} carve {milli} milli \
                         (> {})",
                        residents.iter().map(|s| s.name.as_str()).collect::<Vec<_>>(),
                        crate::gpu_share::DEVICE_MILLI
                    );
                }
                if sh.max_slots_per_device > 0 && residents.len() > sh.max_slots_per_device {
                    bail!(
                        "device {d} holds {} slots, over the share cap of {}",
                        residents.len(),
                        sh.max_slots_per_device
                    );
                }
                for s in &residents {
                    if s.compute_milli < sh.min_compute_milli {
                        bail!(
                            "stage `{}` slot of {} milli is under min_compute_milli {}",
                            s.name,
                            s.compute_milli,
                            sh.min_compute_milli
                        );
                    }
                }
            }
        }
        for e in &self.edges {
            for end in [&e.from, &e.to] {
                if !self.stages.iter().any(|s| &s.name == end) {
                    bail!("edge references unknown stage `{end}`");
                }
            }
            if e.from == e.to {
                bail!("self-edge on `{}`", e.from);
            }
            // Replicated AR consumers are stateful (KV / sequence state,
            // streamed conditioning): every item of a request must land on
            // the same replica, which only affinity routing guarantees.
            let to = self.stage(&e.to).unwrap();
            if to.replicas > 1
                && to.kind == StageKind::Ar
                && !matches!(
                    e.routing,
                    RoutingKind::Auto | RoutingKind::Affinity | RoutingKind::CacheAware
                )
            {
                bail!(
                    "edge {}->{}: AR consumer `{}` has {} replicas; stateful stages \
                     require `affinity` (or `cache_aware`) routing (got `{}`)",
                    e.from,
                    e.to,
                    e.to,
                    to.replicas,
                    e.routing.name()
                );
            }
        }
        Ok(())
    }

    pub fn stage(&self, name: &str) -> Option<&StageConfig> {
        self.stages.iter().find(|s| s.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_stage() -> PipelineConfig {
        PipelineConfig {
            name: "t".into(),
            stages: vec![
                StageConfig::new("a", "thinker25", StageKind::Ar),
                StageConfig::new("b", "talker25", StageKind::Ar),
            ],
            edges: vec![EdgeConfig {
                from: "a".into(),
                to: "b".into(),
                transfer: "thinker2talker".into(),
                connector: ConnectorKind::Inline,
                routing: RoutingKind::Auto,
            }],
            n_devices: 2,
            device_bytes: 1 << 20,
            autoscaler: None,
            admission: None,
            cache: None,
            transport: TransportConfig::default(),
            cluster: None,
            share: None,
            runtime: None,
        }
    }

    #[test]
    fn valid_pipeline_passes() {
        two_stage().validate().unwrap();
    }

    #[test]
    fn runtime_block_validates() {
        let mut p = two_stage();
        p.runtime = Some(RuntimeConfig::default());
        p.validate().unwrap();
        p.runtime = Some(RuntimeConfig {
            replay_record: true,
            replay_path: String::new(),
            ..Default::default()
        });
        assert!(p.validate().is_err(), "recording without a path must be rejected");
        assert_eq!(DriverKind::from_name("sim").unwrap(), DriverKind::Sim);
        assert_eq!(DriverKind::from_name("real").unwrap().name(), "real");
        assert!(DriverKind::from_name("quantum").is_err());
    }

    #[test]
    fn rejects_bad_placement() {
        let mut p = two_stage();
        p.stages[0].devices = vec![5];
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let mut p = two_stage();
        p.stages[1].name = "a".into();
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_unknown_edge_endpoint() {
        let mut p = two_stage();
        p.edges[0].to = "zzz".into();
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_self_edge() {
        let mut p = two_stage();
        p.edges[0].to = "a".into();
        assert!(p.validate().is_err());
    }

    #[test]
    fn sched_policy_roundtrip_and_resolution() {
        for p in [SchedPolicyKind::Auto, SchedPolicyKind::Fifo,
                  SchedPolicyKind::Continuous, SchedPolicyKind::StepLevel] {
            assert_eq!(SchedPolicyKind::from_name(p.name()).unwrap(), p);
        }
        assert!(SchedPolicyKind::from_name("nope").is_err());
        assert_eq!(SchedPolicyKind::Auto.resolve(StageKind::Ar), SchedPolicyKind::Continuous);
        assert_eq!(SchedPolicyKind::Auto.resolve(StageKind::Dit), SchedPolicyKind::StepLevel);
        assert_eq!(SchedPolicyKind::Auto.resolve(StageKind::Encoder), SchedPolicyKind::Fifo);
        assert_eq!(SchedPolicyKind::Auto.resolve(StageKind::CnnVocoder), SchedPolicyKind::Fifo);
        // Explicit choices pass through.
        assert_eq!(SchedPolicyKind::Fifo.resolve(StageKind::Ar), SchedPolicyKind::Fifo);
    }

    #[test]
    fn sched_defaults_are_backward_compatible() {
        let s = StageConfig::new("a", "thinker25", StageKind::Ar);
        assert_eq!(s.sched.policy, SchedPolicyKind::Auto);
        assert_eq!(s.sched.max_batch_tokens, 0);
        assert_eq!(s.sched.queue_depth, 0);
        assert!(s.sched.step_window > 0);
    }

    #[test]
    fn routing_kind_roundtrip_and_resolution() {
        for r in [RoutingKind::Auto, RoutingKind::RoundRobin,
                  RoutingKind::LeastDepth, RoutingKind::Affinity,
                  RoutingKind::CacheAware] {
            assert_eq!(RoutingKind::from_name(r.name()).unwrap(), r);
        }
        assert_eq!(
            RoutingKind::from_name("cache-aware").unwrap(),
            RoutingKind::CacheAware
        );
        assert!(RoutingKind::from_name("nope").is_err());
        // Auto resolves by consumer replication; explicit passes through.
        assert_eq!(RoutingKind::Auto.resolve(1), RoutingKind::RoundRobin);
        assert_eq!(RoutingKind::Auto.resolve(3), RoutingKind::Affinity);
        assert_eq!(RoutingKind::LeastDepth.resolve(4), RoutingKind::LeastDepth);
    }

    #[test]
    fn replicas_default_to_one_and_zero_is_rejected() {
        let p = two_stage();
        assert!(p.stages.iter().all(|s| s.replicas == 1));
        p.validate().unwrap();
        let mut p = two_stage();
        p.stages[0].replicas = 0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn replicated_ar_consumer_requires_affinity_routing() {
        // Replicated AR consumer + explicit per-item routing: rejected.
        let mut p = two_stage();
        p.stages[1].replicas = 2;
        p.edges[0].routing = RoutingKind::RoundRobin;
        assert!(p.validate().is_err());
        // Affinity (explicit or via Auto) is accepted.
        p.edges[0].routing = RoutingKind::Affinity;
        p.validate().unwrap();
        p.edges[0].routing = RoutingKind::Auto;
        p.validate().unwrap();
        // Cache-aware keeps affinity-grade stickiness, so it is allowed.
        p.edges[0].routing = RoutingKind::CacheAware;
        p.validate().unwrap();
    }

    #[test]
    fn cache_config_defaults_and_validation() {
        let c = CacheConfig::default();
        assert!(c.prefix_cache);
        assert_eq!(c.eviction, EvictionPolicy::Lru);
        assert_eq!(
            c.encoder_cache_capacity,
            crate::engine::encoder::DEFAULT_CACHE_CAPACITY
        );
        let mut p = two_stage();
        p.cache = Some(CacheConfig {
            prefix_cache: false,
            eviction: EvictionPolicy::HitAware,
            encoder_cache_capacity: 0,
        });
        p.validate().unwrap();
    }

    #[test]
    fn autoscaler_config_validates() {
        let mut p = two_stage();
        p.autoscaler = Some(AutoscalerConfig::default());
        p.validate().unwrap();
        p.autoscaler = Some(AutoscalerConfig { min_replicas: 0, ..Default::default() });
        assert!(p.validate().is_err());
        p.autoscaler =
            Some(AutoscalerConfig { min_replicas: 3, max_replicas: 2, ..Default::default() });
        assert!(p.validate().is_err());
        p.autoscaler = Some(AutoscalerConfig { interval_s: 0.0, ..Default::default() });
        assert!(p.validate().is_err());
        p.autoscaler = Some(AutoscalerConfig {
            scale_up_queue: 1.0,
            scale_down_queue: 2.0,
            ..Default::default()
        });
        assert!(p.validate().is_err());
    }

    #[test]
    fn admission_config_validates() {
        let mut p = two_stage();
        p.admission = Some(AdmissionConfig::default());
        p.validate().unwrap();
        p.admission = Some(AdmissionConfig { slack: 0.0, ..Default::default() });
        assert!(p.validate().is_err());
        p.admission = Some(AdmissionConfig { shed_horizon_s: -1.0, ..Default::default() });
        assert!(p.validate().is_err());
        p.admission = Some(AdmissionConfig { retry_after_s: f64::NAN, ..Default::default() });
        assert!(p.validate().is_err());
        p.admission = Some(AdmissionConfig {
            tenant_weights: vec![("".into(), 1.0)],
            ..Default::default()
        });
        assert!(p.validate().is_err());
        p.admission = Some(AdmissionConfig {
            tenant_weights: vec![("acme".into(), 0.0)],
            ..Default::default()
        });
        assert!(p.validate().is_err());
        let a = AdmissionConfig {
            tenant_weights: vec![("acme".into(), 4.0)],
            ..Default::default()
        };
        assert_eq!(a.tenant_weight("acme"), 4.0);
        assert_eq!(a.tenant_weight("unlisted"), 1.0);
    }

    #[test]
    fn transport_config_validates() {
        let mut p = two_stage();
        p.transport = TransportConfig::default();
        p.validate().unwrap();
        p.transport = TransportConfig { heartbeat_s: 0.0, read_timeout_s: 1.0 };
        assert!(p.validate().is_err());
        // A timeout at or under the heartbeat would declare healthy peers
        // dead between beats.
        p.transport = TransportConfig { heartbeat_s: 1.0, read_timeout_s: 1.0 };
        assert!(p.validate().is_err());
        p.transport = TransportConfig { heartbeat_s: 0.05, read_timeout_s: f64::NAN };
        assert!(p.validate().is_err());
    }

    fn two_nodes() -> ClusterConfig {
        ClusterConfig {
            nodes: vec![
                NodeSpec { id: "n0".into(), gpus: 2, device_bytes: 1 << 20 },
                NodeSpec { id: "n1".into(), gpus: 2, device_bytes: 1 << 20 },
            ],
            placement: PlacementPolicy::TransferAware,
            link_gbps: 10.0,
            link_latency_ms: 2.0,
        }
    }

    #[test]
    fn cluster_config_validates() {
        let mut p = two_stage();
        p.cluster = Some(two_nodes());
        p.validate().unwrap();
        let mut c = two_nodes();
        c.nodes.clear();
        p.cluster = Some(c);
        assert!(p.validate().is_err());
        let mut c = two_nodes();
        c.nodes[1].id = "n0".into();
        p.cluster = Some(c);
        assert!(p.validate().is_err());
        let mut c = two_nodes();
        c.nodes[0].gpus = 0;
        p.cluster = Some(c);
        assert!(p.validate().is_err());
        let mut c = two_nodes();
        c.link_gbps = 0.0;
        p.cluster = Some(c);
        assert!(p.validate().is_err());
        let mut c = two_nodes();
        c.link_latency_ms = -1.0;
        p.cluster = Some(c);
        assert!(p.validate().is_err());
    }

    #[test]
    fn cluster_link_and_totals() {
        let c = two_nodes();
        assert_eq!(c.total_gpus(), 4);
        let (bw, lat) = c.link();
        assert_eq!(bw, 1.25e9, "10 Gbit/s is 1.25 GB/s");
        assert_eq!(lat, 0.002);
    }

    #[test]
    fn placement_policy_roundtrip() {
        for p in [PlacementPolicy::TransferAware, PlacementPolicy::RoundRobin] {
            assert_eq!(PlacementPolicy::from_name(p.name()).unwrap(), p);
        }
        assert_eq!(
            PlacementPolicy::from_name("transfer-aware").unwrap(),
            PlacementPolicy::TransferAware
        );
        assert!(PlacementPolicy::from_name("nope").is_err());
    }

    #[test]
    fn role_roundtrip_and_defaults() {
        for r in [StageRole::Fused, StageRole::Prefill, StageRole::Decode] {
            assert_eq!(StageRole::from_name(r.name()).unwrap(), r);
        }
        assert!(StageRole::from_name("nope").is_err());
        let s = StageConfig::new("a", "thinker25", StageKind::Ar);
        assert_eq!(s.role, StageRole::Fused, "role defaults to fused");
    }

    #[test]
    fn non_ar_stage_roles_rejected() {
        let mut p = two_stage();
        p.stages[0].kind = StageKind::Encoder;
        p.stages[0].role = StageRole::Prefill;
        assert!(p.validate().is_err());
        // AR stages accept the split roles.
        let mut p = two_stage();
        p.stages[0].role = StageRole::Prefill;
        p.stages[1].role = StageRole::Decode;
        p.validate().unwrap();
    }

    #[test]
    fn fractional_slots_require_a_share_block() {
        let mut p = two_stage();
        p.stages[1].devices = vec![1];
        p.stages[0].compute_milli = 300;
        assert!(p.validate().is_err(), "fraction without share block");
        p.share = Some(ShareConfig::default());
        p.validate().unwrap();
        // Out-of-range milli rejected with or without the block.
        p.stages[0].compute_milli = 0;
        assert!(p.validate().is_err());
        p.stages[0].compute_milli = 1001;
        assert!(p.validate().is_err());
    }

    #[test]
    fn share_ledger_rejects_oversubscribed_device() {
        // Both stages fractional on device 0: fits at 500+500...
        let mut p = two_stage();
        p.share = Some(ShareConfig::default());
        p.stages[0].devices = vec![0];
        p.stages[1].devices = vec![0];
        p.stages[0].compute_milli = 500;
        p.stages[1].compute_milli = 500;
        p.validate().unwrap();
        // ...but a fractional resident next to a whole-GPU one (500 +
        // 1000) over-subscribes the ledger.
        p.stages[1].compute_milli = 1000;
        assert!(p.validate().is_err());
        // Whole-GPU stages alone keep time-multiplexing as before.
        p.stages[0].compute_milli = 1000;
        p.validate().unwrap();
    }

    #[test]
    fn share_config_bounds_validate() {
        let mut p = two_stage();
        p.stages[1].devices = vec![1];
        p.stages[0].compute_milli = 300;
        p.share = Some(ShareConfig { quantum_ms: f64::NAN, ..Default::default() });
        assert!(p.validate().is_err());
        p.share = Some(ShareConfig { min_compute_milli: 0, ..Default::default() });
        assert!(p.validate().is_err());
        // A slot under min_compute_milli is rejected.
        p.share = Some(ShareConfig { min_compute_milli: 400, ..Default::default() });
        assert!(p.validate().is_err());
        // Slot cap per device.
        let mut p = two_stage();
        p.stages[0].devices = vec![0];
        p.stages[1].devices = vec![0];
        p.stages[0].compute_milli = 200;
        p.stages[1].compute_milli = 200;
        p.share = Some(ShareConfig { max_slots_per_device: 1, ..Default::default() });
        assert!(p.validate().is_err());
        p.share = Some(ShareConfig { max_slots_per_device: 2, ..Default::default() });
        p.validate().unwrap();
    }

    #[test]
    fn kind_roundtrip() {
        for k in [StageKind::Ar, StageKind::Dit, StageKind::CnnVocoder,
                  StageKind::PatchDecoder, StageKind::Encoder] {
            assert_eq!(StageKind::from_name(k.name()).unwrap(), k);
        }
        assert!(StageKind::from_name("nope").is_err());
    }
}
