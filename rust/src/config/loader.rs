//! JSON <-> [`PipelineConfig`] (de)serialization, so deployments can be
//! described in files (`omni-serve serve --config pipeline.json`).

use std::path::Path;

use anyhow::{Context, Result};

use super::{
    AdmissionConfig, AutoscalerConfig, CacheConfig, ClusterConfig, ConnectorKind, DiffusionParams,
    DriverKind, EdgeConfig, NodeSpec, PipelineConfig, PlacementPolicy, RoutingKind, RuntimeConfig,
    SchedParams, SchedPolicyKind, ShareConfig, StageConfig, StageKind, StageRole, TransportConfig,
};
use crate::kv_cache::EvictionPolicy;
use crate::jobj;
use crate::json::{self, Value};

pub fn from_file(path: &Path) -> Result<PipelineConfig> {
    let v = json::from_file(path)?;
    from_value(&v).with_context(|| format!("in config {}", path.display()))
}

pub fn from_value(v: &Value) -> Result<PipelineConfig> {
    let mut stages = Vec::new();
    for sv in v.req_arr("stages")? {
        let kind = StageKind::from_name(sv.req_str("kind")?)?;
        let mut s = StageConfig::new(sv.req_str("name")?, sv.req_str("model")?, kind);
        if let Some(r) = sv.get("role").as_str() {
            s.role = StageRole::from_name(r)?;
        }
        if let Some(devs) = sv.get("devices").as_arr() {
            s.devices = devs.iter().filter_map(|d| d.as_usize()).collect();
        }
        if let Some(b) = sv.get("max_batch").as_usize() {
            s.max_batch = b;
        }
        if let Some(r) = sv.get("replicas").as_usize() {
            s.replicas = r;
        }
        if let Some(m) = sv.get("compute_milli").as_usize() {
            s.compute_milli = m as u32;
        }
        if let Some(f) = sv.get("kv_memory_frac").as_f64() {
            s.kv_memory_frac = f;
        }
        if let Some(b) = sv.get("chunked_prefill").as_bool() {
            s.chunked_prefill = b;
        }
        if let Some(k) = sv.get("multi_step").as_usize() {
            s.multi_step = k;
        }
        if let Some(c) = sv.get("stream_chunk").as_usize() {
            s.stream_chunk = c;
        }
        let dv = sv.get("diffusion");
        if !dv.is_null() {
            s.diffusion = DiffusionParams {
                steps: dv.get("steps").as_usize().unwrap_or(20),
                cfg_scale: dv.get("cfg_scale").as_f64().unwrap_or(3.0) as f32,
                stepcache_threshold: dv.get("stepcache_threshold").as_f64().unwrap_or(0.0) as f32,
            };
        }
        let scv = sv.get("sched");
        if !scv.is_null() {
            let defaults = SchedParams::default();
            s.sched = SchedParams {
                policy: match scv.get("policy").as_str() {
                    Some(p) => SchedPolicyKind::from_name(p)?,
                    None => defaults.policy,
                },
                max_batch_tokens: scv
                    .get("max_batch_tokens")
                    .as_usize()
                    .unwrap_or(defaults.max_batch_tokens),
                queue_depth: scv.get("queue_depth").as_usize().unwrap_or(defaults.queue_depth),
                step_window: scv.get("step_window").as_usize().unwrap_or(defaults.step_window),
            };
        }
        stages.push(s);
    }
    let mut edges = Vec::new();
    if let Some(evs) = v.get("edges").as_arr() {
        for ev in evs {
            edges.push(EdgeConfig {
                from: ev.req_str("from")?.to_string(),
                to: ev.req_str("to")?.to_string(),
                transfer: ev.req_str("transfer")?.to_string(),
                connector: ConnectorKind::from_name(
                    ev.get("connector").as_str().unwrap_or("inline"),
                )?,
                routing: RoutingKind::from_name(ev.get("routing").as_str().unwrap_or("auto"))?,
            });
        }
    }
    let av = v.get("autoscaler");
    let autoscaler = if av.is_null() {
        None
    } else {
        // A typo like `"autoscaler": true` must not silently enable the
        // control plane with defaults the user never chose.
        anyhow::ensure!(av.as_obj().is_some(), "`autoscaler` must be an object");
        let d = AutoscalerConfig::default();
        Some(AutoscalerConfig {
            min_replicas: av.get("min_replicas").as_usize().unwrap_or(d.min_replicas),
            max_replicas: av.get("max_replicas").as_usize().unwrap_or(d.max_replicas),
            gpu_budget: av.get("gpu_budget").as_usize().unwrap_or(d.gpu_budget),
            scale_up_queue: av.get("scale_up_queue").as_f64().unwrap_or(d.scale_up_queue),
            scale_down_queue: av.get("scale_down_queue").as_f64().unwrap_or(d.scale_down_queue),
            interval_s: av.get("interval_s").as_f64().unwrap_or(d.interval_s),
            cooldown_s: av.get("cooldown_s").as_f64().unwrap_or(d.cooldown_s),
        })
    };
    let adv = v.get("admission");
    let admission = if adv.is_null() {
        None
    } else {
        // Same guard as the autoscaler: `"admission": true` is a typo,
        // not "enable with defaults".
        anyhow::ensure!(adv.as_obj().is_some(), "`admission` must be an object");
        let d = AdmissionConfig::default();
        let mut tenant_weights = Vec::new();
        let tw = adv.get("tenant_weights");
        if !tw.is_null() {
            let obj = tw
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("`tenant_weights` must be an object"))?;
            for (name, wv) in obj {
                let w = wv.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("tenant `{name}` weight must be a number")
                })?;
                tenant_weights.push((name.clone(), w));
            }
            // BTreeMap iteration is sorted, but keep it explicit: tenant
            // ids are assigned by position (see serving::admission).
            tenant_weights.sort_by(|a, b| a.0.cmp(&b.0));
        }
        Some(AdmissionConfig {
            slack: adv.get("slack").as_f64().unwrap_or(d.slack),
            shed_horizon_s: adv.get("shed_horizon_s").as_f64().unwrap_or(d.shed_horizon_s),
            retry_after_s: adv.get("retry_after_s").as_f64().unwrap_or(d.retry_after_s),
            tenant_weights,
        })
    };
    let cv = v.get("cache");
    let cache = if cv.is_null() {
        None
    } else {
        // Same guard as the autoscaler: `"cache": true` is a typo, not
        // "enable with defaults".
        anyhow::ensure!(cv.as_obj().is_some(), "`cache` must be an object");
        let d = CacheConfig::default();
        Some(CacheConfig {
            prefix_cache: cv.get("prefix_cache").as_bool().unwrap_or(d.prefix_cache),
            eviction: match cv.get("eviction").as_str() {
                Some(name) => EvictionPolicy::from_name(name)?,
                None => d.eviction,
            },
            encoder_cache_capacity: cv
                .get("encoder_cache_capacity")
                .as_usize()
                .unwrap_or(d.encoder_cache_capacity),
        })
    };
    let tv = v.get("transport");
    let transport = if tv.is_null() {
        TransportConfig::default()
    } else {
        // Same guard as the autoscaler: `"transport": true` is a typo,
        // not "enable with defaults".
        anyhow::ensure!(tv.as_obj().is_some(), "`transport` must be an object");
        let d = TransportConfig::default();
        TransportConfig {
            heartbeat_s: tv.get("heartbeat_s").as_f64().unwrap_or(d.heartbeat_s),
            read_timeout_s: tv.get("read_timeout_s").as_f64().unwrap_or(d.read_timeout_s),
        }
    };
    let clv = v.get("cluster");
    let cluster = if clv.is_null() {
        None
    } else {
        // Same guard as the autoscaler: a topology must be spelled out.
        anyhow::ensure!(clv.as_obj().is_some(), "`cluster` must be an object");
        let d = ClusterConfig::default();
        let mut nodes = Vec::new();
        for nv in clv.req_arr("nodes")? {
            nodes.push(NodeSpec {
                id: nv.req_str("id")?.to_string(),
                gpus: nv.get("gpus").as_usize().unwrap_or(1),
                device_bytes: nv
                    .get("device_bytes")
                    .as_usize()
                    .unwrap_or(crate::device::DEFAULT_DEVICE_BYTES),
            });
        }
        Some(ClusterConfig {
            nodes,
            placement: match clv.get("placement").as_str() {
                Some(name) => PlacementPolicy::from_name(name)?,
                None => d.placement,
            },
            link_gbps: clv.get("link_gbps").as_f64().unwrap_or(d.link_gbps),
            link_latency_ms: clv.get("link_latency_ms").as_f64().unwrap_or(d.link_latency_ms),
        })
    };
    let shv = v.get("share");
    let share = if shv.is_null() {
        None
    } else {
        // Same guard as the autoscaler: `"share": true` is a typo, not
        // "enable fractional sharing with defaults".
        anyhow::ensure!(shv.as_obj().is_some(), "`share` must be an object");
        let d = ShareConfig::default();
        Some(ShareConfig {
            quantum_ms: shv.get("quantum_ms").as_f64().unwrap_or(d.quantum_ms),
            max_slots_per_device: shv
                .get("max_slots_per_device")
                .as_usize()
                .unwrap_or(d.max_slots_per_device),
            min_compute_milli: shv
                .get("min_compute_milli")
                .as_usize()
                .map(|m| m as u32)
                .unwrap_or(d.min_compute_milli),
        })
    };
    let rv = v.get("runtime");
    let runtime = if rv.is_null() {
        None
    } else {
        // Same guard as the autoscaler: `"runtime": true` is a typo, not
        // "enable replay recording with defaults".
        anyhow::ensure!(rv.as_obj().is_some(), "`runtime` must be an object");
        let d = RuntimeConfig::default();
        Some(RuntimeConfig {
            driver: match rv.get("driver").as_str() {
                Some(name) => DriverKind::from_name(name)?,
                None => d.driver,
            },
            replay_record: rv.get("replay_record").as_bool().unwrap_or(d.replay_record),
            replay_path: rv
                .get("replay_path")
                .as_str()
                .map(|s| s.to_string())
                .unwrap_or(d.replay_path),
        })
    };
    let cfg = PipelineConfig {
        name: v.req_str("name")?.to_string(),
        stages,
        edges,
        n_devices: v.get("n_devices").as_usize().unwrap_or(2),
        device_bytes: v
            .get("device_bytes")
            .as_usize()
            .unwrap_or(crate::device::DEFAULT_DEVICE_BYTES),
        autoscaler,
        admission,
        cache,
        transport,
        cluster,
        share,
        runtime,
    };
    cfg.validate()?;
    Ok(cfg)
}

pub fn to_value(p: &PipelineConfig) -> Value {
    let stages: Vec<Value> = p
        .stages
        .iter()
        .map(|s| {
            jobj! {
                "name" => s.name.clone(),
                "model" => s.model.clone(),
                "kind" => s.kind.name(),
                "role" => s.role.name(),
                "devices" => s.devices.clone(),
                "replicas" => s.replicas,
                "compute_milli" => s.compute_milli as usize,
                "max_batch" => s.max_batch,
                "kv_memory_frac" => s.kv_memory_frac,
                "chunked_prefill" => s.chunked_prefill,
                "multi_step" => s.multi_step,
                "stream_chunk" => s.stream_chunk,
                "diffusion" => jobj! {
                    "steps" => s.diffusion.steps,
                    "cfg_scale" => s.diffusion.cfg_scale as f64,
                    "stepcache_threshold" => s.diffusion.stepcache_threshold as f64,
                },
                "sched" => jobj! {
                    "policy" => s.sched.policy.name(),
                    "max_batch_tokens" => s.sched.max_batch_tokens,
                    "queue_depth" => s.sched.queue_depth,
                    "step_window" => s.sched.step_window,
                },
            }
        })
        .collect();
    let edges: Vec<Value> = p
        .edges
        .iter()
        .map(|e| {
            jobj! {
                "from" => e.from.clone(),
                "to" => e.to.clone(),
                "transfer" => e.transfer.clone(),
                "connector" => e.connector.name(),
                "routing" => e.routing.name(),
            }
        })
        .collect();
    let mut out = jobj! {
        "name" => p.name.clone(),
        "stages" => Value::Arr(stages),
        "edges" => Value::Arr(edges),
        "n_devices" => p.n_devices,
        "device_bytes" => p.device_bytes,
    };
    if let Some(a) = &p.autoscaler {
        if let Value::Obj(m) = &mut out {
            m.insert(
                "autoscaler".to_string(),
                jobj! {
                    "min_replicas" => a.min_replicas,
                    "max_replicas" => a.max_replicas,
                    "gpu_budget" => a.gpu_budget,
                    "scale_up_queue" => a.scale_up_queue,
                    "scale_down_queue" => a.scale_down_queue,
                    "interval_s" => a.interval_s,
                    "cooldown_s" => a.cooldown_s,
                },
            );
        }
    }
    if let Some(a) = &p.admission {
        if let Value::Obj(m) = &mut out {
            let mut weights = std::collections::BTreeMap::new();
            for (name, w) in &a.tenant_weights {
                weights.insert(name.clone(), Value::Num(*w));
            }
            m.insert(
                "admission".to_string(),
                jobj! {
                    "slack" => a.slack,
                    "shed_horizon_s" => a.shed_horizon_s,
                    "retry_after_s" => a.retry_after_s,
                    "tenant_weights" => Value::Obj(weights),
                },
            );
        }
    }
    if let Some(c) = &p.cache {
        if let Value::Obj(m) = &mut out {
            m.insert(
                "cache".to_string(),
                jobj! {
                    "prefix_cache" => c.prefix_cache,
                    "eviction" => c.eviction.name(),
                    "encoder_cache_capacity" => c.encoder_cache_capacity,
                },
            );
        }
    }
    if p.transport != TransportConfig::default() {
        if let Value::Obj(m) = &mut out {
            m.insert(
                "transport".to_string(),
                jobj! {
                    "heartbeat_s" => p.transport.heartbeat_s,
                    "read_timeout_s" => p.transport.read_timeout_s,
                },
            );
        }
    }
    if let Some(sh) = &p.share {
        if let Value::Obj(m) = &mut out {
            m.insert(
                "share".to_string(),
                jobj! {
                    "quantum_ms" => sh.quantum_ms,
                    "max_slots_per_device" => sh.max_slots_per_device,
                    "min_compute_milli" => sh.min_compute_milli as usize,
                },
            );
        }
    }
    if let Some(r) = &p.runtime {
        if let Value::Obj(m) = &mut out {
            m.insert(
                "runtime".to_string(),
                jobj! {
                    "driver" => r.driver.name(),
                    "replay_record" => r.replay_record,
                    "replay_path" => r.replay_path.clone(),
                },
            );
        }
    }
    if let Some(c) = &p.cluster {
        if let Value::Obj(m) = &mut out {
            let nodes: Vec<Value> = c
                .nodes
                .iter()
                .map(|n| {
                    jobj! {
                        "id" => n.id.clone(),
                        "gpus" => n.gpus,
                        "device_bytes" => n.device_bytes,
                    }
                })
                .collect();
            m.insert(
                "cluster".to_string(),
                jobj! {
                    "nodes" => Value::Arr(nodes),
                    "placement" => c.placement.name(),
                    "link_gbps" => c.link_gbps,
                    "link_latency_ms" => c.link_latency_ms,
                },
            );
        }
    }
    out
}

pub fn to_json_string(p: &PipelineConfig) -> String {
    json::to_string_pretty(&to_value(p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn presets_roundtrip_through_json() {
        for p in presets::all() {
            let s = to_json_string(&p);
            let v = json::parse(&s).unwrap();
            let q = from_value(&v).unwrap();
            assert_eq!(p.name, q.name);
            assert_eq!(p.stages.len(), q.stages.len());
            for (a, b) in p.stages.iter().zip(&q.stages) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.model, b.model);
                assert_eq!(a.kind, b.kind);
                assert_eq!(a.role, b.role);
                assert_eq!(a.devices, b.devices);
                assert_eq!(a.replicas, b.replicas);
                assert_eq!(a.compute_milli, b.compute_milli);
                assert_eq!(a.max_batch, b.max_batch);
                assert_eq!(a.multi_step, b.multi_step);
                assert_eq!(a.diffusion.steps, b.diffusion.steps);
                assert_eq!(a.sched.policy, b.sched.policy);
                assert_eq!(a.sched.max_batch_tokens, b.sched.max_batch_tokens);
                assert_eq!(a.sched.queue_depth, b.sched.queue_depth);
                assert_eq!(a.sched.step_window, b.sched.step_window);
            }
            assert_eq!(p.edges.len(), q.edges.len());
            for (a, b) in p.edges.iter().zip(&q.edges) {
                assert_eq!(a.transfer, b.transfer);
                assert_eq!(a.connector, b.connector);
                assert_eq!(a.routing, b.routing);
            }
            assert_eq!(p.transport, q.transport);
            assert_eq!(p.cluster, q.cluster);
            assert_eq!(p.share, q.share);
            assert_eq!(p.runtime, q.runtime);
        }
    }

    #[test]
    fn share_block_roundtrips_and_defaults() {
        let p = presets::qwen3_omni_branching();
        assert!(p.share.is_some(), "branching preset enables fractional sharing");
        let s = to_json_string(&p);
        let q = from_value(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(q.share, p.share);
        // Partial block: unspecified fields take the defaults, and a
        // fractional stage is accepted once the block is present.
        let v = json::parse(
            r#"{"name": "x", "n_devices": 2, "stages": [
                {"name": "a", "model": "enc3", "kind": "encoder", "devices": [0],
                 "compute_milli": 400},
                {"name": "b", "model": "thinker3", "kind": "ar", "devices": [1]}
            ], "edges": [
                {"from": "a", "to": "b", "transfer": "embeds2prompt"}
            ], "share": {"quantum_ms": 2.0}}"#,
        )
        .unwrap();
        let q = from_value(&v).unwrap();
        let sh = q.share.unwrap();
        assert_eq!(sh.quantum_ms, 2.0);
        assert_eq!(sh.max_slots_per_device, ShareConfig::default().max_slots_per_device);
        assert_eq!(q.stages[0].compute_milli, 400);
        assert_eq!(q.stages[1].compute_milli, 1000, "compute_milli defaults to a whole device");
        // No block at all: None (whole-GPU allocation only).
        assert!(presets::qwen3_omni().share.is_none());
        // A fractional stage without a share block is rejected at load time.
        let bad = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0],
                 "compute_milli": 400}
            ]}"#,
        )
        .unwrap();
        assert!(from_value(&bad).is_err());
        // A non-object value is a config mistake, not "all defaults".
        let typo = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "share": true}"#,
        )
        .unwrap();
        assert!(from_value(&typo).is_err());
    }

    #[test]
    fn invalid_config_rejected() {
        let v = json::parse(r#"{"name": "x", "stages": []}"#).unwrap();
        assert!(from_value(&v).is_err());
    }

    #[test]
    fn sched_block_parses_with_partial_fields() {
        let v = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0],
                 "sched": {"policy": "continuous", "max_batch_tokens": 512}}
            ]}"#,
        )
        .unwrap();
        let p = from_value(&v).unwrap();
        let s = &p.stages[0].sched;
        assert_eq!(s.policy, crate::config::SchedPolicyKind::Continuous);
        assert_eq!(s.max_batch_tokens, 512);
        // Unspecified fields keep their defaults.
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.step_window, crate::config::SchedParams::default().step_window);
    }

    #[test]
    fn replicas_and_routing_parse_from_json() {
        let v = json::parse(
            r#"{"name": "x", "n_devices": 2, "stages": [
                {"name": "a", "model": "thinker3", "kind": "ar", "devices": [0]},
                {"name": "b", "model": "talker3", "kind": "ar", "devices": [1], "replicas": 2}
            ], "edges": [
                {"from": "a", "to": "b", "transfer": "thinker2talker", "routing": "affinity"}
            ]}"#,
        )
        .unwrap();
        let p = from_value(&v).unwrap();
        assert_eq!(p.stages[0].replicas, 1, "replicas defaults to 1");
        assert_eq!(p.stages[1].replicas, 2);
        assert_eq!(p.edges[0].routing, RoutingKind::Affinity);
        // Per-item routing into a replicated AR consumer is rejected at
        // load time (validate() runs inside from_value).
        let bad = json::parse(
            r#"{"name": "x", "n_devices": 2, "stages": [
                {"name": "a", "model": "thinker3", "kind": "ar", "devices": [0]},
                {"name": "b", "model": "talker3", "kind": "ar", "devices": [1], "replicas": 2}
            ], "edges": [
                {"from": "a", "to": "b", "transfer": "thinker2talker", "routing": "round_robin"}
            ]}"#,
        )
        .unwrap();
        assert!(from_value(&bad).is_err());
    }

    #[test]
    fn autoscaler_block_roundtrips_and_defaults() {
        let mut p = presets::qwen3_omni_replicated();
        p.autoscaler = Some(AutoscalerConfig {
            max_replicas: 3,
            gpu_budget: 4,
            ..Default::default()
        });
        let s = to_json_string(&p);
        let q = from_value(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(q.autoscaler, p.autoscaler);
        // Partial block: unspecified fields take the defaults.
        let v = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "autoscaler": {"gpu_budget": 2}}"#,
        )
        .unwrap();
        let q = from_value(&v).unwrap();
        let a = q.autoscaler.unwrap();
        assert_eq!(a.gpu_budget, 2);
        assert_eq!(a.min_replicas, AutoscalerConfig::default().min_replicas);
        // No block at all: None (static replication).
        assert!(presets::qwen3_omni().autoscaler.is_none());
        // Invalid block rejected at load time.
        let bad = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "autoscaler": {"min_replicas": 0}}"#,
        )
        .unwrap();
        assert!(from_value(&bad).is_err());
        // A non-object value is a config mistake, not "all defaults".
        let typo = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "autoscaler": true}"#,
        )
        .unwrap();
        assert!(from_value(&typo).is_err());
    }

    #[test]
    fn admission_block_roundtrips_and_defaults() {
        let mut p = presets::qwen3_omni();
        p.admission = Some(AdmissionConfig {
            slack: 1.5,
            shed_horizon_s: 8.0,
            retry_after_s: 1.0,
            tenant_weights: vec![("acme".to_string(), 4.0), ("zed".to_string(), 1.0)],
        });
        let s = to_json_string(&p);
        let q = from_value(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(q.admission, p.admission);
        // Partial block: unspecified fields take the defaults.
        let v = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "admission": {"slack": 2.0}}"#,
        )
        .unwrap();
        let q = from_value(&v).unwrap();
        let a = q.admission.unwrap();
        assert_eq!(a.slack, 2.0);
        assert_eq!(a.shed_horizon_s, AdmissionConfig::default().shed_horizon_s);
        assert!(a.tenant_weights.is_empty());
        // No block at all: None (admit everything).
        assert!(presets::qwen3_omni().admission.is_none());
        // Invalid block rejected at load time.
        let bad = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "admission": {"slack": 0.0}}"#,
        )
        .unwrap();
        assert!(from_value(&bad).is_err());
        // A non-object value is a config mistake, not "all defaults".
        let typo = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "admission": true}"#,
        )
        .unwrap();
        assert!(from_value(&typo).is_err());
    }

    #[test]
    fn cache_block_roundtrips_and_defaults() {
        let mut p = presets::qwen3_omni();
        p.cache = Some(CacheConfig {
            prefix_cache: true,
            eviction: EvictionPolicy::HitAware,
            encoder_cache_capacity: 64,
        });
        let s = to_json_string(&p);
        let q = from_value(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(q.cache, p.cache);
        // Partial block: unspecified fields take the defaults; the
        // eviction name accepts the hyphenated spelling.
        let v = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "cache": {"eviction": "hit-aware"}}"#,
        )
        .unwrap();
        let q = from_value(&v).unwrap();
        let c = q.cache.unwrap();
        assert_eq!(c.eviction, EvictionPolicy::HitAware);
        assert!(c.prefix_cache);
        assert_eq!(
            c.encoder_cache_capacity,
            CacheConfig::default().encoder_cache_capacity
        );
        // No block at all: None (engine defaults, caches on).
        assert!(presets::qwen3_omni().cache.is_none());
        // Unknown eviction policy rejected at load time.
        let bad = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "cache": {"eviction": "mru"}}"#,
        )
        .unwrap();
        assert!(from_value(&bad).is_err());
        // A non-object value is a config mistake, not "all defaults".
        let typo = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "cache": false}"#,
        )
        .unwrap();
        assert!(from_value(&typo).is_err());
    }

    #[test]
    fn transport_block_roundtrips_and_defaults() {
        let mut p = presets::qwen3_omni();
        p.transport = TransportConfig { heartbeat_s: 0.1, read_timeout_s: 1.0 };
        let s = to_json_string(&p);
        let q = from_value(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(q.transport, p.transport);
        // Partial block: unspecified fields take the defaults.
        let v = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "transport": {"read_timeout_s": 2.5}}"#,
        )
        .unwrap();
        let q = from_value(&v).unwrap();
        assert_eq!(q.transport.read_timeout_s, 2.5);
        assert_eq!(q.transport.heartbeat_s, TransportConfig::default().heartbeat_s);
        // No block at all: the defaults.
        assert_eq!(presets::qwen3_omni().transport, TransportConfig::default());
        // Invalid block rejected at load time (timeout under heartbeat).
        let bad = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "transport": {"heartbeat_s": 3.0, "read_timeout_s": 1.0}}"#,
        )
        .unwrap();
        assert!(from_value(&bad).is_err());
        // A non-object value is a config mistake, not "all defaults".
        let typo = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "transport": true}"#,
        )
        .unwrap();
        assert!(from_value(&typo).is_err());
    }

    #[test]
    fn cluster_block_roundtrips_and_defaults() {
        let p = presets::qwen3_omni_cluster();
        let s = to_json_string(&p);
        let q = from_value(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(q.cluster, p.cluster);
        // Partial block: node gpus/device_bytes and the link model take
        // defaults; the placement name accepts the hyphenated spelling.
        let v = json::parse(
            r#"{"name": "x", "n_devices": 2, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "cluster": {"nodes": [{"id": "n0"}, {"id": "n1", "gpus": 3}],
                           "placement": "round-robin"}}"#,
        )
        .unwrap();
        let q = from_value(&v).unwrap();
        let c = q.cluster.unwrap();
        assert_eq!(c.nodes[0].gpus, 1);
        assert_eq!(c.nodes[0].device_bytes, crate::device::DEFAULT_DEVICE_BYTES);
        assert_eq!(c.nodes[1].gpus, 3);
        assert_eq!(c.placement, PlacementPolicy::RoundRobin);
        assert_eq!(c.link_gbps, ClusterConfig::default().link_gbps);
        // No block at all: None (single-process deployment).
        assert!(presets::qwen3_omni().cluster.is_none());
        // A topology without nodes is rejected at load time.
        let bad = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "cluster": {"nodes": []}}"#,
        )
        .unwrap();
        assert!(from_value(&bad).is_err());
        // A non-object value is a config mistake, not "all defaults".
        let typo = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "cluster": true}"#,
        )
        .unwrap();
        assert!(from_value(&typo).is_err());
    }

    #[test]
    fn runtime_block_roundtrips_and_defaults() {
        let mut p = presets::qwen3_omni();
        p.runtime = Some(RuntimeConfig {
            driver: DriverKind::Real,
            replay_record: true,
            replay_path: "run.evl".to_string(),
        });
        let s = to_json_string(&p);
        let q = from_value(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(q.runtime, p.runtime);
        // Partial block: unspecified fields take the defaults.
        let v = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "runtime": {"driver": "sim"}}"#,
        )
        .unwrap();
        let q = from_value(&v).unwrap();
        let r = q.runtime.unwrap();
        assert_eq!(r.driver, DriverKind::Sim);
        assert!(!r.replay_record);
        assert_eq!(r.replay_path, RuntimeConfig::default().replay_path);
        // No block at all: None (real driver, no recording).
        assert!(presets::qwen3_omni().runtime.is_none());
        // Unknown driver rejected at load time.
        let bad = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "runtime": {"driver": "fiber"}}"#,
        )
        .unwrap();
        assert!(from_value(&bad).is_err());
        // A non-object value is a config mistake, not "all defaults".
        let typo = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0]}
            ], "runtime": true}"#,
        )
        .unwrap();
        assert!(from_value(&typo).is_err());
    }

    #[test]
    fn role_parses_and_defaults_from_json() {
        let v = json::parse(
            r#"{"name": "x", "n_devices": 2, "stages": [
                {"name": "p", "model": "thinker3", "kind": "ar", "devices": [0], "role": "prefill"},
                {"name": "d", "model": "thinker3", "kind": "ar", "devices": [1], "role": "decode"},
                {"name": "t", "model": "talker3", "kind": "ar", "devices": [1]}
            ], "edges": [
                {"from": "p", "to": "d", "transfer": "kv2decode"},
                {"from": "d", "to": "t", "transfer": "thinker2talker"}
            ]}"#,
        )
        .unwrap();
        let p = from_value(&v).unwrap();
        assert_eq!(p.stages[0].role, crate::config::StageRole::Prefill);
        assert_eq!(p.stages[1].role, crate::config::StageRole::Decode);
        assert_eq!(p.stages[2].role, crate::config::StageRole::Fused, "role defaults to fused");
        // Unknown role rejected.
        let bad = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0], "role": "both"}
            ]}"#,
        )
        .unwrap();
        assert!(from_value(&bad).is_err());
    }

    #[test]
    fn unknown_sched_policy_rejected() {
        let v = json::parse(
            r#"{"name": "x", "n_devices": 1, "stages": [
                {"name": "a", "model": "mimo", "kind": "ar", "devices": [0],
                 "sched": {"policy": "wfq"}}
            ]}"#,
        )
        .unwrap();
        assert!(from_value(&v).is_err());
    }
}
