//! Paged KV-cache manager (vLLM-style, paper §3.3 "KV manager") with a
//! global cross-request prefix cache (ISSUE 7, after Cornserve).
//!
//! Tracks device KV memory in fixed-size token blocks with reference
//! counting, copy-on-write forking, and hash-based prefix sharing.  The
//! AR scheduler consults it for admission (a sequence runs only while its
//! blocks fit the stage's KV budget) and preemption.
//!
//! Every block is in exactly one of three states:
//!
//! * **free** — on the free list, no content, no hash;
//! * **referenced** — held by one or more live sequences (refcount > 0);
//! * **cached** — refcount 0 but still resident: the block kept its
//!   prefix hash when its last sequence released it, so a *later*
//!   request with the same prompt prefix re-attaches to it instead of
//!   recomputing prefill.  Cached blocks are reclaimed on demand by the
//!   configured [`EvictionPolicy`] (only refcount-0 blocks are ever
//!   evicted), so the cache degrades gracefully under memory pressure.
//!
//! Before ISSUE 7 a released block was pushed straight to the free list
//! and its hash purged, so prefix sharing only worked between
//! *concurrently live* sequences and within KV imports.  The cached
//! state is what makes the prefix cache cross-request.
//!
//! Note on fidelity: the compiled decode executables hold KV densely per
//! batch slot (HLO shapes are static), so the block table is the
//! *accounting* layer — exactly the admission/preemption role vLLM's
//! block manager plays — while the per-slot dense tensors are the storage
//! layer.  The AR engine mirrors the hash index with a host-side content
//! stash so a prefix-cache hit also skips the prefill compute (see
//! `engine/ar/core.rs`).  See DESIGN.md §6.

use std::collections::HashMap;

use anyhow::{bail, Result};

pub type BlockId = u32;

/// Content hash chain for prefix sharing: hash of (parent_hash, tokens).
pub fn chain_hash(parent: u64, tokens: &[u32]) -> u64 {
    let mut h = parent ^ 0x9E3779B97F4A7C15;
    for &t in tokens {
        h ^= t as u64;
        h = h.wrapping_mul(0x100000001B3);
        h ^= h >> 29;
    }
    h
}

/// Chain hashes of every *full* `block_size` window of `tokens` — the
/// block-granular identity of a prompt prefix.  `block_hashes(bs, p)[i]`
/// is the hash a [`BlockManager`] with block size `bs` assigns to the
/// i-th full block of prompt `p`.
pub fn block_hashes(block_size: usize, tokens: &[u32]) -> Vec<u64> {
    assert!(block_size > 0);
    let mut out = Vec::with_capacity(tokens.len() / block_size);
    let mut parent = 0u64;
    let mut i = 0;
    while i + block_size <= tokens.len() {
        parent = chain_hash(parent, &tokens[i..i + block_size]);
        out.push(parent);
        i += block_size;
    }
    out
}

/// Whole-prompt content signature (block-size independent).  The router's
/// cache-aware policy matches a request's signature against the
/// signatures replicas advertise (see `connector/router.rs`).
pub fn prompt_signature(tokens: &[u32]) -> u64 {
    chain_hash(0, tokens)
}

/// Which refcount-0 cached block to reclaim when the pool needs a block
/// and the free list is empty.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Evict the least-recently-used cached block.
    Lru,
    /// Evict the cached block with the fewest lifetime hits, breaking
    /// ties by recency — hot system prompts survive longer than
    /// one-off prompts of the same age.
    HitAware,
}

impl EvictionPolicy {
    pub fn name(self) -> &'static str {
        match self {
            EvictionPolicy::Lru => "lru",
            EvictionPolicy::HitAware => "hit_aware",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "lru" => EvictionPolicy::Lru,
            "hit_aware" | "hit-aware" => EvictionPolicy::HitAware,
            other => bail!("unknown eviction policy `{other}`"),
        })
    }
}

#[derive(Debug, Clone)]
struct Block {
    refcount: u32,
    /// Prefix hash when the block is full and shareable.
    hash: Option<u64>,
    /// Refcount-0 resident (in the prefix cache, not on the free list).
    cached: bool,
    /// Logical time of the last allocation/hit/release touching this
    /// block (LRU eviction order).
    last_use: u64,
    /// Lifetime prefix-cache hits on this block (hit-rate-aware eviction).
    hits: u64,
}

/// Per-sequence block table.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    pub blocks: Vec<BlockId>,
    /// Tokens stored so far.
    pub len: usize,
}

/// Serializable accounting state of one sequence's block table — the
/// block-level half of a [`crate::kv_transfer::KvHandoff`].  Carries the
/// prefix chain hash of every *full* block so an importing pool can
/// deduplicate against blocks it already holds (hash-based prefix
/// sharing across the prefill/decode boundary) instead of allocating
/// fresh ones.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KvSeqExport {
    /// Exporter's block size (hashes only transfer between pools with
    /// the same geometry).
    pub block_size: u32,
    /// Tokens resident in the exported cache.
    pub len: u64,
    /// One entry per full block: the prefix chain hash when the block is
    /// shareable (`None` for blocks grown past the prompt by decode
    /// appends, which never carry a hash).
    pub full_hashes: Vec<Option<u64>>,
}

/// The paged allocator for one stage's KV pool.
#[derive(Debug)]
pub struct BlockManager {
    block_size: usize,
    blocks: Vec<Block>,
    free: Vec<BlockId>,
    /// full-block prefix hash -> block id (prefix cache).  Points only at
    /// referenced or cached blocks, never at free ones.
    prefix_index: HashMap<u64, BlockId>,
    /// Keep refcount-0 blocks resident (the cross-request prefix cache).
    /// Off = the pre-ISSUE-7 behaviour: release frees immediately.
    cache_enabled: bool,
    policy: EvictionPolicy,
    /// Refcount-0 resident block count (cached state).
    n_cached: usize,
    /// Logical clock for LRU ordering.
    tick: u64,
    /// Hashes whose blocks left the index (evicted, overwritten, or
    /// force-freed).  The engine drains this to invalidate its host-side
    /// content stash — a stale hash must never skip prefill onto a
    /// recycled block.
    retired_hashes: Vec<u64>,
    /// cache hits since creation (metrics).
    pub prefix_hits: u64,
    /// full-block lookups that missed (metrics; hit rate denominator is
    /// hits + misses).
    pub prefix_misses: u64,
    /// cached blocks reclaimed under memory pressure (metrics).
    pub evictions: u64,
    /// Copy-on-write tail copies triggered by appends to forked tables
    /// (metrics; each one stands for a device-side block copy).
    pub cow_copies: u64,
}

impl BlockManager {
    /// A manager with the cross-request prefix cache ON under LRU
    /// eviction (the ISSUE 7 default).
    pub fn new(n_blocks: usize, block_size: usize) -> Self {
        Self::with_cache(n_blocks, block_size, true, EvictionPolicy::Lru)
    }

    pub fn with_cache(
        n_blocks: usize,
        block_size: usize,
        cache_enabled: bool,
        policy: EvictionPolicy,
    ) -> Self {
        assert!(block_size > 0 && n_blocks > 0);
        Self {
            block_size,
            blocks: vec![
                Block { refcount: 0, hash: None, cached: false, last_use: 0, hits: 0 };
                n_blocks
            ],
            free: (0..n_blocks as BlockId).rev().collect(),
            prefix_index: HashMap::new(),
            cache_enabled,
            policy,
            n_cached: 0,
            tick: 0,
            retired_hashes: Vec::new(),
            prefix_hits: 0,
            prefix_misses: 0,
            evictions: 0,
            cow_copies: 0,
        }
    }

    /// Build a manager sized from a byte budget.
    pub fn from_bytes(budget_bytes: usize, bytes_per_token: usize, block_size: usize) -> Self {
        let tokens = budget_bytes / bytes_per_token.max(1);
        let n_blocks = (tokens / block_size).max(1);
        Self::new(n_blocks, block_size)
    }

    /// [`Self::from_bytes`] with explicit cache configuration.
    pub fn from_bytes_with(
        budget_bytes: usize,
        bytes_per_token: usize,
        block_size: usize,
        cache_enabled: bool,
        policy: EvictionPolicy,
    ) -> Self {
        let tokens = budget_bytes / bytes_per_token.max(1);
        let n_blocks = (tokens / block_size).max(1);
        Self::with_cache(n_blocks, block_size, cache_enabled, policy)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Refcount-0 blocks kept resident by the prefix cache.
    pub fn cached_blocks(&self) -> usize {
        self.n_cached
    }

    /// Blocks a new sequence could claim right now (free + evictable).
    pub fn reclaimable_blocks(&self) -> usize {
        self.free.len() + self.n_cached
    }

    pub fn cache_enabled(&self) -> bool {
        self.cache_enabled
    }

    pub fn blocks_needed(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Can a sequence of `tokens` total tokens be admitted right now?
    /// Cached blocks count — they are reclaimed on demand by eviction.
    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_needed(tokens) <= self.reclaimable_blocks()
    }

    /// The prefix hash of a resident block, if it carries one.
    pub fn block_hash(&self, bid: BlockId) -> Option<u64> {
        self.blocks.get(bid as usize).and_then(|b| b.hash)
    }

    /// Is a full block with this prefix hash resident (referenced or
    /// cached)?
    pub fn is_resident(&self, hash: u64) -> bool {
        self.prefix_index.contains_key(&hash)
    }

    /// Every full-block prefix hash currently resident (referenced or
    /// cached) — a stage's cache-coverage advertisement for cache-aware
    /// routing (order unspecified).
    pub fn resident_hashes(&self) -> Vec<u64> {
        self.prefix_index.keys().copied().collect()
    }

    /// Drain the hashes retired from the index since the last call
    /// (evicted, overwritten, or force-freed blocks).  The engine uses
    /// this to invalidate its host-side KV content stash.
    pub fn take_retired_hashes(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.retired_hashes)
    }

    fn touch(&mut self, bid: BlockId) {
        self.tick += 1;
        self.blocks[bid as usize].last_use = self.tick;
    }

    /// Remove a block's index entry (logging the retirement) and clear
    /// its hash.  Called whenever block content stops being addressable.
    fn retire_hash(&mut self, bid: BlockId) {
        if let Some(h) = self.blocks[bid as usize].hash.take() {
            if self.prefix_index.get(&h) == Some(&bid) {
                self.prefix_index.remove(&h);
                self.retired_hashes.push(h);
            }
        }
    }

    /// Reclaim one cached block per the eviction policy.  Only
    /// refcount-0 (cached) blocks are candidates, and the hash-index
    /// entry is purged atomically with the reclaim — a stale hash must
    /// never dedup a new request onto a recycled block.
    fn evict_one(&mut self) -> Result<BlockId> {
        if self.n_cached == 0 {
            bail!("KV pool exhausted");
        }
        let victim = self
            .blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.cached)
            .min_by_key(|(i, b)| match self.policy {
                EvictionPolicy::Lru => (b.last_use, 0, *i),
                EvictionPolicy::HitAware => (b.hits, b.last_use, *i),
            })
            .map(|(i, _)| i as BlockId)
            .expect("n_cached > 0");
        self.retire_hash(victim);
        let b = &mut self.blocks[victim as usize];
        debug_assert!(b.cached && b.refcount == 0);
        b.cached = false;
        b.hits = 0;
        self.n_cached -= 1;
        self.evictions += 1;
        Ok(victim)
    }

    /// Claim a block for new content: the free list first, then the
    /// eviction policy.  The returned block has refcount 1 and no hash.
    fn alloc_block(&mut self) -> Result<BlockId> {
        let id = match self.free.pop() {
            Some(id) => {
                // Free blocks never carry a hash (retired when freed),
                // but stay defensive against state drift.
                self.retire_hash(id);
                id
            }
            None => self.evict_one()?,
        };
        let b = &mut self.blocks[id as usize];
        debug_assert_eq!(b.refcount, 0);
        b.refcount = 1;
        b.hits = 0;
        self.touch(id);
        Ok(id)
    }

    /// Re-attach to a resident block (prefix-cache hit): a cached block
    /// is resurrected to refcount 1, a referenced block gains a sharer.
    fn attach(&mut self, bid: BlockId) {
        let b = &mut self.blocks[bid as usize];
        if b.cached {
            debug_assert_eq!(b.refcount, 0);
            b.cached = false;
            self.n_cached -= 1;
        }
        b.refcount += 1;
        b.hits += 1;
        self.prefix_hits += 1;
        self.touch(bid);
    }

    /// Force-free every block of a table regardless of cache policy —
    /// rollback of a partially allocated table whose blocks never held
    /// computed content (they must not be resurrectable by hash).
    fn release_uncached(&mut self, table: &BlockTable) {
        for &bid in &table.blocks {
            let b = &mut self.blocks[bid as usize];
            assert!(b.refcount > 0, "double free of block {bid}");
            b.refcount -= 1;
            if b.refcount == 0 {
                if b.cached {
                    unreachable!("refcount>0 block cannot be cached");
                }
                self.retire_hash(bid);
                self.blocks[bid as usize].hits = 0;
                self.free.push(bid);
            }
        }
    }

    /// Allocate a table for a prompt, matching the leading full blocks
    /// against resident (referenced OR cached) blocks.  Returns the
    /// table plus the number of *leading* full blocks that hit — the
    /// prefix whose KV is already resident, which the engine's prefill
    /// skips (it restarts at the first miss).
    pub fn allocate_prompt_matched(&mut self, tokens: &[u32]) -> Result<(BlockTable, usize)> {
        let mut table = BlockTable::default();
        let mut parent = 0u64;
        let mut i = 0;
        let mut leading = 0usize;
        let mut contiguous = true;
        // Full blocks: try the prefix cache first.
        while i + self.block_size <= tokens.len() {
            let h = chain_hash(parent, &tokens[i..i + self.block_size]);
            if let Some(&bid) = self.prefix_index.get(&h) {
                self.attach(bid);
                if contiguous {
                    leading += 1;
                }
                table.blocks.push(bid);
            } else {
                self.prefix_misses += 1;
                contiguous = false;
                match self.alloc_block() {
                    Ok(bid) => {
                        self.blocks[bid as usize].hash = Some(h);
                        self.prefix_index.insert(h, bid);
                        table.blocks.push(bid);
                    }
                    Err(e) => {
                        self.release_uncached(&table);
                        return Err(e);
                    }
                }
            }
            parent = h;
            i += self.block_size;
        }
        // Tail partial block (never shared).
        if i < tokens.len() {
            match self.alloc_block() {
                Ok(bid) => table.blocks.push(bid),
                Err(e) => {
                    self.release_uncached(&table);
                    return Err(e);
                }
            }
        }
        table.len = tokens.len();
        Ok((table, leading))
    }

    /// Allocate a table for a prompt, reusing shared full-block prefixes
    /// when the token content matches (prefix caching).
    pub fn allocate_prompt(&mut self, tokens: &[u32]) -> Result<BlockTable> {
        self.allocate_prompt_matched(tokens).map(|(t, _)| t)
    }

    /// Extend a table by one generated token, allocating a block at the
    /// boundary.  Returns true if a new block was allocated.
    ///
    /// Copy-on-write: when the partial tail block is shared (the table
    /// was [`fork`](Self::fork)ed, or is a fork's sibling), the append
    /// must not mutate the shared copy — the tail moves to a private
    /// block first (counted in [`Self::cow_copies`]; each one stands for
    /// a device-side block copy).
    pub fn append_token(&mut self, table: &mut BlockTable) -> Result<bool> {
        if table.len % self.block_size == 0 {
            let bid = self.alloc_block()?;
            table.blocks.push(bid);
            table.len += 1;
            return Ok(true);
        }
        let tail = *table.blocks.last().expect("partial tail implies a block");
        if self.blocks[tail as usize].refcount > 1 {
            // On exhaustion the error propagates with the table intact
            // (len unchanged, tail still shared) — callers can preempt.
            let fresh = self.alloc_block()?;
            self.blocks[tail as usize].refcount -= 1;
            self.cow_copies += 1;
            *table.blocks.last_mut().expect("checked above") = fresh;
            table.len += 1;
            return Ok(true);
        }
        table.len += 1;
        Ok(false)
    }

    /// Copy-on-write fork (e.g. beam/parallel sampling): shares all
    /// blocks, bumping refcounts.
    pub fn fork(&mut self, table: &BlockTable) -> BlockTable {
        for &bid in &table.blocks {
            self.blocks[bid as usize].refcount += 1;
        }
        table.clone()
    }

    /// Release a table (sequence finished, cancelled, or preempted).
    /// With the prefix cache on, hashed blocks whose refcount drops to 0
    /// stay RESIDENT in the cached state — the cross-request cache —
    /// instead of freeing; unhashed blocks (partial tails, decode-grown
    /// blocks) free immediately.  With the cache off, this is the
    /// pre-ISSUE-7 release: the hash-index entry is purged atomically
    /// with the free on every path (cancel sweeps included), so a stale
    /// hash can never dedup a new request onto a recycled block.
    pub fn release(&mut self, table: &BlockTable) {
        for &bid in &table.blocks {
            let b = &mut self.blocks[bid as usize];
            assert!(b.refcount > 0, "double free of block {bid}");
            b.refcount -= 1;
            if b.refcount > 0 {
                continue;
            }
            let keep = self.cache_enabled
                && self.blocks[bid as usize].hash.is_some()
                && self.blocks[bid as usize]
                    .hash
                    .map(|h| self.prefix_index.get(&h) == Some(&bid))
                    .unwrap_or(false);
            if keep {
                self.blocks[bid as usize].cached = true;
                self.n_cached += 1;
                self.touch(bid);
            } else {
                self.retire_hash(bid);
                self.blocks[bid as usize].hits = 0;
                self.free.push(bid);
            }
        }
    }

    /// Drop every cached (refcount-0 resident) block to the free list,
    /// retiring their hashes.  Returns how many were flushed.
    pub fn flush_cache(&mut self) -> usize {
        let mut flushed = 0;
        for i in 0..self.blocks.len() {
            if self.blocks[i].cached {
                let bid = i as BlockId;
                self.retire_hash(bid);
                let b = &mut self.blocks[i];
                b.cached = false;
                b.hits = 0;
                self.n_cached -= 1;
                self.free.push(bid);
                flushed += 1;
            }
        }
        flushed
    }

    /// Export a sequence's block accounting for a KV handoff
    /// (prefill/decode disaggregation, paper §3.4): the full blocks'
    /// prefix hashes travel with the payload so the importing pool can
    /// reuse already-resident prefix blocks.  Does not mutate the pool —
    /// the caller releases the table when the handoff is sent.
    pub fn export_seq(&self, table: &BlockTable) -> KvSeqExport {
        let full = table.len / self.block_size;
        KvSeqExport {
            block_size: self.block_size as u32,
            len: table.len as u64,
            full_hashes: table
                .blocks
                .iter()
                .take(full)
                .map(|&bid| self.blocks[bid as usize].hash)
                .collect(),
        }
    }

    /// Import an exported sequence into this pool, reusing hash-matched
    /// resident prefix blocks (each reuse counts as a [`Self::prefix_hits`]
    /// and is returned in the reuse count — those blocks' contents are
    /// already device-resident and need no re-send).  Freshly allocated
    /// full blocks register their hash so *later* imports of the same
    /// prefix dedup against them.  On pool exhaustion the partial import
    /// is rolled back and the error propagates (the caller re-queues).
    pub fn import_seq(&mut self, ex: &KvSeqExport) -> Result<(BlockTable, usize)> {
        let len = ex.len as usize;
        let full = len / self.block_size;
        // Hash chains are per-geometry: a different block size means no
        // dedup, but the import still lands (fresh blocks throughout).
        let same_geometry = ex.block_size as usize == self.block_size;
        if same_geometry && ex.full_hashes.len() != full {
            bail!(
                "kv import: {} full-block hashes but {len} tokens need {full} full blocks",
                ex.full_hashes.len()
            );
        }
        let mut table = BlockTable::default();
        let mut reused = 0usize;
        for i in 0..full {
            let h = if same_geometry { ex.full_hashes[i] } else { None };
            if let Some(h) = h {
                if let Some(&bid) = self.prefix_index.get(&h) {
                    self.attach(bid);
                    reused += 1;
                    table.blocks.push(bid);
                    continue;
                }
                self.prefix_misses += 1;
            }
            match self.alloc_block() {
                Ok(bid) => {
                    if let Some(h) = h {
                        self.blocks[bid as usize].hash = Some(h);
                        self.prefix_index.insert(h, bid);
                    }
                    table.blocks.push(bid);
                }
                Err(e) => {
                    self.release_uncached(&table);
                    return Err(e);
                }
            }
        }
        // Tail partial block (never shared), exactly like allocate_prompt.
        if len % self.block_size != 0 {
            match self.alloc_block() {
                Ok(bid) => table.blocks.push(bid),
                Err(e) => {
                    self.release_uncached(&table);
                    return Err(e);
                }
            }
        }
        table.len = len;
        Ok((table, reused))
    }

    /// Invariant check (used by property tests): every block is in
    /// exactly one of free / cached / referenced, the free list has no
    /// duplicates, cached blocks are refcount-0 AND indexed, and no
    /// hash-index entry points at a freed (or evicted) block.
    pub fn check_invariants(&self) -> Result<()> {
        let mut on_free = vec![false; self.blocks.len()];
        for &f in &self.free {
            if on_free[f as usize] {
                bail!("duplicate free block {f}");
            }
            on_free[f as usize] = true;
            let b = &self.blocks[f as usize];
            if b.refcount != 0 {
                bail!("free block {f} has refcount {}", b.refcount);
            }
            if b.cached {
                bail!("free block {f} is marked cached");
            }
            if b.hash.is_some() {
                bail!("free block {f} still carries a hash");
            }
        }
        let mut cached_count = 0usize;
        for (i, b) in self.blocks.iter().enumerate() {
            let states =
                on_free[i] as usize + b.cached as usize + (b.refcount > 0) as usize;
            if states != 1 {
                bail!(
                    "block {i} in {states} states (free={}, cached={}, refcount={})",
                    on_free[i],
                    b.cached,
                    b.refcount
                );
            }
            if b.cached {
                cached_count += 1;
                let Some(h) = b.hash else {
                    bail!("cached block {i} has no hash");
                };
                if self.prefix_index.get(&h) != Some(&(i as BlockId)) {
                    bail!("cached block {i} not indexed under its hash");
                }
            }
        }
        if cached_count != self.n_cached {
            bail!("n_cached {} but {cached_count} blocks marked cached", self.n_cached);
        }
        for (&h, &bid) in &self.prefix_index {
            let b = &self.blocks[bid as usize];
            if b.hash != Some(h) {
                bail!("index entry {h:#x} points at block {bid} with hash {:?}", b.hash);
            }
            if b.refcount == 0 && !b.cached {
                bail!("index entry {h:#x} points at freed block {bid}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;
    use crate::util::Prng;

    /// The pre-ISSUE-7 behaviour: no cross-request cache.
    fn uncached(n: usize, bs: usize) -> BlockManager {
        BlockManager::with_cache(n, bs, false, EvictionPolicy::Lru)
    }

    #[test]
    fn prompt_allocation_and_release() {
        let mut m = BlockManager::new(10, 4);
        let t = m.allocate_prompt(&[1, 2, 3, 4, 5, 6]).unwrap(); // 2 blocks
        assert_eq!(t.blocks.len(), 2);
        assert_eq!(m.free_blocks(), 8);
        m.release(&t);
        // The hashed full block stays cached; the partial tail frees.
        assert_eq!(m.free_blocks(), 9);
        assert_eq!(m.cached_blocks(), 1);
        assert_eq!(m.reclaimable_blocks(), 10);
        m.check_invariants().unwrap();
    }

    #[test]
    fn append_allocates_at_boundary() {
        let mut m = BlockManager::new(10, 4);
        let mut t = m.allocate_prompt(&[1, 2, 3]).unwrap(); // 1 block, len 3
        assert!(!m.append_token(&mut t).unwrap()); // len 4, fits
        assert!(m.append_token(&mut t).unwrap()); // len 5, new block
        assert_eq!(t.blocks.len(), 2);
        m.release(&t);
        m.check_invariants().unwrap();
    }

    #[test]
    fn prefix_sharing_hits() {
        let mut m = BlockManager::new(10, 4);
        let prompt = [7u32, 8, 9, 10, 11, 12, 13, 14];
        let a = m.allocate_prompt(&prompt).unwrap();
        let used_after_a = m.free_blocks();
        let b = m.allocate_prompt(&prompt).unwrap();
        // Both full blocks shared; no extra allocation.
        assert_eq!(m.free_blocks(), used_after_a);
        assert_eq!(m.prefix_hits, 2);
        assert_eq!(a.blocks, b.blocks);
        m.release(&a);
        m.release(&b);
        m.check_invariants().unwrap();
    }

    #[test]
    fn different_prefix_not_shared() {
        let mut m = BlockManager::new(10, 4);
        let a = m.allocate_prompt(&[1, 2, 3, 4]).unwrap();
        let b = m.allocate_prompt(&[1, 2, 3, 5]).unwrap();
        assert_ne!(a.blocks, b.blocks);
        assert_eq!(m.prefix_hits, 0);
        m.release(&a);
        m.release(&b);
    }

    #[test]
    fn exhaustion_fails_cleanly_and_rolls_back() {
        let mut m = BlockManager::new(2, 4);
        let err = m.allocate_prompt(&(0..20).collect::<Vec<u32>>());
        assert!(err.is_err());
        // Partial allocation must have been rolled back, and the
        // rolled-back blocks must NOT be resurrectable by hash (their
        // content was never computed).
        assert_eq!(m.free_blocks(), 2);
        assert_eq!(m.cached_blocks(), 0);
        m.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_and_releases() {
        let mut m = BlockManager::new(4, 4);
        let a = m.allocate_prompt(&[1, 2, 3, 4, 5]).unwrap();
        let free_before = m.free_blocks();
        let b = m.fork(&a);
        assert_eq!(m.free_blocks(), free_before);
        m.release(&a);
        m.check_invariants().unwrap();
        m.release(&b);
        assert_eq!(m.reclaimable_blocks(), 4);
    }

    #[test]
    fn cow_append_diverges_forked_tail_without_touching_the_sibling() {
        let mut m = BlockManager::new(8, 4);
        let mut a = m.allocate_prompt(&[1, 2, 3, 4, 5, 6]).unwrap(); // [full, partial]
        let mut b = m.fork(&a);
        assert_eq!(a.blocks, b.blocks);
        // First append into the shared partial tail: fork A must move to
        // a private block; B's view is untouched.
        assert!(m.append_token(&mut a).unwrap(), "CoW counts as an allocation");
        assert_eq!(m.cow_copies, 1);
        assert_eq!(a.blocks[0], b.blocks[0], "full prefix block still shared");
        assert_ne!(a.blocks[1], b.blocks[1], "partial tail diverged");
        assert_eq!(b.len, 6, "sibling untouched");
        // B's tail is now exclusively owned: its append is in place.
        assert!(!m.append_token(&mut b).unwrap());
        assert_eq!(m.cow_copies, 1);
        // Further appends on A stay in place until the block boundary.
        assert!(!m.append_token(&mut a).unwrap());
        m.release(&a);
        m.release(&b);
        assert_eq!(m.reclaimable_blocks(), 8);
        m.check_invariants().unwrap();
    }

    #[test]
    fn cow_append_exhaustion_fails_cleanly() {
        let mut m = BlockManager::new(2, 4);
        let mut a = m.allocate_prompt(&[1, 2, 3, 4, 5]).unwrap(); // both blocks
        let mut b = m.fork(&a);
        // No free block for the CoW copy: the append fails and the table
        // is left intact (still shared, same length) so the caller can
        // preempt instead of corrupting the sibling.
        let err = m.append_token(&mut a);
        assert!(err.is_err());
        assert_eq!(a.len, 5);
        assert_eq!(a.blocks, b.blocks);
        assert_eq!(m.cow_copies, 0);
        m.release(&a);
        // With the fork released, the sibling appends in place again.
        assert!(!m.append_token(&mut b).unwrap());
        m.release(&b);
        m.check_invariants().unwrap();
    }

    #[test]
    fn released_prefix_blocks_stay_resident_and_hit() {
        // THE cross-request promotion: after a sequence finishes, a new
        // request with the same prompt re-attaches to its blocks.
        let mut m = BlockManager::new(4, 4);
        let prompt = [1u32, 2, 3, 4];
        let a = m.allocate_prompt(&prompt).unwrap();
        let a_block = a.blocks[0];
        m.release(&a);
        assert_eq!(m.cached_blocks(), 1);
        let (b, leading) = m.allocate_prompt_matched(&prompt).unwrap();
        assert_eq!(m.prefix_hits, 1, "released prefix must hit across requests");
        assert_eq!(leading, 1, "the hit is a leading (prefill-skippable) block");
        assert_eq!(b.blocks[0], a_block, "same physical block resurrected");
        assert_eq!(m.cached_blocks(), 0, "resurrected out of the cached state");
        m.release(&b);
        m.check_invariants().unwrap();
    }

    #[test]
    fn cache_off_restores_release_means_free() {
        let mut m = uncached(4, 4);
        let prompt = [1u32, 2, 3, 4];
        let a = m.allocate_prompt(&prompt).unwrap();
        m.release(&a);
        assert_eq!(m.free_blocks(), 4, "cache off: release frees immediately");
        // The freed block must not be resurrected through the prefix
        // cache: the same content allocates fresh, with no hit recorded.
        let b = m.allocate_prompt(&prompt).unwrap();
        assert_eq!(m.prefix_hits, 0, "freed prefix entry must not hit");
        m.release(&b);
        m.check_invariants().unwrap();
    }

    #[test]
    fn reused_block_sheds_its_stale_prefix_entry() {
        let mut m = BlockManager::new(1, 4); // one block: reuse is forced
        let a = m.allocate_prompt(&[1, 2, 3, 4]).unwrap();
        let a_block = a.blocks[0];
        let a_hash = m.block_hash(a_block).unwrap();
        m.release(&a);
        assert_eq!(m.cached_blocks(), 1);
        // Different content reuses the same physical block (evicting the
        // cached entry)...
        let b = m.allocate_prompt(&[9, 9, 9, 9]).unwrap();
        assert_eq!(b.blocks[0], a_block);
        assert_eq!(m.evictions, 1);
        assert!(
            m.take_retired_hashes().contains(&a_hash),
            "eviction must surface the retired hash for stash invalidation"
        );
        m.release(&b);
        // ...and the original content must now MISS (no aliasing with
        // block contents that were overwritten).
        let c = m.allocate_prompt(&[1, 2, 3, 4]).unwrap();
        assert_eq!(m.prefix_hits, 0);
        m.release(&c);
        m.check_invariants().unwrap();
    }

    #[test]
    fn lru_evicts_the_coldest_cached_block() {
        let mut m = BlockManager::new(2, 2);
        let a = m.allocate_prompt(&[1, 2]).unwrap();
        let b = m.allocate_prompt(&[3, 4]).unwrap();
        let (a0, b0) = (a.blocks[0], b.blocks[0]);
        m.release(&a); // cached, older
        m.release(&b); // cached, newer
        // A new prompt needs one block: LRU evicts A's (the colder one).
        let c = m.allocate_prompt(&[5, 6]).unwrap();
        assert_eq!(c.blocks[0], a0, "LRU must reclaim the coldest block");
        // [3,4] is still resident and hits; [1,2] was evicted.
        let d = m.allocate_prompt(&[3, 4]).unwrap();
        assert_eq!(d.blocks[0], b0);
        assert_eq!(m.prefix_hits, 1);
        m.release(&c);
        m.release(&d);
        m.check_invariants().unwrap();
    }

    #[test]
    fn hit_aware_eviction_protects_hot_prefixes() {
        let mut m = BlockManager::with_cache(2, 2, true, EvictionPolicy::HitAware);
        let hot = m.allocate_prompt(&[1, 2]).unwrap();
        let hot0 = hot.blocks[0];
        let cold = m.allocate_prompt(&[3, 4]).unwrap();
        let cold0 = cold.blocks[0];
        m.release(&hot);
        m.release(&cold);
        // Hit the hot prefix once (resurrect + release again): its hit
        // count now exceeds the cold block's.
        let h2 = m.allocate_prompt(&[1, 2]).unwrap();
        m.release(&h2);
        // Under LRU the hot block would now be the *newer* one too, so
        // make the discriminating case explicit: hits 1 vs 0.
        let c = m.allocate_prompt(&[5, 6]).unwrap();
        assert_eq!(c.blocks[0], cold0, "hit-aware must sacrifice the zero-hit block");
        let again = m.allocate_prompt(&[1, 2]).unwrap();
        assert_eq!(again.blocks[0], hot0, "the hot prefix survived");
        m.release(&c);
        m.release(&again);
        m.check_invariants().unwrap();
    }

    #[test]
    fn matched_leading_blocks_stop_at_the_first_miss() {
        let mut m = BlockManager::new(16, 4);
        let a = m.allocate_prompt(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]).unwrap();
        m.release(&a);
        // Same first 2 blocks, divergent third: leading match = 2.
        let (b, leading) =
            m.allocate_prompt_matched(&[1, 2, 3, 4, 5, 6, 7, 8, 99, 98, 97, 96]).unwrap();
        assert_eq!(leading, 2);
        assert_eq!(m.prefix_hits, 2);
        // Cold allocations count as misses too: 3 for prompt A, 1 for
        // prompt B's divergent third block.
        assert_eq!(m.prefix_misses, 4);
        m.release(&b);
        m.check_invariants().unwrap();
    }

    #[test]
    fn flush_cache_frees_every_cached_block() {
        let mut m = BlockManager::new(8, 4);
        let a = m.allocate_prompt(&[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        m.release(&a);
        assert_eq!(m.cached_blocks(), 2);
        assert_eq!(m.flush_cache(), 2);
        assert_eq!(m.cached_blocks(), 0);
        assert_eq!(m.free_blocks(), 8);
        let retired = m.take_retired_hashes();
        assert_eq!(retired.len(), 2);
        // Flushed content misses afterwards.
        let b = m.allocate_prompt(&[1, 2, 3, 4]).unwrap();
        assert_eq!(m.prefix_hits, 0);
        m.release(&b);
        m.check_invariants().unwrap();
    }

    #[test]
    fn live_prefix_block_still_shares_while_forks_exist() {
        // Fork + prefix sharing interact: the full block of a live prompt
        // is shared by hash, while fork shares the whole table.
        let mut m = BlockManager::new(8, 4);
        let a = m.allocate_prompt(&[7, 7, 7, 7, 1]).unwrap();
        let f = m.fork(&a);
        let b = m.allocate_prompt(&[7, 7, 7, 7, 2]).unwrap();
        assert_eq!(m.prefix_hits, 1, "full block shared by content hash");
        assert_eq!(a.blocks[0], b.blocks[0]);
        assert_ne!(a.blocks[1], b.blocks[1], "tails are private per prompt");
        m.release(&a);
        m.release(&f);
        m.release(&b);
        assert_eq!(m.reclaimable_blocks(), 8);
        m.check_invariants().unwrap();
    }

    #[test]
    fn block_hashes_match_the_manager_assignment() {
        let prompt: Vec<u32> = (0..10).collect();
        let hs = block_hashes(4, &prompt);
        assert_eq!(hs.len(), 2);
        let mut m = BlockManager::new(8, 4);
        let t = m.allocate_prompt(&prompt).unwrap();
        assert_eq!(m.block_hash(t.blocks[0]), Some(hs[0]));
        assert_eq!(m.block_hash(t.blocks[1]), Some(hs[1]));
        assert_eq!(m.block_hash(t.blocks[2]), None, "partial tail is unhashed");
        assert!(m.is_resident(hs[0]));
        m.release(&t);
        assert!(m.is_resident(hs[0]), "released blocks stay resident (cached)");
    }

    #[test]
    fn export_import_roundtrip_dedups_resident_prefix_blocks() {
        let mut src = BlockManager::new(16, 4);
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8, 9]; // 2 full blocks + tail
        let t = src.allocate_prompt(&prompt).unwrap();
        let ex = src.export_seq(&t);
        assert_eq!(ex.len, 9);
        assert_eq!(ex.full_hashes.len(), 2);
        assert!(ex.full_hashes.iter().all(|h| h.is_some()));
        src.release(&t);

        // First import into a fresh pool: no resident prefixes, all blocks
        // allocated fresh (3 of them), hashes registered.
        let mut dst = BlockManager::new(16, 4);
        let (a, reused_a) = dst.import_seq(&ex).unwrap();
        assert_eq!(reused_a, 0);
        assert_eq!(a.blocks.len(), 3);
        assert_eq!(dst.free_blocks(), 13);
        // Second import of the same prefix: the full blocks dedup against
        // the now-resident copies — only the tail allocates.
        let (b, reused_b) = dst.import_seq(&ex).unwrap();
        assert_eq!(reused_b, 2, "full prefix blocks must be reused, not re-sent");
        assert_eq!(dst.free_blocks(), 12, "only the tail block is new");
        assert_eq!(a.blocks[..2], b.blocks[..2]);
        assert_ne!(a.blocks[2], b.blocks[2], "tails stay private");
        assert_eq!(dst.prefix_hits, 2);
        dst.release(&a);
        dst.release(&b);
        assert_eq!(dst.reclaimable_blocks(), 16);
        dst.check_invariants().unwrap();
    }

    #[test]
    fn import_dedups_against_a_cached_released_sequence() {
        // Cross-request sharing across the import path too: the pool
        // served (and released) a sequence with this prefix; the import
        // re-attaches to the cached blocks.
        let mut src = BlockManager::new(8, 4);
        let prompt = [7u32, 8, 9, 10, 11];
        let t0 = src.allocate_prompt(&prompt).unwrap();
        let ex = src.export_seq(&t0);
        let mut dst = BlockManager::new(8, 4);
        let local = dst.allocate_prompt(&prompt).unwrap();
        let local_block = local.blocks[0];
        dst.release(&local); // cached, not freed
        let (imported, reused) = dst.import_seq(&ex).unwrap();
        assert_eq!(reused, 1);
        assert_eq!(local_block, imported.blocks[0]);
        dst.release(&imported);
        dst.check_invariants().unwrap();
    }

    #[test]
    fn import_exhaustion_rolls_back_cleanly() {
        let mut src = BlockManager::new(8, 4);
        let t = src.allocate_prompt(&(0..20).collect::<Vec<u32>>()).unwrap(); // 5 blocks
        let ex = src.export_seq(&t);
        let mut dst = BlockManager::new(2, 4);
        assert!(dst.import_seq(&ex).is_err());
        assert_eq!(dst.free_blocks(), 2, "partial import must roll back");
        assert_eq!(dst.cached_blocks(), 0, "rolled-back blocks are not resurrectable");
        dst.check_invariants().unwrap();
    }

    #[test]
    fn import_across_block_geometries_lands_without_dedup() {
        let mut src = BlockManager::new(8, 4);
        let t = src.allocate_prompt(&[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        let ex = src.export_seq(&t);
        let mut dst = BlockManager::new(8, 2); // different block size
        let (a, reused) = dst.import_seq(&ex).unwrap();
        assert_eq!(reused, 0);
        assert_eq!(a.blocks.len(), 4, "8 tokens at block size 2... re-blocked");
        let (b, reused_b) = dst.import_seq(&ex).unwrap();
        assert_eq!(reused_b, 0, "foreign-geometry hashes must never alias");
        dst.release(&a);
        dst.release(&b);
        dst.check_invariants().unwrap();
    }

    #[test]
    fn export_of_decode_grown_table_has_unhashed_tail_blocks() {
        let mut m = BlockManager::new(8, 2);
        let mut t = m.allocate_prompt(&[1, 2]).unwrap(); // 1 full (hashed) block
        m.append_token(&mut t).unwrap(); // new block at the boundary
        m.append_token(&mut t).unwrap(); // fills it — but decode-grown: no hash
        let ex = m.export_seq(&t);
        assert_eq!(ex.full_hashes.len(), 2);
        assert!(ex.full_hashes[0].is_some());
        assert!(ex.full_hashes[1].is_none(), "decode-grown block carries no hash");
        // Import still works; the unhashed block just never dedups.
        let (i1, r1) = m.import_seq(&ex).unwrap();
        assert_eq!(r1, 1, "only the prompt's hashed block is shared");
        m.release(&t);
        m.release(&i1);
        m.check_invariants().unwrap();
    }

    /// Drain a manager completely (cache included) and assert nothing
    /// leaked.
    fn assert_drains_clean(m: &mut BlockManager, live: &mut Vec<BlockTable>) {
        for t in live.drain(..) {
            m.release(&t);
        }
        assert_eq!(m.reclaimable_blocks(), m.n_blocks(), "leak after full release");
        m.flush_cache();
        assert_eq!(m.free_blocks(), m.n_blocks(), "flush must free every cached block");
        m.check_invariants().unwrap();
    }

    #[test]
    fn prop_export_import_interleavings_preserve_invariants() {
        // Satellite property: random allocate/append/fork/release/export/
        // import interleavings never violate refcount/CoW/free-list/cache
        // invariants, and everything released returns the pool to full.
        quick("kv_export_import_invariants", |rng: &mut Prng| {
            let mut m = BlockManager::new(rng.range(6, 28), rng.range(2, 6));
            let mut live: Vec<BlockTable> = vec![];
            let mut exports: Vec<KvSeqExport> = vec![];
            for _ in 0..rng.range(1, 60) {
                match rng.range(0, 5) {
                    0 => {
                        let n = rng.range(1, 20);
                        let toks: Vec<u32> = (0..n).map(|_| rng.below(6) as u32).collect();
                        if let Ok(t) = m.allocate_prompt(&toks) {
                            live.push(t);
                        }
                    }
                    1 if !live.is_empty() => {
                        let i = rng.range(0, live.len() - 1);
                        let f = m.fork(&live[i]);
                        live.push(f);
                    }
                    2 if !live.is_empty() => {
                        let i = rng.range(0, live.len() - 1);
                        let t = live.swap_remove(i);
                        m.release(&t);
                    }
                    3 if !live.is_empty() => {
                        // Export a live table (sometimes releasing the
                        // original right away, like a prefill handoff).
                        let i = rng.range(0, live.len() - 1);
                        exports.push(m.export_seq(&live[i]));
                        if rng.bool(0.5) {
                            let t = live.swap_remove(i);
                            m.release(&t);
                        }
                    }
                    4 if !exports.is_empty() => {
                        let i = rng.range(0, exports.len() - 1);
                        if let Ok((t, _)) = m.import_seq(&exports[i]) {
                            live.push(t);
                        }
                    }
                    _ => {
                        if let Some(t) = live.last_mut() {
                            let _ = m.append_token(t);
                        }
                    }
                }
                m.check_invariants().unwrap();
            }
            assert_drains_clean(&mut m, &mut live);
        });
    }

    #[test]
    fn prop_forked_appends_preserve_invariants() {
        quick("kv_cow_invariants", |rng: &mut Prng| {
            let mut m = BlockManager::new(rng.range(6, 24), rng.range(2, 6));
            let mut live: Vec<BlockTable> = vec![];
            for _ in 0..rng.range(1, 50) {
                match rng.range(0, 3) {
                    0 => {
                        let n = rng.range(1, 20);
                        let toks: Vec<u32> = (0..n).map(|_| rng.below(6) as u32).collect();
                        if let Ok(t) = m.allocate_prompt(&toks) {
                            live.push(t);
                        }
                    }
                    1 if !live.is_empty() => {
                        let i = rng.range(0, live.len() - 1);
                        let f = m.fork(&live[i]);
                        live.push(f);
                    }
                    2 if !live.is_empty() => {
                        let i = rng.range(0, live.len() - 1);
                        let t = live.swap_remove(i);
                        m.release(&t);
                    }
                    _ => {
                        if let Some(t) = live.last_mut() {
                            let _ = m.append_token(t);
                        }
                    }
                }
                m.check_invariants().unwrap();
            }
            assert_drains_clean(&mut m, &mut live);
        });
    }

    #[test]
    fn prop_alloc_free_never_leaks() {
        quick("kv_no_leak", |rng: &mut Prng| {
            let mut m = BlockManager::new(rng.range(4, 32), rng.range(1, 8));
            let mut live: Vec<BlockTable> = vec![];
            for _ in 0..rng.range(1, 60) {
                match rng.range(0, 2) {
                    0 => {
                        let n = rng.range(1, 30);
                        let toks: Vec<u32> = (0..n).map(|_| rng.below(50) as u32).collect();
                        if let Ok(t) = m.allocate_prompt(&toks) {
                            live.push(t);
                        }
                    }
                    1 if !live.is_empty() => {
                        let i = rng.range(0, live.len() - 1);
                        let t = live.swap_remove(i);
                        m.release(&t);
                    }
                    _ => {
                        if let Some(t) = live.last_mut() {
                            let _ = m.append_token(t);
                        }
                    }
                }
                m.check_invariants().unwrap();
            }
            assert_drains_clean(&mut m, &mut live);
        });
    }

    #[test]
    fn prop_prefix_cache_consistent_with_content() {
        quick("kv_prefix_consistency", |rng: &mut Prng| {
            let bs = 4;
            let mut m = BlockManager::new(64, bs);
            // Same content must share, different must not (while blocks live).
            let n = rng.range(1, 4) * bs;
            let toks: Vec<u32> = (0..n).map(|_| rng.below(10) as u32).collect();
            let a = m.allocate_prompt(&toks).unwrap();
            let b = m.allocate_prompt(&toks).unwrap();
            assert_eq!(a.blocks[..n / bs], b.blocks[..n / bs]);
            let mut other = toks.clone();
            other[0] ^= 1;
            let c = m.allocate_prompt(&other).unwrap();
            assert_ne!(a.blocks[0], c.blocks[0]);
            m.release(&a);
            m.release(&b);
            m.release(&c);
            m.check_invariants().unwrap();
        });
    }

    #[test]
    fn prop_cross_request_sharing_with_cancel_interleavings() {
        // ISSUE 7 satellite: cross-sequence prefix-attach + randomized
        // cancel (release-at-any-point) interleavings under memory
        // pressure and both eviction policies.  Asserts, at every step:
        // refcount/state invariants hold, the hash index never points at
        // a freed or evicted block (check_invariants), retired hashes
        // are really gone from the index, and hits only ever attach to
        // resident blocks whose content chain matches.
        quick("kv_cross_request_cancel", |rng: &mut Prng| {
            let bs = rng.range(2, 4);
            let policy =
                if rng.bool(0.5) { EvictionPolicy::Lru } else { EvictionPolicy::HitAware };
            // Small pools force eviction pressure.
            let mut m = BlockManager::with_cache(rng.range(4, 16), bs, true, policy);
            // A few hot prefixes shared across requests, plus cold tails.
            let hot: Vec<Vec<u32>> = (0..rng.range(1, 3))
                .map(|k| (0..2 * bs).map(|i| (100 * (k + 1) + i) as u32).collect())
                .collect();
            let mut live: Vec<BlockTable> = vec![];
            let mut retired_seen: Vec<u64> = vec![];
            for _ in 0..rng.range(10, 80) {
                match rng.range(0, 4) {
                    // Cross-sequence prefix-attach: hot prefix + unique tail.
                    0 => {
                        let mut toks = hot[rng.range(0, hot.len() - 1)].clone();
                        for _ in 0..rng.range(0, 2 * bs) {
                            toks.push(rng.below(1000) as u32 + 5000);
                        }
                        if let Ok((t, leading)) = m.allocate_prompt_matched(&toks) {
                            assert!(leading <= toks.len() / bs);
                            live.push(t);
                        }
                    }
                    // Cold request.
                    1 => {
                        let n = rng.range(1, 3 * bs);
                        let toks: Vec<u32> =
                            (0..n).map(|_| rng.below(4000) as u32 + 10_000).collect();
                        if let Ok(t) = m.allocate_prompt(&toks) {
                            live.push(t);
                        }
                    }
                    // Cancel: release a random live table mid-anything.
                    2 if !live.is_empty() => {
                        let i = rng.range(0, live.len() - 1);
                        let t = live.swap_remove(i);
                        m.release(&t);
                    }
                    // Decode progress on a random live table.
                    _ => {
                        if !live.is_empty() {
                            let i = rng.range(0, live.len() - 1);
                            let _ = m.append_token(&mut live[i]);
                        }
                    }
                }
                m.check_invariants().unwrap();
                // Retirements surface for stash invalidation.  A retired
                // hash MAY be re-registered later (same content allocated
                // fresh after its cached copy was evicted) — dropping the
                // stash entry is conservative, never wrong — so the only
                // hard guarantee is index consistency, checked above.
                retired_seen.extend(m.take_retired_hashes());
            }
            assert_drains_clean(&mut m, &mut live);
            // With every block freed, nothing is resident — every hash
            // ever retired must be gone from the index.
            for h in &retired_seen {
                assert!(!m.is_resident(*h), "hash {h:#x} resident after flush");
            }
        });
    }
}
