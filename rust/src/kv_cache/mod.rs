//! Paged KV-cache manager (vLLM-style, paper §3.3 "KV manager").
//!
//! Tracks device KV memory in fixed-size token blocks with reference
//! counting, copy-on-write forking, and hash-based prefix sharing.  The
//! AR scheduler consults it for admission (a sequence runs only while its
//! blocks fit the stage's KV budget) and preemption.
//!
//! Note on fidelity: the compiled decode executables hold KV densely per
//! batch slot (HLO shapes are static), so the block table is the
//! *accounting* layer — exactly the admission/preemption role vLLM's
//! block manager plays — while the per-slot dense tensors are the storage
//! layer.  See DESIGN.md §6.

use std::collections::HashMap;

use anyhow::{bail, Result};

pub type BlockId = u32;

/// Content hash chain for prefix sharing: hash of (parent_hash, tokens).
fn chain_hash(parent: u64, tokens: &[u32]) -> u64 {
    let mut h = parent ^ 0x9E3779B97F4A7C15;
    for &t in tokens {
        h ^= t as u64;
        h = h.wrapping_mul(0x100000001B3);
        h ^= h >> 29;
    }
    h
}

#[derive(Debug, Clone)]
struct Block {
    refcount: u32,
    /// Prefix hash when the block is full and shareable.
    hash: Option<u64>,
}

/// Per-sequence block table.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    pub blocks: Vec<BlockId>,
    /// Tokens stored so far.
    pub len: usize,
}

/// Serializable accounting state of one sequence's block table — the
/// block-level half of a [`crate::kv_transfer::KvHandoff`].  Carries the
/// prefix chain hash of every *full* block so an importing pool can
/// deduplicate against blocks it already holds (hash-based prefix
/// sharing across the prefill/decode boundary) instead of allocating
/// fresh ones.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KvSeqExport {
    /// Exporter's block size (hashes only transfer between pools with
    /// the same geometry).
    pub block_size: u32,
    /// Tokens resident in the exported cache.
    pub len: u64,
    /// One entry per full block: the prefix chain hash when the block is
    /// shareable (`None` for blocks grown past the prompt by decode
    /// appends, which never carry a hash).
    pub full_hashes: Vec<Option<u64>>,
}

/// The paged allocator for one stage's KV pool.
#[derive(Debug)]
pub struct BlockManager {
    block_size: usize,
    blocks: Vec<Block>,
    free: Vec<BlockId>,
    /// full-block prefix hash -> block id (prefix cache).
    prefix_index: HashMap<u64, BlockId>,
    /// cache hits since creation (metrics).
    pub prefix_hits: u64,
    /// Copy-on-write tail copies triggered by appends to forked tables
    /// (metrics; each one stands for a device-side block copy).
    pub cow_copies: u64,
}

impl BlockManager {
    pub fn new(n_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && n_blocks > 0);
        Self {
            block_size,
            blocks: vec![Block { refcount: 0, hash: None }; n_blocks],
            free: (0..n_blocks as BlockId).rev().collect(),
            prefix_index: HashMap::new(),
            prefix_hits: 0,
            cow_copies: 0,
        }
    }

    /// Build a manager sized from a byte budget.
    pub fn from_bytes(budget_bytes: usize, bytes_per_token: usize, block_size: usize) -> Self {
        let tokens = budget_bytes / bytes_per_token.max(1);
        let n_blocks = (tokens / block_size).max(1);
        Self::new(n_blocks, block_size)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_needed(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Can a sequence of `tokens` total tokens be admitted right now?
    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_needed(tokens) <= self.free.len()
    }

    fn pop_free(&mut self) -> Result<BlockId> {
        let Some(id) = self.free.pop() else { bail!("KV pool exhausted") };
        let b = &mut self.blocks[id as usize];
        debug_assert_eq!(b.refcount, 0);
        b.refcount = 1;
        // Block content is being rewritten; drop any stale prefix entry.
        if let Some(h) = b.hash.take() {
            if self.prefix_index.get(&h) == Some(&id) {
                self.prefix_index.remove(&h);
            }
        }
        Ok(id)
    }

    /// Allocate a table for a prompt, reusing shared full-block prefixes
    /// when the token content matches (prefix caching).
    pub fn allocate_prompt(&mut self, tokens: &[u32]) -> Result<BlockTable> {
        let mut table = BlockTable::default();
        let mut parent = 0u64;
        let mut i = 0;
        // Full blocks: try the prefix cache first.
        while i + self.block_size <= tokens.len() {
            let h = chain_hash(parent, &tokens[i..i + self.block_size]);
            if let Some(&bid) = self.prefix_index.get(&h) {
                self.blocks[bid as usize].refcount += 1;
                self.prefix_hits += 1;
                table.blocks.push(bid);
            } else {
                match self.pop_free() {
                    Ok(bid) => {
                        self.blocks[bid as usize].hash = Some(h);
                        self.prefix_index.insert(h, bid);
                        table.blocks.push(bid);
                    }
                    Err(e) => {
                        self.release(&table);
                        return Err(e);
                    }
                }
            }
            parent = h;
            i += self.block_size;
        }
        // Tail partial block (never shared).
        if i < tokens.len() {
            match self.pop_free() {
                Ok(bid) => table.blocks.push(bid),
                Err(e) => {
                    self.release(&table);
                    return Err(e);
                }
            }
        }
        table.len = tokens.len();
        Ok(table)
    }

    /// Extend a table by one generated token, allocating a block at the
    /// boundary.  Returns true if a new block was allocated.
    ///
    /// Copy-on-write: when the partial tail block is shared (the table
    /// was [`fork`](Self::fork)ed, or is a fork's sibling), the append
    /// must not mutate the shared copy — the tail moves to a private
    /// block first (counted in [`Self::cow_copies`]; each one stands for
    /// a device-side block copy).
    pub fn append_token(&mut self, table: &mut BlockTable) -> Result<bool> {
        if table.len % self.block_size == 0 {
            let bid = self.pop_free()?;
            table.blocks.push(bid);
            table.len += 1;
            return Ok(true);
        }
        let tail = *table.blocks.last().expect("partial tail implies a block");
        if self.blocks[tail as usize].refcount > 1 {
            // On exhaustion the error propagates with the table intact
            // (len unchanged, tail still shared) — callers can preempt.
            let fresh = self.pop_free()?;
            self.blocks[tail as usize].refcount -= 1;
            self.cow_copies += 1;
            *table.blocks.last_mut().expect("checked above") = fresh;
            table.len += 1;
            return Ok(true);
        }
        table.len += 1;
        Ok(false)
    }

    /// Copy-on-write fork (e.g. beam/parallel sampling): shares all
    /// blocks, bumping refcounts.
    pub fn fork(&mut self, table: &BlockTable) -> BlockTable {
        for &bid in &table.blocks {
            self.blocks[bid as usize].refcount += 1;
        }
        table.clone()
    }

    /// Release a table (sequence finished or preempted).
    pub fn release(&mut self, table: &BlockTable) {
        for &bid in &table.blocks {
            let b = &mut self.blocks[bid as usize];
            assert!(b.refcount > 0, "double free of block {bid}");
            b.refcount -= 1;
            if b.refcount == 0 {
                // A freed block must not be resurrected through the prefix
                // cache while it sits on the free list.
                if let Some(h) = b.hash.take() {
                    if self.prefix_index.get(&h) == Some(&bid) {
                        self.prefix_index.remove(&h);
                    }
                }
                self.free.push(bid);
            }
        }
    }

    /// Export a sequence's block accounting for a KV handoff
    /// (prefill/decode disaggregation, paper §3.4): the full blocks'
    /// prefix hashes travel with the payload so the importing pool can
    /// reuse already-resident prefix blocks.  Does not mutate the pool —
    /// the caller releases the table when the handoff is sent.
    pub fn export_seq(&self, table: &BlockTable) -> KvSeqExport {
        let full = table.len / self.block_size;
        KvSeqExport {
            block_size: self.block_size as u32,
            len: table.len as u64,
            full_hashes: table
                .blocks
                .iter()
                .take(full)
                .map(|&bid| self.blocks[bid as usize].hash)
                .collect(),
        }
    }

    /// Import an exported sequence into this pool, reusing hash-matched
    /// resident prefix blocks (each reuse counts as a [`Self::prefix_hits`]
    /// and is returned in the reuse count — those blocks' contents are
    /// already device-resident and need no re-send).  Freshly allocated
    /// full blocks register their hash so *later* imports of the same
    /// prefix dedup against them.  On pool exhaustion the partial import
    /// is rolled back and the error propagates (the caller re-queues).
    pub fn import_seq(&mut self, ex: &KvSeqExport) -> Result<(BlockTable, usize)> {
        let len = ex.len as usize;
        let full = len / self.block_size;
        // Hash chains are per-geometry: a different block size means no
        // dedup, but the import still lands (fresh blocks throughout).
        let same_geometry = ex.block_size as usize == self.block_size;
        if same_geometry && ex.full_hashes.len() != full {
            bail!(
                "kv import: {} full-block hashes but {len} tokens need {full} full blocks",
                ex.full_hashes.len()
            );
        }
        let mut table = BlockTable::default();
        let mut reused = 0usize;
        for i in 0..full {
            let h = if same_geometry { ex.full_hashes[i] } else { None };
            if let Some(h) = h {
                if let Some(&bid) = self.prefix_index.get(&h) {
                    self.blocks[bid as usize].refcount += 1;
                    self.prefix_hits += 1;
                    reused += 1;
                    table.blocks.push(bid);
                    continue;
                }
            }
            match self.pop_free() {
                Ok(bid) => {
                    if let Some(h) = h {
                        self.blocks[bid as usize].hash = Some(h);
                        self.prefix_index.insert(h, bid);
                    }
                    table.blocks.push(bid);
                }
                Err(e) => {
                    self.release(&table);
                    return Err(e);
                }
            }
        }
        // Tail partial block (never shared), exactly like allocate_prompt.
        if len % self.block_size != 0 {
            match self.pop_free() {
                Ok(bid) => table.blocks.push(bid),
                Err(e) => {
                    self.release(&table);
                    return Err(e);
                }
            }
        }
        table.len = len;
        Ok((table, reused))
    }

    /// Invariant check (used by property tests): every block is either
    /// free xor referenced, and the free list has no duplicates.
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = vec![false; self.blocks.len()];
        for &f in &self.free {
            if seen[f as usize] {
                bail!("duplicate free block {f}");
            }
            seen[f as usize] = true;
            if self.blocks[f as usize].refcount != 0 {
                bail!("free block {f} has refcount {}", self.blocks[f as usize].refcount);
            }
        }
        for (i, b) in self.blocks.iter().enumerate() {
            if b.refcount == 0 && !seen[i] {
                bail!("leaked block {i} (refcount 0 but not free)");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;
    use crate::util::Prng;

    #[test]
    fn prompt_allocation_and_release() {
        let mut m = BlockManager::new(10, 4);
        let t = m.allocate_prompt(&[1, 2, 3, 4, 5, 6]).unwrap(); // 2 blocks
        assert_eq!(t.blocks.len(), 2);
        assert_eq!(m.free_blocks(), 8);
        m.release(&t);
        assert_eq!(m.free_blocks(), 10);
        m.check_invariants().unwrap();
    }

    #[test]
    fn append_allocates_at_boundary() {
        let mut m = BlockManager::new(10, 4);
        let mut t = m.allocate_prompt(&[1, 2, 3]).unwrap(); // 1 block, len 3
        assert!(!m.append_token(&mut t).unwrap()); // len 4, fits
        assert!(m.append_token(&mut t).unwrap()); // len 5, new block
        assert_eq!(t.blocks.len(), 2);
        m.release(&t);
        m.check_invariants().unwrap();
    }

    #[test]
    fn prefix_sharing_hits() {
        let mut m = BlockManager::new(10, 4);
        let prompt = [7u32, 8, 9, 10, 11, 12, 13, 14];
        let a = m.allocate_prompt(&prompt).unwrap();
        let used_after_a = m.free_blocks();
        let b = m.allocate_prompt(&prompt).unwrap();
        // Both full blocks shared; no extra allocation.
        assert_eq!(m.free_blocks(), used_after_a);
        assert_eq!(m.prefix_hits, 2);
        assert_eq!(a.blocks, b.blocks);
        m.release(&a);
        m.release(&b);
        m.check_invariants().unwrap();
    }

    #[test]
    fn different_prefix_not_shared() {
        let mut m = BlockManager::new(10, 4);
        let a = m.allocate_prompt(&[1, 2, 3, 4]).unwrap();
        let b = m.allocate_prompt(&[1, 2, 3, 5]).unwrap();
        assert_ne!(a.blocks, b.blocks);
        assert_eq!(m.prefix_hits, 0);
        m.release(&a);
        m.release(&b);
    }

    #[test]
    fn exhaustion_fails_cleanly_and_rolls_back() {
        let mut m = BlockManager::new(2, 4);
        let err = m.allocate_prompt(&(0..20).collect::<Vec<u32>>());
        assert!(err.is_err());
        // Partial allocation must have been rolled back.
        assert_eq!(m.free_blocks(), 2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_and_releases() {
        let mut m = BlockManager::new(4, 4);
        let a = m.allocate_prompt(&[1, 2, 3, 4, 5]).unwrap();
        let free_before = m.free_blocks();
        let b = m.fork(&a);
        assert_eq!(m.free_blocks(), free_before);
        m.release(&a);
        m.check_invariants().unwrap();
        m.release(&b);
        assert_eq!(m.free_blocks(), 4);
    }

    #[test]
    fn cow_append_diverges_forked_tail_without_touching_the_sibling() {
        let mut m = BlockManager::new(8, 4);
        let mut a = m.allocate_prompt(&[1, 2, 3, 4, 5, 6]).unwrap(); // [full, partial]
        let mut b = m.fork(&a);
        assert_eq!(a.blocks, b.blocks);
        // First append into the shared partial tail: fork A must move to
        // a private block; B's view is untouched.
        assert!(m.append_token(&mut a).unwrap(), "CoW counts as an allocation");
        assert_eq!(m.cow_copies, 1);
        assert_eq!(a.blocks[0], b.blocks[0], "full prefix block still shared");
        assert_ne!(a.blocks[1], b.blocks[1], "partial tail diverged");
        assert_eq!(b.len, 6, "sibling untouched");
        // B's tail is now exclusively owned: its append is in place.
        assert!(!m.append_token(&mut b).unwrap());
        assert_eq!(m.cow_copies, 1);
        // Further appends on A stay in place until the block boundary.
        assert!(!m.append_token(&mut a).unwrap());
        m.release(&a);
        m.release(&b);
        assert_eq!(m.free_blocks(), 8);
        m.check_invariants().unwrap();
    }

    #[test]
    fn cow_append_exhaustion_fails_cleanly() {
        let mut m = BlockManager::new(2, 4);
        let mut a = m.allocate_prompt(&[1, 2, 3, 4, 5]).unwrap(); // both blocks
        let mut b = m.fork(&a);
        // No free block for the CoW copy: the append fails and the table
        // is left intact (still shared, same length) so the caller can
        // preempt instead of corrupting the sibling.
        let err = m.append_token(&mut a);
        assert!(err.is_err());
        assert_eq!(a.len, 5);
        assert_eq!(a.blocks, b.blocks);
        assert_eq!(m.cow_copies, 0);
        m.release(&a);
        // With the fork released, the sibling appends in place again.
        assert!(!m.append_token(&mut b).unwrap());
        m.release(&b);
        m.check_invariants().unwrap();
    }

    #[test]
    fn released_prefix_blocks_are_evicted_from_the_cache() {
        let mut m = BlockManager::new(4, 4);
        let prompt = [1u32, 2, 3, 4];
        let a = m.allocate_prompt(&prompt).unwrap();
        m.release(&a);
        // The freed block must not be resurrected through the prefix
        // cache: the same content allocates fresh, with no hit recorded.
        let b = m.allocate_prompt(&prompt).unwrap();
        assert_eq!(m.prefix_hits, 0, "freed prefix entry must not hit");
        m.release(&b);
        m.check_invariants().unwrap();
    }

    #[test]
    fn reused_block_sheds_its_stale_prefix_entry() {
        let mut m = BlockManager::new(1, 4); // one block: reuse is forced
        let a = m.allocate_prompt(&[1, 2, 3, 4]).unwrap();
        let a_block = a.blocks[0];
        m.release(&a);
        // Different content reuses the same physical block...
        let b = m.allocate_prompt(&[9, 9, 9, 9]).unwrap();
        assert_eq!(b.blocks[0], a_block);
        m.release(&b);
        // ...and the original content must now MISS (no aliasing with
        // block contents that were overwritten).
        let c = m.allocate_prompt(&[1, 2, 3, 4]).unwrap();
        assert_eq!(m.prefix_hits, 0);
        m.release(&c);
        m.check_invariants().unwrap();
    }

    #[test]
    fn live_prefix_block_still_shares_while_forks_exist() {
        // Fork + prefix sharing interact: the full block of a live prompt
        // is shared by hash, while fork shares the whole table.
        let mut m = BlockManager::new(8, 4);
        let a = m.allocate_prompt(&[7, 7, 7, 7, 1]).unwrap();
        let f = m.fork(&a);
        let b = m.allocate_prompt(&[7, 7, 7, 7, 2]).unwrap();
        assert_eq!(m.prefix_hits, 1, "full block shared by content hash");
        assert_eq!(a.blocks[0], b.blocks[0]);
        assert_ne!(a.blocks[1], b.blocks[1], "tails are private per prompt");
        m.release(&a);
        m.release(&f);
        m.release(&b);
        assert_eq!(m.free_blocks(), 8);
        m.check_invariants().unwrap();
    }

    #[test]
    fn export_import_roundtrip_dedups_resident_prefix_blocks() {
        let mut src = BlockManager::new(16, 4);
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8, 9]; // 2 full blocks + tail
        let t = src.allocate_prompt(&prompt).unwrap();
        let ex = src.export_seq(&t);
        assert_eq!(ex.len, 9);
        assert_eq!(ex.full_hashes.len(), 2);
        assert!(ex.full_hashes.iter().all(|h| h.is_some()));
        src.release(&t);

        // First import into a fresh pool: no resident prefixes, all blocks
        // allocated fresh (3 of them), hashes registered.
        let mut dst = BlockManager::new(16, 4);
        let (a, reused_a) = dst.import_seq(&ex).unwrap();
        assert_eq!(reused_a, 0);
        assert_eq!(a.blocks.len(), 3);
        assert_eq!(dst.free_blocks(), 13);
        // Second import of the same prefix: the full blocks dedup against
        // the now-resident copies — only the tail allocates.
        let (b, reused_b) = dst.import_seq(&ex).unwrap();
        assert_eq!(reused_b, 2, "full prefix blocks must be reused, not re-sent");
        assert_eq!(dst.free_blocks(), 12, "only the tail block is new");
        assert_eq!(a.blocks[..2], b.blocks[..2]);
        assert_ne!(a.blocks[2], b.blocks[2], "tails stay private");
        assert_eq!(dst.prefix_hits, 2);
        dst.release(&a);
        dst.release(&b);
        assert_eq!(dst.free_blocks(), 16);
        dst.check_invariants().unwrap();
    }

    #[test]
    fn import_dedups_against_a_live_local_prompt() {
        // The importing pool already serves a sequence with the same
        // prompt prefix (allocated locally): the import shares its full
        // blocks through the same hash index.
        let mut src = BlockManager::new(8, 4);
        let prompt = [7u32, 8, 9, 10, 11];
        let t0 = src.allocate_prompt(&prompt).unwrap();
        let t = src.export_seq(&t0);
        let mut dst = BlockManager::new(8, 4);
        let local = dst.allocate_prompt(&prompt).unwrap();
        let (imported, reused) = dst.import_seq(&t).unwrap();
        assert_eq!(reused, 1);
        assert_eq!(local.blocks[0], imported.blocks[0]);
        dst.release(&local);
        dst.release(&imported);
        dst.check_invariants().unwrap();
    }

    #[test]
    fn import_exhaustion_rolls_back_cleanly() {
        let mut src = BlockManager::new(8, 4);
        let t = src.allocate_prompt(&(0..20).collect::<Vec<u32>>()).unwrap(); // 5 blocks
        let ex = src.export_seq(&t);
        let mut dst = BlockManager::new(2, 4);
        assert!(dst.import_seq(&ex).is_err());
        assert_eq!(dst.free_blocks(), 2, "partial import must roll back");
        dst.check_invariants().unwrap();
    }

    #[test]
    fn import_across_block_geometries_lands_without_dedup() {
        let mut src = BlockManager::new(8, 4);
        let t = src.allocate_prompt(&[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        let ex = src.export_seq(&t);
        let mut dst = BlockManager::new(8, 2); // different block size
        let (a, reused) = dst.import_seq(&ex).unwrap();
        assert_eq!(reused, 0);
        assert_eq!(a.blocks.len(), 4, "8 tokens at block size 2... re-blocked");
        let (b, reused_b) = dst.import_seq(&ex).unwrap();
        assert_eq!(reused_b, 0, "foreign-geometry hashes must never alias");
        dst.release(&a);
        dst.release(&b);
        dst.check_invariants().unwrap();
    }

    #[test]
    fn export_of_decode_grown_table_has_unhashed_tail_blocks() {
        let mut m = BlockManager::new(8, 2);
        let mut t = m.allocate_prompt(&[1, 2]).unwrap(); // 1 full (hashed) block
        m.append_token(&mut t).unwrap(); // new block at the boundary
        m.append_token(&mut t).unwrap(); // fills it — but decode-grown: no hash
        let ex = m.export_seq(&t);
        assert_eq!(ex.full_hashes.len(), 2);
        assert!(ex.full_hashes[0].is_some());
        assert!(ex.full_hashes[1].is_none(), "decode-grown block carries no hash");
        // Import still works; the unhashed block just never dedups.
        let (i1, r1) = m.import_seq(&ex).unwrap();
        assert_eq!(r1, 1, "only the prompt's hashed block is shared");
        m.release(&t);
        m.release(&i1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn prop_export_import_interleavings_preserve_invariants() {
        // Satellite property: random allocate/append/fork/release/export/
        // import interleavings never violate refcount/CoW/free-list
        // invariants, and everything released returns the pool to full.
        quick("kv_export_import_invariants", |rng: &mut Prng| {
            let mut m = BlockManager::new(rng.range(6, 28), rng.range(2, 6));
            let mut live: Vec<BlockTable> = vec![];
            let mut exports: Vec<KvSeqExport> = vec![];
            for _ in 0..rng.range(1, 60) {
                match rng.range(0, 5) {
                    0 => {
                        let n = rng.range(1, 20);
                        let toks: Vec<u32> = (0..n).map(|_| rng.below(6) as u32).collect();
                        if let Ok(t) = m.allocate_prompt(&toks) {
                            live.push(t);
                        }
                    }
                    1 if !live.is_empty() => {
                        let i = rng.range(0, live.len() - 1);
                        let f = m.fork(&live[i]);
                        live.push(f);
                    }
                    2 if !live.is_empty() => {
                        let i = rng.range(0, live.len() - 1);
                        let t = live.swap_remove(i);
                        m.release(&t);
                    }
                    3 if !live.is_empty() => {
                        // Export a live table (sometimes releasing the
                        // original right away, like a prefill handoff).
                        let i = rng.range(0, live.len() - 1);
                        exports.push(m.export_seq(&live[i]));
                        if rng.bool(0.5) {
                            let t = live.swap_remove(i);
                            m.release(&t);
                        }
                    }
                    4 if !exports.is_empty() => {
                        let i = rng.range(0, exports.len() - 1);
                        if let Ok((t, _)) = m.import_seq(&exports[i]) {
                            live.push(t);
                        }
                    }
                    _ => {
                        if let Some(t) = live.last_mut() {
                            let _ = m.append_token(t);
                        }
                    }
                }
                m.check_invariants().unwrap();
            }
            for t in live.drain(..) {
                m.release(&t);
            }
            assert_eq!(m.free_blocks(), m.n_blocks(), "leak after full release");
            m.check_invariants().unwrap();
        });
    }

    #[test]
    fn prop_forked_appends_preserve_invariants() {
        quick("kv_cow_invariants", |rng: &mut Prng| {
            let mut m = BlockManager::new(rng.range(6, 24), rng.range(2, 6));
            let mut live: Vec<BlockTable> = vec![];
            for _ in 0..rng.range(1, 50) {
                match rng.range(0, 3) {
                    0 => {
                        let n = rng.range(1, 20);
                        let toks: Vec<u32> = (0..n).map(|_| rng.below(6) as u32).collect();
                        if let Ok(t) = m.allocate_prompt(&toks) {
                            live.push(t);
                        }
                    }
                    1 if !live.is_empty() => {
                        let i = rng.range(0, live.len() - 1);
                        let f = m.fork(&live[i]);
                        live.push(f);
                    }
                    2 if !live.is_empty() => {
                        let i = rng.range(0, live.len() - 1);
                        let t = live.swap_remove(i);
                        m.release(&t);
                    }
                    _ => {
                        if let Some(t) = live.last_mut() {
                            let _ = m.append_token(t);
                        }
                    }
                }
                m.check_invariants().unwrap();
            }
            for t in live.drain(..) {
                m.release(&t);
            }
            assert_eq!(m.free_blocks(), m.n_blocks());
        });
    }

    #[test]
    fn prop_alloc_free_never_leaks() {
        quick("kv_no_leak", |rng: &mut Prng| {
            let mut m = BlockManager::new(rng.range(4, 32), rng.range(1, 8));
            let mut live: Vec<BlockTable> = vec![];
            for _ in 0..rng.range(1, 60) {
                match rng.range(0, 2) {
                    0 => {
                        let n = rng.range(1, 30);
                        let toks: Vec<u32> = (0..n).map(|_| rng.below(50) as u32).collect();
                        if let Ok(t) = m.allocate_prompt(&toks) {
                            live.push(t);
                        }
                    }
                    1 if !live.is_empty() => {
                        let i = rng.range(0, live.len() - 1);
                        let t = live.swap_remove(i);
                        m.release(&t);
                    }
                    _ => {
                        if let Some(t) = live.last_mut() {
                            let _ = m.append_token(t);
                        }
                    }
                }
                m.check_invariants().unwrap();
            }
            for t in live.drain(..) {
                m.release(&t);
            }
            assert_eq!(m.free_blocks(), m.n_blocks());
        });
    }

    #[test]
    fn prop_prefix_cache_consistent_with_content() {
        quick("kv_prefix_consistency", |rng: &mut Prng| {
            let bs = 4;
            let mut m = BlockManager::new(64, bs);
            // Same content must share, different must not (while blocks live).
            let n = rng.range(1, 4) * bs;
            let toks: Vec<u32> = (0..n).map(|_| rng.below(10) as u32).collect();
            let a = m.allocate_prompt(&toks).unwrap();
            let b = m.allocate_prompt(&toks).unwrap();
            assert_eq!(a.blocks[..n / bs], b.blocks[..n / bs]);
            let mut other = toks.clone();
            other[0] ^= 1;
            let c = m.allocate_prompt(&other).unwrap();
            assert_ne!(a.blocks[0], c.blocks[0]);
            m.release(&a);
            m.release(&b);
            m.release(&c);
            m.check_invariants().unwrap();
        });
    }
}
