//! Stage-transfer functions (the paper's edge functions, §3.2).
//!
//! A transfer maps one upstream [`StageItem`] into commands for the
//! downstream engine.  Transfers run on the *consumer* side of the
//! connector (the data plane moves raw items; see `connector/`).
//! Each edge instantiates its own stateful closure from the registry
//! (per-request accumulation state lives inside).

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::engine::ar::ArJob;
use crate::engine::diffusion::DiffusionJob;
use crate::engine::encoder::EncodeJob;
use crate::engine::vocoder::VocoderJob;
use crate::engine::{SamplingParams, StageItem};

/// Per-request metadata that transfers need to build downstream jobs
/// (registered by the orchestrator frontend at submit time).
#[derive(Debug, Clone, Default)]
pub struct ReqMeta {
    pub seed: u64,
    pub max_audio_tokens: usize,
    pub diffusion_steps: usize,
    pub ignore_eos: bool,
    /// Text prompt (needed by EPD's embeds2prompt transfer, which builds
    /// the Thinker submission downstream of a standalone encoder stage).
    pub prompt_tokens: Vec<u32>,
    pub max_text_tokens: usize,
    /// Admission priority rank ([`crate::serving::Priority::rank`]),
    /// consulted by stage loops when enqueuing into the per-stage
    /// scheduler.
    pub priority: u8,
    /// Interned tenant id for weighted fair queueing (0 = anonymous; see
    /// [`crate::serving::admission::AdmissionController::tenant_id`]).
    pub tenant: u32,
}

/// Shared request-metadata table (the paper's "predefined dictionary for
/// storing intermediate per-request data").
pub type ReqTable = Arc<Mutex<HashMap<u64, ReqMeta>>>;

/// Context handed to a transfer factory at edge instantiation.
#[derive(Clone)]
pub struct TransferCtx {
    pub reqs: ReqTable,
    /// Downstream chunk capacity in frames/tokens (vocoder-style edges).
    pub chunk_frames: usize,
    /// Downstream per-token conditioning width (DiT vocoder edges).
    pub cond_tokens_dim: usize,
}

/// Commands a transfer can issue to its downstream engine.
#[derive(Debug)]
pub enum EngineCmd {
    SubmitAr(ArJob),
    /// Hidden-state rows feeding a conditioning stream.
    Upstream { req_id: u64, rows: Vec<f32>, dim: usize, complete: bool },
    SubmitDiffusion(DiffusionJob),
    SubmitVocoder(VocoderJob),
    /// Multimodal encode job (standalone encoder stages, EPD mode).
    SubmitEncode(EncodeJob),
    /// A prefill stage's exported KV state for a decode stage to import
    /// (P/D disaggregation, see [`crate::kv_transfer`]).
    SubmitKv(Box<crate::kv_transfer::KvHandoff>),
}

/// A stateful transfer instance.
pub type Transfer = Box<dyn FnMut(&StageItem) -> Result<Vec<EngineCmd>> + Send>;

/// Factory: instantiate a transfer for one edge.
pub type TransferFactory = Arc<dyn Fn(TransferCtx) -> Transfer + Send + Sync>;

struct RegistryEntry {
    factory: TransferFactory,
    /// Whether an instance keeps NO per-request state across items, so
    /// items of one request may be split across consumer replicas (the
    /// router's per-item routing policies).  Every built-in accumulates
    /// per-request state, so they all register stateful; custom
    /// transfers opt in via [`Registry::register_stateless`].
    stateless: bool,
    /// Whether the transfer produces [`EngineCmd::SubmitKv`] from
    /// KV-handoff items — required on every edge into a
    /// [`crate::config::StageRole::Decode`] stage (enforced at graph
    /// build: a decode pool fed by a non-KV transfer would never see a
    /// sequence).  `kv2decode` registers with it; custom wrappers opt in
    /// via [`Registry::register_kv`].
    kv: bool,
}

/// Named transfer registry.
#[derive(Clone)]
pub struct Registry {
    map: HashMap<String, Arc<RegistryEntry>>,
}

impl Registry {
    pub fn empty() -> Self {
        Self { map: HashMap::new() }
    }

    /// The built-in transfers used by the model-zoo presets.  All of
    /// them accumulate per-request state consumer-side (chunk buffers,
    /// conditioning streams, first-item submits), so all are stateful:
    /// replicated consumers behind them require affinity routing.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register("thinker2talker", Arc::new(thinker2talker));
        r.register("embeds2prompt", Arc::new(embeds2prompt));
        r.register("talker2vocoder", Arc::new(talker2vocoder));
        r.register("hidden2cond", Arc::new(hidden2cond));
        r.register("tokens2patches", Arc::new(tokens2patches));
        r.register_kv("kv2decode", Arc::new(kv2decode));
        r
    }

    /// Register a transfer that keeps per-request state (the safe
    /// default): per-item routing into a replicated consumer is rejected
    /// at graph build for edges using it.
    pub fn register(&mut self, name: &str, f: TransferFactory) {
        self.map.insert(
            name.to_string(),
            Arc::new(RegistryEntry { factory: f, stateless: false, kv: false }),
        );
    }

    /// Register a transfer that treats every item independently, making
    /// per-item routing (`round_robin` / `least_depth`) into a
    /// replicated consumer safe for its edges.
    pub fn register_stateless(&mut self, name: &str, f: TransferFactory) {
        self.map.insert(
            name.to_string(),
            Arc::new(RegistryEntry { factory: f, stateless: true, kv: false }),
        );
    }

    /// Register a KV-handoff transfer (emits [`EngineCmd::SubmitKv`]),
    /// valid on prefill→decode edges.  Stateful, like every built-in.
    pub fn register_kv(&mut self, name: &str, f: TransferFactory) {
        self.map.insert(
            name.to_string(),
            Arc::new(RegistryEntry { factory: f, stateless: false, kv: true }),
        );
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    /// Whether `name` is registered as stateless (unknown names are NOT).
    pub fn is_stateless(&self, name: &str) -> bool {
        self.map.get(name).map(|e| e.stateless).unwrap_or(false)
    }

    /// Whether `name` is registered as a KV-handoff transfer (unknown
    /// names are NOT).
    pub fn is_kv(&self, name: &str) -> bool {
        self.map.get(name).map(|e| e.kv).unwrap_or(false)
    }

    pub fn instantiate(&self, name: &str, ctx: TransferCtx) -> Result<Transfer> {
        let e = self
            .map
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown transfer `{name}`"))?;
        Ok((e.factory)(ctx))
    }
}

fn meta(ctx: &TransferCtx, req: u64) -> ReqMeta {
    ctx.reqs.lock().unwrap().get(&req).cloned().unwrap_or_default()
}

// ---------------------------------------------------------------------------
// Built-in transfers
// ---------------------------------------------------------------------------

/// Encoder -> Thinker (EPD disaggregation): the standalone encoder stage
/// finishes a request's embeddings; this transfer assembles the Thinker
/// prompt (text tokens from the request meta + embedding rows) and
/// submits it.
fn embeds2prompt(ctx: TransferCtx) -> Transfer {
    Box::new(move |item: &StageItem| {
        let mut cmds = Vec::new();
        if !item.finished {
            return Ok(cmds);
        }
        let m = meta(&ctx, item.req_id);
        let (rows, dim, frames) = match item.tensor("embeds") {
            Some(e) => {
                let dim = *e.shape.last().unwrap_or(&0);
                (e.as_f32()?.to_vec(), dim, e.shape.first().copied().unwrap_or(0))
            }
            None => (vec![], 0, 0),
        };
        let mut prompt: Vec<crate::engine::ar::PromptItem> = m
            .prompt_tokens
            .iter()
            .map(|&t| crate::engine::ar::PromptItem::Token(t))
            .collect();
        prompt.extend((0..frames).map(crate::engine::ar::PromptItem::Embed));
        cmds.push(EngineCmd::SubmitAr(ArJob {
            req_id: item.req_id,
            prompt,
            mm_embeds: rows,
            emb_dim: dim,
            sampling: SamplingParams {
                max_new_tokens: m.max_text_tokens.max(1),
                temperature: 0.0,
                top_k: 0,
                ignore_eos: m.ignore_eos,
                seed: m.seed,
            },
        }));
        Ok(cmds)
    })
}

/// Prefill -> Decode (P/D disaggregation, paper §3.4): unpack the
/// [`crate::kv_transfer::KvHandoff`] frame the prefill engine attached
/// to its finished item and submit it for import.  A malformed frame is
/// an error (the stage thread surfaces it), never a panic.
fn kv2decode(_ctx: TransferCtx) -> Transfer {
    Box::new(move |item: &StageItem| {
        let Some(t) = item.tensor(crate::kv_transfer::KV_TENSOR) else {
            // Streamed non-final items (no handoff yet) carry nothing for
            // the decode engine.
            return Ok(vec![]);
        };
        let h = crate::kv_transfer::KvHandoff::from_tensor(t)
            .map_err(|e| e.context(format!("kv2decode: request {}", item.req_id)))?;
        Ok(vec![EngineCmd::SubmitKv(Box::new(h))])
    })
}

/// Thinker -> Talker (paper Fig. 4): on the first Thinker item, submit the
/// Talker request (BOS prompt whose generation length comes from the
/// request meta); every item streams the Thinker hidden rows into the
/// Talker's conditioning buffer (consumed by the per-iteration
/// preprocess).
fn thinker2talker(ctx: TransferCtx) -> Transfer {
    let mut submitted: HashSet<u64> = HashSet::new();
    Box::new(move |item: &StageItem| {
        let mut cmds = Vec::new();
        let m = meta(&ctx, item.req_id);
        if submitted.insert(item.req_id) {
            cmds.push(EngineCmd::SubmitAr(crate::engine::ar::token_job(
                item.req_id,
                &[crate::tokenizer::BOS_ID],
                SamplingParams {
                    max_new_tokens: m.max_audio_tokens.max(1),
                    temperature: 0.0,
                    top_k: 0,
                    ignore_eos: m.ignore_eos,
                    seed: m.seed,
                },
            )));
        }
        if let Some(h) = item.tensor("hiddens") {
            let dim = *h.shape.last().unwrap_or(&0);
            cmds.push(EngineCmd::Upstream {
                req_id: item.req_id,
                rows: h.as_f32()?.to_vec(),
                dim,
                complete: item.finished,
            });
        } else if item.finished {
            cmds.push(EngineCmd::Upstream {
                req_id: item.req_id,
                rows: vec![],
                dim: 0,
                complete: true,
            });
        }
        Ok(cmds)
    })
}

/// Deterministic pseudo-embedding for a codec token (the paper's vocoder
/// consumes codec embeddings; our DiT vocoder takes `cond_tokens_dim`
/// features per frame).
pub fn codec_features(token: u32, dim: usize) -> Vec<f32> {
    (0..dim)
        .map(|j| ((token as f32) * 0.061 + (j as f32) * 0.83).sin())
        .collect()
}

/// Talker -> Vocoder: accumulate codec tokens into fixed-size frame
/// chunks; each chunk becomes one vocoder job (DiT denoise, streamed).
fn talker2vocoder(ctx: TransferCtx) -> Transfer {
    struct St {
        tokens: Vec<u32>,
        chunks: usize,
    }
    let mut state: HashMap<u64, St> = HashMap::new();
    Box::new(move |item: &StageItem| {
        let mut cmds = Vec::new();
        let m = meta(&ctx, item.req_id);
        let st = state.entry(item.req_id).or_insert(St { tokens: vec![], chunks: 0 });
        if let Some(t) = item.tensor("tokens") {
            st.tokens.extend(t.as_i32()?.iter().map(|&x| x as u32));
        }
        let cap = ctx.chunk_frames.max(1);
        while st.tokens.len() >= cap || (item.finished && !st.tokens.is_empty()) {
            let take = st.tokens.len().min(cap);
            let chunk: Vec<u32> = st.tokens.drain(..take).collect();
            let is_final = item.finished && st.tokens.is_empty();
            if ctx.cond_tokens_dim > 0 {
                // DiT vocoder: codec pseudo-embeddings as per-token cond.
                let mut ct = Vec::with_capacity(cap * ctx.cond_tokens_dim);
                for i in 0..cap {
                    let tok = chunk.get(i).copied().unwrap_or(0);
                    ct.extend(codec_features(tok, ctx.cond_tokens_dim));
                }
                cmds.push(EngineCmd::SubmitDiffusion(DiffusionJob {
                    req_id: item.req_id,
                    chunk_idx: st.chunks,
                    cond: vec![],
                    cond_tokens: ct,
                    seed: m.seed ^ st.chunks as u64,
                    steps: 0,
                    final_chunk: is_final,
                }));
            } else {
                cmds.push(EngineCmd::SubmitVocoder(VocoderJob {
                    req_id: item.req_id,
                    chunk_idx: st.chunks,
                    tokens: chunk,
                    final_chunk: is_final,
                }));
            }
            st.chunks += 1;
            if is_final {
                break;
            }
        }
        if item.finished && st.tokens.is_empty() && st.chunks == 0 {
            // Degenerate: request produced no audio tokens at all.
            cmds.push(EngineCmd::SubmitVocoder(VocoderJob {
                req_id: item.req_id,
                chunk_idx: 0,
                tokens: vec![],
                final_chunk: true,
            }));
        }
        if item.finished {
            state.remove(&item.req_id);
        }
        Ok(cmds)
    })
}

/// Understanding AR -> DiT generator (BAGEL / GLM-Image shape): when the
/// AR stage finishes, its mean hidden state becomes the DiT conditioning
/// vector for a one-shot generation job.
fn hidden2cond(ctx: TransferCtx) -> Transfer {
    struct Acc {
        sum: Vec<f32>,
        rows: usize,
    }
    let mut state: HashMap<u64, Acc> = HashMap::new();
    Box::new(move |item: &StageItem| {
        let mut cmds = Vec::new();
        if let Some(h) = item.tensor("hiddens") {
            let dim = *h.shape.last().unwrap_or(&0);
            let data = h.as_f32()?;
            let acc = state
                .entry(item.req_id)
                .or_insert_with(|| Acc { sum: vec![0.0; dim], rows: 0 });
            for row in data.chunks_exact(dim.max(1)) {
                for (s, &x) in acc.sum.iter_mut().zip(row) {
                    *s += x;
                }
                acc.rows += 1;
            }
        }
        if item.finished {
            let m = meta(&ctx, item.req_id);
            let cond = state
                .remove(&item.req_id)
                .map(|a| {
                    let n = a.rows.max(1) as f32;
                    a.sum.iter().map(|&s| s / n).collect()
                })
                .unwrap_or_default();
            cmds.push(EngineCmd::SubmitDiffusion(DiffusionJob {
                req_id: item.req_id,
                chunk_idx: 0,
                cond,
                cond_tokens: vec![],
                seed: m.seed,
                steps: m.diffusion_steps,
                final_chunk: true,
            }));
        }
        Ok(cmds)
    })
}

/// MiMo backbone -> patch decoder: audio tokens chunked into patch-decoder
/// calls (CNN-style path of talker2vocoder).
fn tokens2patches(ctx: TransferCtx) -> Transfer {
    let inner_ctx = TransferCtx { cond_tokens_dim: 0, ..ctx };
    talker2vocoder(inner_ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn ctx(chunk: usize, ctd: usize) -> TransferCtx {
        let reqs: ReqTable = Arc::new(Mutex::new(HashMap::new()));
        reqs.lock().unwrap().insert(
            1,
            ReqMeta { seed: 7, max_audio_tokens: 40, diffusion_steps: 6, ignore_eos: true,
                      prompt_tokens: vec![1, 5], max_text_tokens: 12,
                      priority: crate::scheduler::PRIORITY_NORMAL, tenant: 0 },
        );
        TransferCtx { reqs, chunk_frames: chunk, cond_tokens_dim: ctd }
    }

    fn item_tokens(req: u64, toks: &[i32], hid_dim: usize, fin: bool) -> StageItem {
        let n = toks.len();
        let mut it = StageItem::new(req)
            .with("tokens", HostTensor::i32(vec![n], toks.to_vec()))
            .with("hiddens", HostTensor::f32(vec![n, hid_dim], vec![0.5; n * hid_dim]));
        if fin {
            it = it.finished();
        }
        it
    }

    #[test]
    fn thinker2talker_submits_once_then_streams() {
        let mut t = Registry::builtin().instantiate("thinker2talker", ctx(16, 0)).unwrap();
        let cmds = t(&item_tokens(1, &[5, 6], 8, false)).unwrap();
        assert_eq!(cmds.len(), 2);
        assert!(matches!(&cmds[0], EngineCmd::SubmitAr(j) if j.req_id == 1
            && j.sampling.max_new_tokens == 40 && j.sampling.ignore_eos));
        assert!(matches!(&cmds[1], EngineCmd::Upstream { rows, dim: 8, complete: false, .. }
            if rows.len() == 16));
        let cmds2 = t(&item_tokens(1, &[7], 8, true)).unwrap();
        assert_eq!(cmds2.len(), 1); // no resubmission
        assert!(matches!(&cmds2[0], EngineCmd::Upstream { complete: true, .. }));
    }

    #[test]
    fn talker2vocoder_chunks_and_flushes() {
        let mut t = Registry::builtin().instantiate("talker2vocoder", ctx(4, 0)).unwrap();
        let cmds = t(&item_tokens(1, &[1, 2, 3, 4, 5], 4, false)).unwrap();
        assert_eq!(cmds.len(), 1); // one full chunk, 1 leftover
        assert!(matches!(&cmds[0], EngineCmd::SubmitVocoder(j)
            if j.tokens == vec![1, 2, 3, 4] && !j.final_chunk && j.chunk_idx == 0));
        let cmds2 = t(&item_tokens(1, &[6], 4, true)).unwrap();
        assert_eq!(cmds2.len(), 1); // flush [5, 6] as final
        assert!(matches!(&cmds2[0], EngineCmd::SubmitVocoder(j)
            if j.tokens == vec![5, 6] && j.final_chunk && j.chunk_idx == 1));
    }

    #[test]
    fn talker2vocoder_dit_path_builds_cond_tokens() {
        let mut t = Registry::builtin().instantiate("talker2vocoder", ctx(4, 6)).unwrap();
        let cmds = t(&item_tokens(1, &[1, 2, 3, 4], 4, false)).unwrap();
        assert_eq!(cmds.len(), 1);
        match &cmds[0] {
            EngineCmd::SubmitDiffusion(j) => {
                assert_eq!(j.cond_tokens.len(), 4 * 6);
                assert_eq!(j.chunk_idx, 0);
            }
            other => panic!("expected diffusion cmd, got {other:?}"),
        }
    }

    #[test]
    fn hidden2cond_waits_for_finish() {
        let mut t = Registry::builtin().instantiate("hidden2cond", ctx(0, 0)).unwrap();
        assert!(t(&item_tokens(1, &[1, 2], 4, false)).unwrap().is_empty());
        let cmds = t(&item_tokens(1, &[3], 4, true)).unwrap();
        assert_eq!(cmds.len(), 1);
        match &cmds[0] {
            EngineCmd::SubmitDiffusion(j) => {
                assert_eq!(j.cond.len(), 4);
                assert_eq!(j.steps, 6);
                assert!(j.final_chunk);
                // mean of constant 0.5 rows is 0.5
                assert!((j.cond[0] - 0.5).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn kv2decode_unpacks_handoffs_and_rejects_corruption() {
        let mut t = Registry::builtin().instantiate("kv2decode", ctx(0, 0)).unwrap();
        // Items without a handoff tensor (streamed partials) produce nothing.
        assert!(t(&item_tokens(1, &[5], 4, false)).unwrap().is_empty());
        // A finished prefill item with a valid frame becomes a SubmitKv.
        let h = crate::kv_transfer::KvHandoff {
            req_id: 1,
            len: 2,
            first_token: 9,
            hidden: vec![],
            sampling: crate::engine::SamplingParams::default(),
            prng_state: 7,
            n_layers: 1,
            n_heads: 1,
            d_head: 2,
            blocks: crate::kv_cache::KvSeqExport {
                block_size: 2,
                len: 2,
                full_hashes: vec![Some(3)],
            },
            kv: vec![0.5; 8], // 1 layer x 2 x 1 head x 2 tokens x 2 dh
        };
        let item = StageItem::new(1)
            .with(crate::kv_transfer::KV_TENSOR, h.to_tensor())
            .finished();
        let cmds = t(&item).unwrap();
        assert_eq!(cmds.len(), 1);
        assert!(matches!(&cmds[0], EngineCmd::SubmitKv(got) if **got == h));
        // A corrupt frame errors (no panic).
        let mut tensor = h.to_tensor();
        if let Ok(d) = tensor.as_i32_mut() {
            let last = d.len() - 1;
            d[last] ^= 0x5A5A;
        }
        let bad = StageItem::new(1).with(crate::kv_transfer::KV_TENSOR, tensor).finished();
        assert!(t(&bad).is_err());
    }

    #[test]
    fn empty_audio_still_completes() {
        let mut t = Registry::builtin().instantiate("talker2vocoder", ctx(4, 0)).unwrap();
        let mut fin = StageItem::new(1).finished();
        fin.tensors.insert("tokens".into(), HostTensor::i32(vec![0], vec![]));
        let cmds = t(&fin).unwrap();
        assert_eq!(cmds.len(), 1);
        assert!(matches!(&cmds[0], EngineCmd::SubmitVocoder(j)
            if j.tokens.is_empty() && j.final_chunk));
    }
}
