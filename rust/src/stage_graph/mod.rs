//! Stage-graph abstraction (paper §3.2) — the frontend for any-to-any
//! model programming.
//!
//! A pipeline is a DAG whose nodes are model stages (AR / DiT / CNN) and
//! whose edges carry *stage-transfer functions* that transform one
//! stage's output items into the next stage's inputs (submissions,
//! conditioning streams, codec chunks).  [`transfers`] holds the built-in
//! transfer registry (Thinker2Talker, Talker2Vocoder, ...); library users
//! register custom transfers with [`transfers::Registry::register`].

pub mod transfers;

use anyhow::{bail, Result};

use crate::config::{EdgeConfig, PipelineConfig, StageConfig, StageRole};

/// One branch of a fan-out stage: the sub-DAG hanging off a single
/// out-neighbor of a stage with out-degree ≥ 2 (e.g. a thinker fanning
/// out to a parallel image arm and a speech arm that share its prefill).
#[derive(Debug, Clone, PartialEq)]
pub struct BranchInfo {
    /// The fan-out stage the branches split from.
    pub root: usize,
    /// First stage of the branch (`root`'s out-neighbor).
    pub head: usize,
    /// Stages private to this branch, in topological order.  A full
    /// join — a stage every branch reaches — belongs to no branch and
    /// is excluded.
    pub stages: Vec<usize>,
    /// Exit stages private to this branch (empty when the branches
    /// re-join before exiting — completion is then the join's exit).
    pub exits: Vec<usize>,
}

/// A validated stage graph: topology checked, transfers resolvable.
#[derive(Debug, Clone)]
pub struct StageGraph {
    pub config: PipelineConfig,
    /// Topological order of stage indices.
    pub topo: Vec<usize>,
    /// Entry stage (no incoming edges).
    pub entry: usize,
    /// Exit stages (no outgoing edges).
    pub exits: Vec<usize>,
}

impl StageGraph {
    /// Validate the pipeline config structurally and as a graph, using
    /// `registry` to resolve transfer names.
    pub fn build(config: PipelineConfig, registry: &transfers::Registry) -> Result<Self> {
        config.validate()?;
        let n = config.stages.len();
        let idx_of = |name: &str| config.stages.iter().position(|s| s.name == name).unwrap();

        for e in &config.edges {
            if !registry.contains(&e.transfer) {
                bail!("edge {}->{}: unknown transfer `{}`", e.from, e.to, e.transfer);
            }
            // Per-item routing splits a request's item stream across the
            // consumer's replicas.  That corrupts any transfer holding
            // per-request state (chunk accumulators, conditioning
            // streams — every built-in does), so it is only allowed for
            // transfers registered stateless.  (config::validate already
            // rejects the AR-consumer case without needing the registry.)
            let to = config.stage(&e.to).unwrap();
            if to.replicas > 1
                && matches!(
                    e.routing,
                    crate::config::RoutingKind::RoundRobin | crate::config::RoutingKind::LeastDepth
                )
                && !registry.is_stateless(&e.transfer)
            {
                bail!(
                    "edge {}->{}: transfer `{}` keeps per-request state but consumer \
                     `{}` has {} replicas — use `affinity` routing (or register the \
                     transfer with register_stateless)",
                    e.from,
                    e.to,
                    e.transfer,
                    e.to,
                    to.replicas
                );
            }
        }

        // Prefill/decode disaggregation (paper §3.4): a Prefill stage's
        // KV handoffs are only meaningful to a Decode stage serving the
        // SAME model (the KV geometry and weights must match), and a
        // Decode stage gets all of its sequence state from handoffs, so
        // every edge across the split is checked here — a mis-wired EPD
        // graph fails at build time, not with a runtime import error.
        for s in &config.stages {
            match s.role {
                StageRole::Prefill => {
                    let outs: Vec<&EdgeConfig> =
                        config.edges.iter().filter(|e| e.from == s.name).collect();
                    if outs.is_empty() {
                        bail!(
                            "prefill stage `{}` has no outgoing edge — its KV handoffs \
                             need a decode stage to import them",
                            s.name
                        );
                    }
                    for e in &outs {
                        let to = config.stage(&e.to).unwrap();
                        if to.role != StageRole::Decode {
                            bail!(
                                "edge {}->{}: a prefill stage must feed a decode stage \
                                 (got role `{}`)",
                                e.from,
                                e.to,
                                to.role.name()
                            );
                        }
                        if to.model != s.model {
                            bail!(
                                "edge {}->{}: prefill serves `{}` but decode serves `{}` — \
                                 KV handoffs only transfer between engines of the same model",
                                e.from,
                                e.to,
                                s.model,
                                to.model
                            );
                        }
                    }
                }
                StageRole::Decode => {
                    let ins: Vec<&EdgeConfig> =
                        config.edges.iter().filter(|e| e.to == s.name).collect();
                    if ins.is_empty() {
                        bail!(
                            "decode stage `{}` has no incoming edge — it can only serve \
                             sequences imported from a prefill stage",
                            s.name
                        );
                    }
                    for e in &ins {
                        let from = config.stage(&e.from).unwrap();
                        if from.role != StageRole::Prefill {
                            bail!(
                                "edge {}->{}: a decode stage only accepts KV handoffs \
                                 from a prefill stage (got role `{}`)",
                                e.from,
                                e.to,
                                from.role.name()
                            );
                        }
                        // The transfer itself must speak KV handoffs; a
                        // decode pool behind e.g. `thinker2talker` would
                        // never receive a sequence to serve.
                        if !registry.is_kv(&e.transfer) {
                            bail!(
                                "edge {}->{}: decode stages require a KV-handoff \
                                 transfer (`kv2decode`, or one registered with \
                                 register_kv), got `{}`",
                                e.from,
                                e.to,
                                e.transfer
                            );
                        }
                    }
                }
                StageRole::Fused => {}
            }
        }

        // Kahn topo sort.
        let mut indeg = vec![0usize; n];
        let mut adj: Vec<Vec<usize>> = vec![vec![]; n];
        for e in &config.edges {
            let (f, t) = (idx_of(&e.from), idx_of(&e.to));
            adj[f].push(t);
            indeg[t] += 1;
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut topo = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            topo.push(u);
            for &v in &adj[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if topo.len() != n {
            bail!("stage graph `{}` has a cycle", config.name);
        }

        // Branching fan-out / fan-in validation (any-to-any fan-out: one
        // prompt forks into parallel output arms).  For every stage that
        // fans out, each downstream stage must sit on exactly ONE branch
        // (branch-private) or on ALL of them (a full join).  A partial
        // join — fed by some but not all branches — has no completion
        // semantics (whose branch-done would it ride?), so it is
        // rejected at build time.
        let reach = |start: usize| -> Vec<bool> {
            let mut seen = vec![false; n];
            let mut stack = vec![start];
            while let Some(u) = stack.pop() {
                if seen[u] {
                    continue;
                }
                seen[u] = true;
                stack.extend(adj[u].iter().copied());
            }
            seen
        };
        for root in 0..n {
            let mut heads = adj[root].clone();
            heads.sort_unstable();
            heads.dedup();
            if heads.len() < 2 {
                continue;
            }
            let reaches: Vec<Vec<bool>> = heads.iter().map(|&h| reach(h)).collect();
            for i in 0..n {
                if i == root {
                    continue;
                }
                let cnt = reaches.iter().filter(|r| r[i]).count();
                if cnt > 1 && cnt < heads.len() {
                    bail!(
                        "stage graph `{}`: stage `{}` joins {cnt} of {} branches fanned \
                         out from `{}` — a fan-in must merge ALL branches (or none)",
                        config.name,
                        config.stages[i].name,
                        heads.len(),
                        config.stages[root].name
                    );
                }
            }
        }

        // Entry/exits.
        let entries: Vec<usize> = (0..n)
            .filter(|&i| !config.edges.iter().any(|e| idx_of(&e.to) == i))
            .collect();
        if entries.len() != 1 {
            bail!(
                "stage graph `{}` must have exactly one entry stage (found {})",
                config.name,
                entries.len()
            );
        }
        let exits: Vec<usize> = (0..n)
            .filter(|&i| !config.edges.iter().any(|e| idx_of(&e.from) == i))
            .collect();

        Ok(Self { config, topo, entry: entries[0], exits })
    }

    pub fn n_stages(&self) -> usize {
        self.config.stages.len()
    }

    pub fn stage(&self, i: usize) -> &StageConfig {
        &self.config.stages[i]
    }

    pub fn stage_index(&self, name: &str) -> Option<usize> {
        self.config.stages.iter().position(|s| s.name == name)
    }

    /// Edges into stage `i`.
    pub fn incoming(&self, i: usize) -> Vec<&EdgeConfig> {
        let name = &self.config.stages[i].name;
        self.config.edges.iter().filter(|e| &e.to == name).collect()
    }

    /// Edges out of stage `i`.
    pub fn outgoing(&self, i: usize) -> Vec<&EdgeConfig> {
        let name = &self.config.stages[i].name;
        self.config.edges.iter().filter(|e| &e.from == name).collect()
    }

    /// Stages reachable from `start` by following edges (incl. `start`).
    fn reachable_from(&self, start: usize) -> Vec<bool> {
        let mut seen = vec![false; self.n_stages()];
        let mut stack = vec![start];
        while let Some(u) = stack.pop() {
            if seen[u] {
                continue;
            }
            seen[u] = true;
            for e in self.outgoing(u) {
                stack.push(self.stage_index(&e.to).expect("validated edge"));
            }
        }
        seen
    }

    /// Branches of every fan-out stage: one [`BranchInfo`] per distinct
    /// out-neighbor of each stage with out-degree ≥ 2.  [`Self::build`]
    /// has already verified every downstream stage is branch-private or
    /// a full join, so membership here is unambiguous.
    pub fn branches(&self) -> Vec<BranchInfo> {
        let mut out = Vec::new();
        for root in 0..self.n_stages() {
            let mut heads: Vec<usize> = self
                .outgoing(root)
                .iter()
                .filter_map(|e| self.stage_index(&e.to))
                .collect();
            heads.sort_unstable();
            heads.dedup();
            if heads.len() < 2 {
                continue;
            }
            let reaches: Vec<Vec<bool>> =
                heads.iter().map(|&h| self.reachable_from(h)).collect();
            for (bi, &head) in heads.iter().enumerate() {
                let stages: Vec<usize> = self
                    .topo
                    .iter()
                    .copied()
                    .filter(|&i| {
                        reaches[bi][i]
                            && reaches.iter().enumerate().all(|(o, r)| o == bi || !r[i])
                    })
                    .collect();
                let exits =
                    stages.iter().copied().filter(|i| self.exits.contains(i)).collect();
                out.push(BranchInfo { root, head, stages, exits });
            }
        }
        out
    }

    /// Device-memory admission: reserve weights for every engine replica
    /// of every stage on the device groups the allocation plan packed
    /// (TP splits across each group).  Replication multiplies the weight
    /// footprint — each replica holds a full copy — so an over-replicated
    /// pipeline fails here, at construction time.
    pub fn reserve_memory(
        &self,
        pool: &crate::device::DevicePool,
        artifacts: &crate::runtime::Artifacts,
        plan: &crate::scheduler::AllocationPlan,
    ) -> Result<Vec<crate::device::Reservation>> {
        let mut all = Vec::new();
        for (i, s) in self.config.stages.iter().enumerate() {
            let model = artifacts.model(&s.model)?;
            let a = plan.assignment(i);
            for (r, group) in a.replica_devices.iter().enumerate() {
                let label =
                    if r == 0 { s.name.clone() } else { format!("{}#r{r}", s.name) };
                let rs = pool.reserve_tp(group, model.weight_bytes(), &label)?;
                all.extend(rs);
            }
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn reg() -> transfers::Registry {
        transfers::Registry::builtin()
    }

    #[test]
    fn builds_all_presets() {
        for p in presets::all() {
            let g = StageGraph::build(p, &reg()).unwrap();
            assert!(g.n_stages() >= 1);
        }
    }

    #[test]
    fn qwen_omni_topology() {
        let g = StageGraph::build(presets::qwen3_omni(), &reg()).unwrap();
        assert_eq!(g.entry, g.stage_index("thinker").unwrap());
        assert_eq!(g.exits, vec![g.stage_index("vocoder").unwrap()]);
        // topo respects edges
        let pos = |n: &str| g.topo.iter().position(|&i| g.stage(i).name == n).unwrap();
        assert!(pos("thinker") < pos("talker"));
        assert!(pos("talker") < pos("vocoder"));
    }

    #[test]
    fn rejects_cycle() {
        let mut p = presets::qwen3_omni();
        p.edges.push(crate::config::EdgeConfig {
            from: "vocoder".into(),
            to: "thinker".into(),
            transfer: "thinker2talker".into(),
            connector: crate::config::ConnectorKind::Inline,
            routing: crate::config::RoutingKind::Auto,
        });
        assert!(StageGraph::build(p, &reg()).is_err());
    }

    #[test]
    fn rejects_unknown_transfer() {
        let mut p = presets::qwen3_omni();
        p.edges[0].transfer = "nope".into();
        assert!(StageGraph::build(p, &reg()).is_err());
    }

    #[test]
    fn rejects_per_item_routing_into_replicated_ar_stage() {
        // Stateful (AR) consumers with replicas need affinity routing so
        // KV/sequence state stays on one replica; graph build rejects
        // explicit per-item policies.
        let mut p = presets::qwen3_omni();
        p.stages.iter_mut().find(|s| s.name == "talker").unwrap().replicas = 2;
        p.edges[0].routing = crate::config::RoutingKind::LeastDepth;
        assert!(StageGraph::build(p.clone(), &reg()).is_err());
        p.edges[0].routing = crate::config::RoutingKind::Affinity;
        assert!(StageGraph::build(p, &reg()).is_ok());
    }

    #[test]
    fn rejects_per_item_routing_through_stateful_transfers() {
        // Not just AR: talker2vocoder accumulates a request's codec
        // tokens consumer-side, so a replicated VOCODER behind per-item
        // routing would scramble chunk boundaries.  The registry knows
        // every built-in is stateful; graph build rejects the combo.
        let mut p = presets::qwen3_omni();
        p.stages.iter_mut().find(|s| s.name == "vocoder").unwrap().replicas = 2;
        p.edges[1].routing = crate::config::RoutingKind::RoundRobin;
        let err = StageGraph::build(p.clone(), &reg()).unwrap_err();
        assert!(format!("{err:#}").contains("per-request state"), "{err:#}");
        // Affinity (or Auto, which resolves to it) is accepted.
        p.edges[1].routing = crate::config::RoutingKind::Auto;
        assert!(StageGraph::build(p, &reg()).is_ok());
    }

    #[test]
    fn stateless_transfers_allow_per_item_routing() {
        use transfers::{Transfer, TransferCtx};
        let mut r = reg();
        r.register_stateless(
            "item_independent",
            std::sync::Arc::new(|_ctx: TransferCtx| -> Transfer { Box::new(|_item| Ok(vec![])) }),
        );
        assert!(r.is_stateless("item_independent"));
        assert!(!r.is_stateless("talker2vocoder"));
        assert!(!r.is_stateless("no_such_transfer"));
        let mut p = presets::qwen3_omni();
        p.stages.iter_mut().find(|s| s.name == "vocoder").unwrap().replicas = 2;
        p.edges[1].transfer = "item_independent".into();
        p.edges[1].routing = crate::config::RoutingKind::LeastDepth;
        assert!(StageGraph::build(p, &r).is_ok());
    }

    #[test]
    fn epd_preset_builds_with_prefill_feeding_decode() {
        let g = StageGraph::build(presets::qwen3_omni_epd(), &reg()).unwrap();
        assert_eq!(g.entry, g.stage_index("encoder").unwrap());
        let pos = |n: &str| g.topo.iter().position(|&i| g.stage(i).name == n).unwrap();
        assert!(pos("prefill") < pos("decode"));
        assert!(pos("decode") < pos("talker"));
    }

    #[test]
    fn rejects_prefill_not_feeding_a_decode_stage() {
        // Re-point the prefill stage's edge at the talker: rejected.
        let mut p = presets::qwen3_omni_epd();
        p.edges.retain(|e| e.from != "prefill");
        p.edges.push(crate::config::EdgeConfig {
            from: "prefill".into(),
            to: "talker".into(),
            transfer: "thinker2talker".into(),
            connector: crate::config::ConnectorKind::Inline,
            routing: crate::config::RoutingKind::Auto,
        });
        // The decode stage now dangles too; drop it so only the
        // prefill-side violation is under test.
        p.stages.retain(|s| s.name != "decode");
        p.edges.retain(|e| e.from != "decode" && e.to != "decode");
        let err = StageGraph::build(p, &reg()).unwrap_err();
        assert!(format!("{err:#}").contains("must feed a decode stage"), "{err:#}");
    }

    #[test]
    fn rejects_prefill_decode_model_mismatch() {
        let mut p = presets::qwen3_omni_epd();
        p.stages.iter_mut().find(|s| s.name == "decode").unwrap().model = "talker3".into();
        let err = StageGraph::build(p, &reg()).unwrap_err();
        assert!(format!("{err:#}").contains("same model"), "{err:#}");
    }

    #[test]
    fn rejects_decode_fed_by_a_fused_stage() {
        let mut p = presets::qwen3_omni_epd();
        p.stages.iter_mut().find(|s| s.name == "prefill").unwrap().role =
            crate::config::StageRole::Fused;
        let err = StageGraph::build(p, &reg()).unwrap_err();
        assert!(format!("{err:#}").contains("only accepts KV handoffs"), "{err:#}");
    }

    #[test]
    fn rejects_non_kv_transfer_into_a_decode_stage() {
        // Roles line up but the transfer cannot carry KV handoffs: the
        // decode pool would never receive a sequence, so build rejects.
        let mut p = presets::qwen3_omni_epd();
        p.edges.iter_mut().find(|e| e.to == "decode").unwrap().transfer =
            "thinker2talker".into();
        let err = StageGraph::build(p, &reg()).unwrap_err();
        assert!(format!("{err:#}").contains("KV-handoff transfer"), "{err:#}");
        // A custom transfer registered with register_kv is accepted.
        let mut r = reg();
        r.register_kv(
            "my_kv",
            std::sync::Arc::new(|_ctx: transfers::TransferCtx| -> transfers::Transfer {
                Box::new(|_item| Ok(vec![]))
            }),
        );
        assert!(r.is_kv("my_kv"));
        assert!(!r.is_kv("thinker2talker"));
        let mut p = presets::qwen3_omni_epd();
        p.edges.iter_mut().find(|e| e.to == "decode").unwrap().transfer = "my_kv".into();
        assert!(StageGraph::build(p, &r).is_ok());
    }

    #[test]
    fn rejects_dangling_prefill_stage() {
        let mut p = presets::qwen3_omni_epd();
        p.stages.retain(|s| s.name != "decode");
        p.edges.retain(|e| e.to != "decode" && e.from != "decode");
        // prefill now has no outgoing edge at all.
        let err = StageGraph::build(p, &reg()).unwrap_err();
        assert!(format!("{err:#}").contains("no outgoing edge"), "{err:#}");
    }

    #[test]
    fn branching_preset_fans_out_into_two_branches() {
        let g = StageGraph::build(presets::qwen3_omni_branching(), &reg()).unwrap();
        let idx = |n: &str| g.stage_index(n).unwrap();
        assert_eq!(g.entry, idx("encoder"));
        let mut exits = g.exits.clone();
        exits.sort_unstable();
        let mut want = vec![idx("imagegen"), idx("vocoder")];
        want.sort_unstable();
        assert_eq!(exits, want, "both arms terminate the request");
        let branches = g.branches();
        assert_eq!(branches.len(), 2);
        for b in &branches {
            assert_eq!(b.root, idx("thinker"), "the thinker is the fan-out root");
        }
        let image = branches.iter().find(|b| b.head == idx("imagegen")).unwrap();
        assert_eq!(image.stages, vec![idx("imagegen")]);
        assert_eq!(image.exits, vec![idx("imagegen")]);
        let speech = branches.iter().find(|b| b.head == idx("talker")).unwrap();
        assert_eq!(speech.stages, vec![idx("talker"), idx("vocoder")]);
        assert_eq!(speech.exits, vec![idx("vocoder")]);
    }

    #[test]
    fn rejects_partial_fan_in() {
        // Fan the thinker out three ways (image, speech, and a direct
        // edge to the vocoder).  The vocoder is now fed by two of the
        // three branches — a partial join with no completion semantics.
        let mut p = presets::qwen3_omni_branching();
        p.edges.push(crate::config::EdgeConfig {
            from: "thinker".into(),
            to: "vocoder".into(),
            transfer: "talker2vocoder".into(),
            connector: crate::config::ConnectorKind::Inline,
            routing: crate::config::RoutingKind::Auto,
        });
        let err = StageGraph::build(p, &reg()).unwrap_err();
        assert!(format!("{err:#}").contains("a fan-in must merge ALL branches"), "{err:#}");
    }

    #[test]
    fn full_join_of_all_branches_is_accepted() {
        // Route the image arm into the vocoder as well: the vocoder is
        // now reachable from BOTH branches — a full join, accepted, and
        // it belongs to neither branch's private stage set.
        let mut p = presets::qwen3_omni_branching();
        p.edges.push(crate::config::EdgeConfig {
            from: "imagegen".into(),
            to: "vocoder".into(),
            transfer: "hidden2cond".into(),
            connector: crate::config::ConnectorKind::Inline,
            routing: crate::config::RoutingKind::Auto,
        });
        let g = StageGraph::build(p, &reg()).unwrap();
        let idx = |n: &str| g.stage_index(n).unwrap();
        assert_eq!(g.exits, vec![idx("vocoder")], "the join is the single exit");
        let branches = g.branches();
        assert_eq!(branches.len(), 2);
        for b in &branches {
            assert!(!b.stages.contains(&idx("vocoder")), "join is branch-neutral");
            assert!(b.exits.is_empty(), "completion rides the join's exit");
        }
    }

    #[test]
    fn rejects_multiple_entries() {
        let mut p = presets::qwen3_omni();
        p.edges.remove(0); // thinker->talker gone: thinker AND talker are entries
        assert!(StageGraph::build(p, &reg()).is_err());
    }

    #[test]
    fn memory_reservation_respects_budget() {
        let art_dir = crate::runtime::Artifacts::default_dir();
        if !art_dir.join("manifest.json").exists() {
            return;
        }
        let artifacts = crate::runtime::Artifacts::load(&art_dir).unwrap();
        let g = StageGraph::build(presets::qwen3_omni(), &reg()).unwrap();
        let plan =
            crate::scheduler::StageAllocator::new(&g.config).plan(None).unwrap();
        let pool = crate::device::DevicePool::testbed();
        let rs = g.reserve_memory(&pool, &artifacts, &plan).unwrap();
        assert!(!rs.is_empty());
        // Thinker TP2: both devices charged.
        assert!(pool.used(crate::device::DeviceId(0)) > 0);
        assert!(pool.used(crate::device::DeviceId(1)) > 0);
        // A pool that is far too small must reject the pipeline.
        let tiny = crate::device::DevicePool::new(2, 1024);
        assert!(g.reserve_memory(&tiny, &artifacts, &plan).is_err());
    }

    #[test]
    fn replicas_multiply_the_weight_footprint() {
        let art_dir = crate::runtime::Artifacts::default_dir();
        if !art_dir.join("manifest.json").exists() {
            return;
        }
        let artifacts = crate::runtime::Artifacts::load(&art_dir).unwrap();
        let reserved_total = |cfg: crate::config::PipelineConfig| {
            let g = StageGraph::build(cfg, &reg()).unwrap();
            let plan =
                crate::scheduler::StageAllocator::new(&g.config).plan(None).unwrap();
            // Oversized pool so admission itself cannot fail here.
            let pool = crate::device::DevicePool::new(2, usize::MAX / 4);
            let rs = g.reserve_memory(&pool, &artifacts, &plan).unwrap();
            rs.iter().map(|r| r.bytes).sum::<usize>()
        };
        let base = reserved_total(presets::qwen3_omni());
        let rep = reserved_total(presets::qwen3_omni_replicated());
        let talker_bytes = artifacts.model("talker3").unwrap().weight_bytes();
        assert_eq!(rep, base + talker_bytes, "second talker replica = one more weight copy");
    }
}
