//! PJRT runtime layer: loads `artifacts/*.hlo.txt` (AOT-lowered from
//! JAX/Pallas by `python/compile/aot.py`) and executes them on the CPU
//! PJRT client from the engines' hot paths.
//!
//! Structure:
//! * [`artifact`] — manifest parsing, weight blobs, bucket lookup.
//! * [`tensor`] — host tensors and the connector wire format.
//! * [`stage_rt`] — per-engine-thread client + compiled executables +
//!   device-resident weights.

pub mod artifact;
pub mod stage_rt;
pub mod tensor;

pub use artifact::{Artifacts, EntrySpec, IoSpec, ModelSpec};
pub use stage_rt::StageRuntime;
pub use tensor::{DType, HostTensor, TensorData};
