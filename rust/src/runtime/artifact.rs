//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  Parses `artifacts/manifest.json` into typed records and
//! loads weight blobs.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::tensor::DType;
use crate::json::{self, Value};

/// Manifest version this runtime understands (bump in lockstep with
/// `python/compile/aot.py::MANIFEST_VERSION`).
pub const SUPPORTED_VERSION: i64 = 3;

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct WeightLeaf {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub kind: String,
    /// Raw config object from the python side (d_model, max_seq, ...).
    pub config: Value,
    pub weights_file: PathBuf,
    pub weight_leaves: Vec<WeightLeaf>,
    pub entries: HashMap<String, EntrySpec>,
}

impl ModelSpec {
    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model `{}` has no entry `{name}`", self.name))
    }

    /// Integer field from the model config (e.g. "max_seq", "d_model").
    pub fn cfg_usize(&self, key: &str) -> Result<usize> {
        self.config.req_usize(key)
    }

    pub fn total_weight_floats(&self) -> usize {
        self.weight_leaves.iter().map(|l| l.size).sum()
    }

    /// Weight bytes for device-memory accounting.
    pub fn weight_bytes(&self) -> usize {
        self.total_weight_floats() * 4
    }

    /// Largest batch bucket available for an entry family, e.g.
    /// `decode` -> 8 when `decode.b8` exists.
    pub fn buckets(&self, family: &str) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .entries
            .keys()
            .filter_map(|k| {
                let rest = k.strip_prefix(family)?.strip_prefix(".b")?;
                let bucket: String =
                    rest.chars().take_while(|c| c.is_ascii_digit()).collect();
                bucket.parse::<usize>().ok()
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Entry name for the smallest compiled bucket holding `n` items.
    pub fn bucket_entry(&self, family: &str, n: usize, suffix: &str) -> Result<String> {
        let buckets = self.buckets(family);
        let b = buckets
            .iter()
            .find(|&&b| b >= n)
            .or(buckets.last())
            .ok_or_else(|| anyhow::anyhow!("no `{family}` buckets for model `{}`", self.name))?;
        Ok(format!("{family}.b{b}{suffix}"))
    }
}

/// The parsed artifact directory.
#[derive(Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub models: HashMap<String, Arc<ModelSpec>>,
}

impl Artifacts {
    /// An artifact-less placeholder for frontends that can come up before
    /// any compiled model exists (e.g. the serving smoke tests): binding,
    /// `ping`/`config`/`stats` all work; engine construction against it
    /// fails with a clear "unknown model" error.
    pub fn empty() -> Self {
        Self { dir: PathBuf::new(), models: HashMap::new() }
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let v = json::from_file(&manifest_path)?;
        let version = v.get("version").as_i64().unwrap_or(-1);
        if version != SUPPORTED_VERSION {
            bail!(
                "manifest version {version} unsupported (runtime expects {SUPPORTED_VERSION}); \
                 re-run `make artifacts`"
            );
        }
        let mut models = HashMap::new();
        let Some(obj) = v.get("models").as_obj() else {
            bail!("manifest has no models object");
        };
        for (name, mv) in obj {
            let weights = mv.get("weights");
            let mut leaves = Vec::new();
            for lv in weights.req_arr("leaves")? {
                leaves.push(WeightLeaf {
                    name: lv.req_str("name")?.to_string(),
                    shape: lv.req_arr("shape")?.iter().filter_map(|d| d.as_usize()).collect(),
                    offset: lv.req_usize("offset")?,
                    size: lv.req_usize("size")?,
                });
            }
            let mut entries = HashMap::new();
            if let Some(eobj) = mv.get("entries").as_obj() {
                for (ename, ev) in eobj {
                    let parse_io = |key: &str| -> Result<Vec<IoSpec>> {
                        ev.req_arr(key)?
                            .iter()
                            .map(|io| {
                                Ok(IoSpec {
                                    name: io.req_str("name")?.to_string(),
                                    shape: io
                                        .req_arr("shape")?
                                        .iter()
                                        .filter_map(|d| d.as_usize())
                                        .collect(),
                                    dtype: DType::from_name(io.req_str("dtype")?)?,
                                })
                            })
                            .collect()
                    };
                    entries.insert(
                        ename.clone(),
                        EntrySpec {
                            name: ename.clone(),
                            file: dir.join(ev.req_str("file")?),
                            inputs: parse_io("inputs")
                                .with_context(|| format!("{name}.{ename} inputs"))?,
                            outputs: parse_io("outputs")
                                .with_context(|| format!("{name}.{ename} outputs"))?,
                        },
                    );
                }
            }
            models.insert(
                name.clone(),
                Arc::new(ModelSpec {
                    name: name.clone(),
                    kind: mv.req_str("kind")?.to_string(),
                    config: mv.get("config").clone(),
                    weights_file: dir.join(weights.req_str("file")?),
                    weight_leaves: leaves,
                    entries,
                }),
            );
        }
        Ok(Self { dir: dir.to_path_buf(), models })
    }

    /// Default artifact location: `$OMNI_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("OMNI_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn model(&self, name: &str) -> Result<Arc<ModelSpec>> {
        self.models
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("manifest has no model `{name}`"))
    }

    /// Load a model's weight blob (f32 little-endian) into memory.
    pub fn load_weights(&self, model: &ModelSpec) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&model.weights_file)
            .with_context(|| format!("reading {}", model.weights_file.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("weight blob not a multiple of 4 bytes");
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let expect = model.total_weight_floats();
        if floats.len() != expect {
            bail!("weight blob has {} floats, manifest says {expect}", floats.len());
        }
        Ok(floats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art() -> Option<Artifacts> {
        let dir = Artifacts::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Artifacts::load(&dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn loads_shipped_manifest() {
        let Some(a) = art() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = a.model("thinker25").unwrap();
        assert_eq!(m.kind, "ar");
        assert_eq!(m.cfg_usize("d_model").unwrap(), 256);
        assert_eq!(m.buckets("decode"), vec![1, 2, 4, 8]);
        let e = m.entry("decode.b4").unwrap();
        assert_eq!(e.inputs[0].name, "token");
        assert_eq!(e.inputs[0].shape, vec![4]);
        // KV tensor shape: [L, 2, B, H, S, dh]
        assert_eq!(e.inputs[1].shape, vec![4, 2, 4, 4, 256, 64]);
    }

    #[test]
    fn bucket_selection() {
        let Some(a) = art() else { return };
        let m = a.model("thinker25").unwrap();
        assert_eq!(m.bucket_entry("decode", 3, "").unwrap(), "decode.b4");
        assert_eq!(m.bucket_entry("decode", 1, "").unwrap(), "decode.b1");
        assert_eq!(m.bucket_entry("decode", 8, "").unwrap(), "decode.b8");
        // Oversized requests clamp to the largest bucket (caller splits).
        assert_eq!(m.bucket_entry("decode", 100, "").unwrap(), "decode.b8");
        assert_eq!(m.bucket_entry("prefill", 2, ".c32").unwrap(), "prefill.b2.c32");
    }

    #[test]
    fn weights_load_and_match() {
        let Some(a) = art() else { return };
        let m = a.model("talker25").unwrap();
        let w = a.load_weights(&m).unwrap();
        assert_eq!(w.len(), m.total_weight_floats());
        assert!(w.iter().any(|&x| x != 0.0));
    }
}
