//! Per-engine PJRT runtime: compiles HLO-text artifacts on a thread-local
//! CPU client and executes them with device-resident weights.
//!
//! `xla::PjRtClient` is `Rc`-based (not `Send`), so each engine thread
//! owns a [`StageRuntime`].  Only host tensors ([`HostTensor`]) cross
//! threads — which is exactly the disaggregation boundary the paper draws
//! between stages.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::artifact::{Artifacts, EntrySpec, ModelSpec};
use super::tensor::{DType, HostTensor, TensorData};
use crate::util::stats::Welford;

/// One stage's executable set + weights on a thread-local PJRT client.
pub struct StageRuntime {
    client: xla::PjRtClient,
    model: Arc<ModelSpec>,
    /// Weight buffers, device-resident, in manifest leaf order.
    weights: Vec<xla::PjRtBuffer>,
    /// Lazily compiled executables by entry name.
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Where to find HLO files (from [`Artifacts`]).
    pub exec_stats: HashMap<String, Welford>,
}

impl std::fmt::Debug for StageRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StageRuntime")
            .field("model", &self.model.name)
            .field("compiled", &self.executables.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl StageRuntime {
    /// Create a runtime for `model`, uploading its weights to the device.
    pub fn new(artifacts: &Artifacts, model_name: &str) -> Result<Self> {
        let model = artifacts.model(model_name)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let blob = artifacts.load_weights(&model)?;
        let mut weights = Vec::with_capacity(model.weight_leaves.len());
        for leaf in &model.weight_leaves {
            let slice = &blob[leaf.offset..leaf.offset + leaf.size];
            let buf = client
                .buffer_from_host_buffer(slice, &leaf.shape, None)
                .with_context(|| format!("uploading weight {}", leaf.name))?;
            weights.push(buf);
        }
        Ok(Self { client, model, weights, executables: HashMap::new(), exec_stats: HashMap::new() })
    }

    pub fn model(&self) -> &Arc<ModelSpec> {
        &self.model
    }

    /// Pre-compile a set of entries (engine init; avoids first-request
    /// compile latency — the paper's "execution graph compilation").
    pub fn precompile(&mut self, entries: &[String]) -> Result<()> {
        for e in entries {
            self.ensure_compiled(e)?;
        }
        Ok(())
    }

    pub fn is_compiled(&self, entry: &str) -> bool {
        self.executables.contains_key(entry)
    }

    fn ensure_compiled(&mut self, entry: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(entry) {
            let spec = self.model.entry(entry)?;
            let exe = compile_hlo(&self.client, spec)?;
            self.executables.insert(entry.to_string(), exe);
        }
        Ok(&self.executables[entry])
    }

    /// Drop a compiled executable.
    pub fn evict(&mut self, entry: &str) {
        self.executables.remove(entry);
    }

    /// Drop all compiled executables (baseline per-request recompile mode:
    /// no cross-request execution-graph reuse).
    pub fn evict_all(&mut self) {
        self.executables.clear();
    }

    /// Execute `entry` with the given non-weight inputs.  Inputs are
    /// validated against the manifest spec; outputs are downloaded to
    /// host tensors in manifest order.
    pub fn run(&mut self, entry: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.model.entry(entry)?.clone();
        validate_inputs(&spec, inputs)?;
        self.ensure_compiled(entry)?;
        let t0 = std::time::Instant::now();

        // Upload per-call args.
        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for t in inputs {
            args.push(upload(&self.client, t)?);
        }
        let exe = &self.executables[entry];
        let mut all: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.weights.len() + args.len());
        all.extend(self.weights.iter());
        all.extend(args.iter());

        let outs = exe.execute_b(&all).with_context(|| format!("executing {entry}"))?;
        let tuple = outs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow::anyhow!("{entry}: no output buffer"))?;
        let lit = tuple.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!("{entry}: got {} outputs, manifest says {}", parts.len(), spec.outputs.len());
        }
        let mut result = Vec::with_capacity(parts.len());
        for (p, ospec) in parts.into_iter().zip(&spec.outputs) {
            result.push(download(p, ospec.dtype)?);
        }
        self.exec_stats
            .entry(entry.to_string())
            .or_default()
            .push(t0.elapsed().as_secs_f64());
        Ok(result)
    }
}

fn compile_hlo(client: &xla::PjRtClient, spec: &EntrySpec) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        spec.file
            .to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
    )
    .with_context(|| format!("loading HLO {}", spec.file.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("compiling {}", spec.name))
}

fn upload(client: &xla::PjRtClient, t: &HostTensor) -> Result<xla::PjRtBuffer> {
    Ok(match &t.data {
        TensorData::F32(v) => client.buffer_from_host_buffer(v, &t.shape, None)?,
        TensorData::I32(v) => client.buffer_from_host_buffer(v, &t.shape, None)?,
    })
}

fn download(lit: xla::Literal, dtype: DType) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    Ok(match dtype {
        DType::F32 => HostTensor::f32(dims, lit.to_vec::<f32>()?),
        DType::I32 => HostTensor::i32(dims, lit.to_vec::<i32>()?),
    })
}

fn validate_inputs(spec: &EntrySpec, inputs: &[HostTensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "{}: got {} inputs, manifest says {} ({:?})",
            spec.name,
            inputs.len(),
            spec.inputs.len(),
            spec.inputs.iter().map(|i| &i.name).collect::<Vec<_>>()
        );
    }
    for (t, ispec) in inputs.iter().zip(&spec.inputs) {
        if t.shape != ispec.shape {
            bail!(
                "{}.{}: shape {:?} != manifest {:?}",
                spec.name,
                ispec.name,
                t.shape,
                ispec.shape
            );
        }
        if t.dtype() != ispec.dtype {
            bail!("{}.{}: dtype {:?} != manifest {:?}", spec.name, ispec.name, t.dtype(), ispec.dtype);
        }
    }
    Ok(())
}
