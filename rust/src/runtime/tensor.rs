//! Host-side tensors: the currency between engines, connectors, and the
//! PJRT runtime.  Deliberately simple — dense row-major f32/i32 only,
//! matching the AOT manifest's dtype universe.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => bail!("unsupported dtype `{other}`"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }

    pub fn size(self) -> usize {
        4
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data: TensorData::I32(data) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self::f32(shape, vec![0.0; n])
    }

    pub fn zeros_i32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self::i32(shape, vec![0; n])
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len() * self.dtype().size()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32_mut(&mut self) -> Result<&mut [i32]> {
        match &mut self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Serialize to bytes (connector wire format): dtype tag, rank, dims,
    /// raw data — all little-endian.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + self.shape.len() * 8 + self.byte_len());
        out.push(match self.dtype() {
            DType::F32 => 0u8,
            DType::I32 => 1u8,
        });
        out.extend_from_slice(&(self.shape.len() as u32).to_le_bytes());
        for &d in &self.shape {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        match &self.data {
            TensorData::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 5 {
            bail!("tensor bytes too short");
        }
        let tag = bytes[0];
        let rank = u32::from_le_bytes(bytes[1..5].try_into().unwrap()) as usize;
        let mut pos = 5;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            if pos + 8 > bytes.len() {
                bail!("tensor bytes truncated in dims");
            }
            shape.push(u64::from_le_bytes(bytes[pos..pos + 8].try_into().unwrap()) as usize);
            pos += 8;
        }
        let n: usize = shape.iter().product();
        if pos + n * 4 > bytes.len() {
            bail!("tensor bytes truncated in data ({} < {})", bytes.len() - pos, n * 4);
        }
        match tag {
            0 => {
                let v = bytes[pos..pos + n * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Ok(Self::f32(shape, v))
            }
            1 => {
                let v = bytes[pos..pos + n * 4]
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Ok(Self::i32(shape, v))
            }
            t => bail!("unknown tensor dtype tag {t}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;

    #[test]
    fn roundtrip_bytes() {
        let t = HostTensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let u = HostTensor::from_bytes(&t.to_bytes()).unwrap();
        assert_eq!(t, u);
        let t = HostTensor::i32(vec![4], vec![-1, 0, 7, 42]);
        let u = HostTensor::from_bytes(&t.to_bytes()).unwrap();
        assert_eq!(t, u);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn truncation_detected() {
        let t = HostTensor::f32(vec![8], vec![0.5; 8]);
        let mut b = t.to_bytes();
        b.truncate(b.len() - 3);
        assert!(HostTensor::from_bytes(&b).is_err());
    }

    #[test]
    fn prop_bytes_roundtrip() {
        quick("tensor_roundtrip", |rng| {
            let rank = rng.range(0, 3);
            let shape: Vec<usize> = (0..rank).map(|_| rng.range(1, 8)).collect();
            let n: usize = shape.iter().product();
            if rng.bool(0.5) {
                let data: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
                let t = HostTensor::f32(shape, data);
                assert_eq!(HostTensor::from_bytes(&t.to_bytes()).unwrap(), t);
            } else {
                let data: Vec<i32> = (0..n).map(|_| rng.next_u64() as i32).collect();
                let t = HostTensor::i32(shape, data);
                assert_eq!(HostTensor::from_bytes(&t.to_bytes()).unwrap(), t);
            }
        });
    }
}
