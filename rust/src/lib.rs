//! # omni-serve
//!
//! A fully disaggregated serving system for any-to-any multimodal models —
//! a from-scratch reproduction of *vLLM-Omni: Fully Disaggregated Serving
//! for Any-to-Any Multimodal Models* (CS.DC 2026).
//!
//! The system decomposes complex any-to-any architectures (Thinker→Talker→
//! Vocoder speech pipelines, AR+DiT image pipelines, patch-codec audio
//! pipelines) into a [`stage_graph::StageGraph`]: nodes are model stages
//! served by independent engines ([`engine::ar`] — a vLLM-like continuous-
//! batching engine — and [`engine::diffusion`] — a DiT denoising engine),
//! edges are transfer functions routed through a unified
//! [`connector`] (inline queue / POSIX shared memory / Mooncake-like
//! TCP).  The [`orchestrator`] owns request lifecycles and streaming
//! stage output; each stage pulls batches from a per-stage admission
//! queue governed by a [`scheduler`] batching policy (continuous
//! batching for AR stages, step-level batching for diffusion stages,
//! FIFO for encoders/vocoders).  Hot stages scale out with
//! `StageConfig::replicas`: the [`connector::router`] layer fans items
//! across engine replicas (round-robin / least-depth / request-affinity)
//! and the allocator packs each replica onto the least-loaded devices —
//! the paper's "flexible GPU allocation".  Under live traffic the
//! [`serving`] runtime keeps the stage graph up across requests
//! ([`serving::ServingSession`]) and an elastic autoscaler moves
//! replicas toward whichever stage is the bottleneck at runtime, within
//! a global GPU budget.  The client surface is streaming-first: typed
//! [`serving::OmniRequest`]s (priority, deadline, streaming on/off)
//! return a [`serving::ResponseStream`] of mid-flight
//! [`serving::OutputDelta`]s — text tokens, audio chunks, image frames,
//! stage markers — with end-to-end cancellation that drops queued work
//! and frees in-flight KV at every stage.  Pipelines can also span
//! machines: the [`cluster`] module adds node agents, an `OCTL` control
//! plane, and a transfer-cost-aware placement engine that keeps heavy
//! KV edges node-local while letting byte-light edges cross nodes.
//! Stage workers are event-driven: the [`event_core`] layer parks idle
//! threads on condvar wake mailboxes (no spin-polling), runs the live
//! runtime and `scheduler::sim` over one shared loop body via its
//! `Driver` trait, and records checksummed event logs for
//! deterministic, bit-identical trace replay.
//!
//! Model compute is AOT-lowered from JAX/Pallas (see `python/compile/`)
//! into HLO-text artifacts executed through the PJRT CPU client
//! ([`runtime`]).  Python never runs on the request path.
//!
//! ```text
//!  requests ──► orchestrator ──► [Thinker engine] ─connector─► [Talker engine]
//!                   │                 (AR, vLLM-like)             (AR, per-step
//!                   │                                              preprocess)
//!                   └── metrics ◄── [Vocoder engine] ◄─connector─────┘
//!                                     (DiT / CNN)
//! ```

pub mod audio;
pub mod baseline;
pub mod bench_util;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod connector;
pub mod device;
pub mod engine;
pub mod event_core;
pub mod gpu_share;
pub mod json;
pub mod kv_cache;
pub mod kv_transfer;
pub mod metrics;
pub mod orchestrator;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod serving;
pub mod stage_graph;
pub mod tokenizer;
pub mod trace;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
