//! Fractional GPU sharing (ROADMAP: "fractional GPU sharing + richer
//! any-to-any topologies").
//!
//! The paper's flexible GPU allocation stops at whole-GPU granularity,
//! yet encoder and vocoder stages are tiny next to prefill/decode and
//! the DiT — a whole device per replica strands most of its capacity.
//! This module turns each simulated device into a *partitionable*
//! resource:
//!
//! * [`FracSlot`] — a fraction of one device: a compute share in
//!   milli-GPUs (1000 = the whole device) plus a hard memory partition.
//! * [`DeviceShare`] — the per-device slot registry.  Carving a slot
//!   checks the compute ledger (Σ milli ≤ [`DEVICE_MILLI`]) and reserves
//!   the slot's memory through [`DevicePool`], so memory partitioning is
//!   enforced by the same admission that rejects over-subscribed
//!   whole-GPU pipelines.
//! * [`TimeSlice`] — the per-device scheduler engine loops yield to:
//!   weighted round-robin over resident slots with a configurable
//!   quantum, preemption only at step boundaries (a grant wraps exactly
//!   one engine iteration; an exhausted turn passes to the next waiting
//!   slot), and per-slot utilization/wait counters.
//! * [`MilliLedger`] — the packing-side compute ledger shared by the
//!   stage allocator, the autoscaler, and cluster placement: fractional
//!   replicas pack onto the least-loaded device *by milli*, so an
//!   encoder and a vocoder co-reside on one device and the freed
//!   capacity buys extra replicas for the bottleneck stage.
//!
//! Ground truth for the win lives in
//! [`crate::scheduler::sim::fractional_comparison`]: packed fractional
//! allocation must beat whole-GPU packing on mean JCT at equal hardware
//! for every seed.

use std::sync::{Condvar, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::device::{DeviceId, DevicePool, Reservation};

/// Compute capacity of one device in milli-GPUs.
pub const DEVICE_MILLI: u32 = 1000;

/// A fractional slot carved out of one device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FracSlot {
    /// Compute share in milli-GPUs (1..=1000; 1000 = the whole device).
    pub compute_milli: u32,
    /// Hard memory partition backing the slot (weights + KV).
    pub mem_bytes: usize,
}

/// Handle to one resident slot of a device's [`TimeSlice`]/[`DeviceShare`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlotId(pub usize);

/// Per-slot scheduling counters (monotone; read via
/// [`TimeSlice::counters`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SliceCounters {
    /// Step grants issued to the slot.
    pub grants: u64,
    /// Turns taken away at a step boundary while the slot still wanted
    /// the device (quantum exhausted with a competitor waiting).
    pub preemptions: u64,
    /// Seconds the slot held the device (utilization numerator).
    pub held_s: f64,
    /// Seconds the slot spent blocked waiting for its turn.
    pub waited_s: f64,
}

#[derive(Debug)]
struct SlotState {
    weight_milli: u32,
    /// Threads currently blocked in `acquire` for this slot.
    waiting: usize,
    live: bool,
    counters: SliceCounters,
}

#[derive(Debug)]
struct Wrr {
    slots: Vec<SlotState>,
    /// Slot index whose turn it is.
    current: usize,
    /// Seconds left of the current slot's turn.
    budget_s: f64,
    /// Whether a grant is outstanding (grants are exclusive).
    busy: bool,
}

/// Weighted round-robin time-slice scheduler for one device.
///
/// Engine stage loops wrap each `engine.step()` in
/// [`TimeSlice::acquire`]: the returned [`StepGrant`] is exclusive, so
/// co-resident stages interleave at step boundaries — never mid-step —
/// with turn lengths proportional to their compute share.
#[derive(Debug)]
pub struct TimeSlice {
    state: Mutex<Wrr>,
    turn: Condvar,
    /// Full turn length for a whole-device (1000 milli) slot, seconds.
    quantum_s: f64,
}

impl TimeSlice {
    pub fn new(quantum_ms: f64) -> Self {
        Self {
            state: Mutex::new(Wrr { slots: Vec::new(), current: 0, budget_s: 0.0, busy: false }),
            turn: Condvar::new(),
            quantum_s: quantum_ms.max(0.0) / 1e3,
        }
    }

    /// Register a resident slot; its turn length is
    /// `quantum * weight_milli / 1000`.
    pub fn add_slot(&self, weight_milli: u32) -> SlotId {
        let mut s = self.state.lock().unwrap();
        s.slots.push(SlotState {
            weight_milli: weight_milli.clamp(1, DEVICE_MILLI),
            waiting: 0,
            live: true,
            counters: SliceCounters::default(),
        });
        SlotId(s.slots.len() - 1)
    }

    /// Retire a slot (elastic scale-down): it stops being scheduled and
    /// its turn passes on.
    pub fn remove_slot(&self, id: SlotId) {
        let mut s = self.state.lock().unwrap();
        if let Some(slot) = s.slots.get_mut(id.0) {
            slot.live = false;
        }
        self.turn.notify_all();
    }

    /// One slot's turn length in seconds (weighted quantum).
    pub fn turn_budget_s(&self, weight_milli: u32) -> f64 {
        self.quantum_s * f64::from(weight_milli.clamp(1, DEVICE_MILLI)) / f64::from(DEVICE_MILLI)
    }

    /// Threads currently blocked waiting for a turn (test visibility).
    pub fn waiting(&self) -> usize {
        self.state.lock().unwrap().slots.iter().map(|s| s.waiting).sum()
    }

    /// Snapshot one slot's counters.
    pub fn counters(&self, id: SlotId) -> SliceCounters {
        let s = self.state.lock().unwrap();
        s.slots.get(id.0).map(|x| x.counters).unwrap_or_default()
    }

    /// Block until `id` may run one engine step, then return the
    /// exclusive grant.  Work-conserving: when the turn-holding slot is
    /// idle (no thread asking), the turn skips ahead to the next waiting
    /// slot instead of stalling the device.
    pub fn acquire(&self, id: SlotId) -> StepGrant<'_> {
        let t0 = Instant::now();
        let mut s = self.state.lock().unwrap();
        s.slots[id.0].waiting += 1;
        loop {
            if !s.busy {
                let cur = &s.slots[s.current];
                if !cur.live || cur.waiting == 0 {
                    // Current slot is retired or not asking: pass the
                    // turn along to the next waiting live slot.
                    if let Some(next) = next_wanting(&s.slots, s.current) {
                        s.current = next;
                        s.budget_s = self.turn_budget_s(s.slots[next].weight_milli);
                    }
                }
                if s.current == id.0 {
                    s.busy = true;
                    let slot = &mut s.slots[id.0];
                    slot.waiting -= 1;
                    slot.counters.grants += 1;
                    slot.counters.waited_s += t0.elapsed().as_secs_f64();
                    return StepGrant { ts: self, id, t0: Instant::now() };
                }
            }
            s = self.turn.wait(s).unwrap();
        }
    }

    /// Grant-drop bookkeeping: charge the held time against the turn
    /// budget; an exhausted turn passes to the next waiting slot (a
    /// step-boundary preemption when the holder still wants more).
    fn release(&self, id: SlotId, held: f64) {
        let mut s = self.state.lock().unwrap();
        s.busy = false;
        s.slots[id.0].counters.held_s += held;
        s.budget_s -= held;
        if s.budget_s <= 0.0 {
            if let Some(next) = next_wanting(&s.slots, s.current) {
                if next != s.current {
                    if s.slots[s.current].waiting > 0 {
                        s.slots[s.current].counters.preemptions += 1;
                    }
                    s.current = next;
                }
                s.budget_s = self.turn_budget_s(s.slots[s.current].weight_milli);
            }
        }
        drop(s);
        self.turn.notify_all();
    }
}

/// Next live slot at or after `from + 1` (wrapping) with a waiter;
/// `None` when nobody is asking.
fn next_wanting(slots: &[SlotState], from: usize) -> Option<usize> {
    let n = slots.len();
    (1..=n).map(|k| (from + k) % n).find(|&i| slots[i].live && slots[i].waiting > 0)
}

/// Exclusive permission for one engine step on a shared device.
pub struct StepGrant<'a> {
    ts: &'a TimeSlice,
    id: SlotId,
    t0: Instant,
}

impl Drop for StepGrant<'_> {
    fn drop(&mut self) {
        self.ts.release(self.id, self.t0.elapsed().as_secs_f64());
    }
}

/// Per-device slot registry: carving a slot checks the compute ledger
/// and hard-partitions the slot's memory through [`DevicePool`].
#[derive(Debug)]
pub struct DeviceShare {
    device: DeviceId,
    carved_milli: Mutex<u32>,
}

/// A successfully carved slot: the compute grant plus the memory
/// partition backing it.
#[derive(Debug)]
pub struct CarvedSlot {
    pub device: DeviceId,
    pub slot: FracSlot,
    pub reservation: Reservation,
}

impl DeviceShare {
    pub fn new(device: DeviceId) -> Self {
        Self { device, carved_milli: Mutex::new(0) }
    }

    pub fn device(&self) -> DeviceId {
        self.device
    }

    /// Milli-GPUs already carved out of the device.
    pub fn carved_milli(&self) -> u32 {
        *self.carved_milli.lock().unwrap()
    }

    /// Carve a fractional slot: admit the compute share against the
    /// 1000-milli ledger and hard-partition `mem_bytes` through `pool`
    /// (the same admission that rejects over-subscribed whole-GPU
    /// pipelines).  Either both succeed or nothing is held.
    pub fn carve(&self, pool: &DevicePool, slot: FracSlot, label: &str) -> Result<CarvedSlot> {
        if slot.compute_milli == 0 || slot.compute_milli > DEVICE_MILLI {
            bail!(
                "slot `{label}`: compute_milli {} out of range 1..={DEVICE_MILLI}",
                slot.compute_milli
            );
        }
        let mut carved = self.carved_milli.lock().unwrap();
        if *carved + slot.compute_milli > DEVICE_MILLI {
            bail!(
                "device {} compute over-subscribed: {} milli carved + {} requested \
                 ({label}) > {DEVICE_MILLI}",
                self.device.0,
                *carved,
                slot.compute_milli
            );
        }
        let reservation = pool.reserve(self.device, slot.mem_bytes, label)?;
        *carved += slot.compute_milli;
        Ok(CarvedSlot { device: self.device, slot, reservation })
    }

    /// Return a carved slot: frees the compute share and the memory
    /// partition.
    pub fn free(&self, pool: &DevicePool, carved: &CarvedSlot) {
        let mut c = self.carved_milli.lock().unwrap();
        *c = c.saturating_sub(carved.slot.compute_milli);
        pool.release(&carved.reservation);
    }
}

/// Packing-side compute ledger: per-device carved milli, shared by the
/// stage allocator, the autoscaler, and cluster placement.
#[derive(Debug, Clone)]
pub struct MilliLedger {
    used: Vec<u32>,
}

impl MilliLedger {
    pub fn new(n_devices: usize) -> Self {
        Self { used: vec![0; n_devices] }
    }

    /// Seed the ledger from per-device whole-slot counts (each occupied
    /// whole slot consumes the full 1000 milli).
    pub fn from_slots(slots: &[usize]) -> Self {
        Self { used: slots.iter().map(|&s| (s as u32).saturating_mul(DEVICE_MILLI)).collect() }
    }

    pub fn used(&self, d: usize) -> u32 {
        self.used.get(d).copied().unwrap_or(DEVICE_MILLI)
    }

    pub fn fits(&self, d: usize, milli: u32) -> bool {
        d < self.used.len() && self.used[d] + milli <= DEVICE_MILLI
    }

    pub fn commit(&mut self, d: usize, milli: u32) {
        if let Some(u) = self.used.get_mut(d) {
            *u += milli;
        }
    }

    pub fn release(&mut self, d: usize, milli: u32) {
        if let Some(u) = self.used.get_mut(d) {
            *u = u.saturating_sub(milli);
        }
    }

    /// Least-loaded device (by carved milli) where `milli` still fits;
    /// lowest index wins ties for determinism.  `None` when no device
    /// has room.
    pub fn pack(&self, milli: u32) -> Option<usize> {
        (0..self.used.len())
            .filter(|&d| self.fits(d, milli))
            .min_by_key(|&d| (self.used[d], d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn carve_enforces_compute_and_memory() {
        let pool = DevicePool::new(1, 1000);
        let share = DeviceShare::new(DeviceId(0));
        let enc = share
            .carve(&pool, FracSlot { compute_milli: 300, mem_bytes: 400 }, "encoder")
            .unwrap();
        assert_eq!(share.carved_milli(), 300);
        assert_eq!(pool.used(DeviceId(0)), 400);
        // Compute over-subscription rejected, memory untouched.
        let err = share.carve(&pool, FracSlot { compute_milli: 800, mem_bytes: 100 }, "big");
        assert!(err.is_err());
        assert_eq!(pool.used(DeviceId(0)), 400);
        // Memory over-subscription rejected, compute ledger untouched.
        let err = share.carve(&pool, FracSlot { compute_milli: 100, mem_bytes: 900 }, "fat");
        assert!(err.is_err());
        assert_eq!(share.carved_milli(), 300);
        // Freeing returns both resources.
        share.free(&pool, &enc);
        assert_eq!(share.carved_milli(), 0);
        assert_eq!(pool.used(DeviceId(0)), 0);
    }

    #[test]
    fn zero_and_oversized_milli_rejected() {
        let pool = DevicePool::new(1, 1000);
        let share = DeviceShare::new(DeviceId(0));
        assert!(share.carve(&pool, FracSlot { compute_milli: 0, mem_bytes: 1 }, "z").is_err());
        assert!(share
            .carve(&pool, FracSlot { compute_milli: DEVICE_MILLI + 1, mem_bytes: 1 }, "o")
            .is_err());
        assert_eq!(pool.used(DeviceId(0)), 0);
    }

    #[test]
    fn milli_ledger_packs_least_loaded() {
        let mut l = MilliLedger::new(3);
        l.commit(0, 800);
        l.commit(1, 200);
        // Least-loaded device that fits wins; index breaks ties.
        assert_eq!(l.pack(300), Some(2));
        l.commit(2, 200);
        assert_eq!(l.pack(300), Some(1));
        // Too big for any device.
        assert_eq!(l.pack(900), None);
        l.release(0, 800);
        assert_eq!(l.pack(900), Some(0));
        // Seeding from whole-device slot counts marks them full.
        let l2 = MilliLedger::from_slots(&[1, 0]);
        assert!(!l2.fits(0, 1));
        assert!(l2.fits(1, 1000));
    }

    #[test]
    fn weighted_turn_budgets_are_proportional() {
        let ts = TimeSlice::new(4.0);
        let b750 = ts.turn_budget_s(750);
        let b250 = ts.turn_budget_s(250);
        assert!((b750 / b250 - 3.0).abs() < 1e-9);
        assert!((ts.turn_budget_s(DEVICE_MILLI) - 4.0e-3).abs() < 1e-12);
    }

    #[test]
    fn single_slot_never_waits_on_itself() {
        let ts = TimeSlice::new(1.0);
        let a = ts.add_slot(1000);
        for _ in 0..5 {
            let _g = ts.acquire(a);
        }
        let c = ts.counters(a);
        assert_eq!(c.grants, 5);
        assert_eq!(c.preemptions, 0);
    }

    #[test]
    fn turn_passes_to_waiter_at_step_boundary() {
        // Quantum 0: every step boundary is a potential preemption point.
        let ts = Arc::new(TimeSlice::new(0.0));
        let a = ts.add_slot(500);
        let b = ts.add_slot(500);
        let grant_a = ts.acquire(a);
        // A competitor blocks for its turn while A holds the device.
        let ts2 = ts.clone();
        let waiter = std::thread::spawn(move || {
            let _g = ts2.acquire(b);
        });
        while ts.waiting() == 0 {
            std::thread::yield_now();
        }
        // Releasing at the step boundary hands the turn to B and counts
        // a preemption against... nobody: A was not asking again.
        drop(grant_a);
        waiter.join().unwrap();
        assert_eq!(ts.counters(a).grants, 1);
        assert_eq!(ts.counters(b).grants, 1);
        assert!(ts.counters(b).waited_s >= 0.0);
    }

    #[test]
    fn co_resident_slots_interleave_to_completion() {
        // Two threads hammer the same device; both must finish all their
        // steps (no starvation, no deadlock) and the device is exclusive
        // per grant.
        let ts = Arc::new(TimeSlice::new(0.01));
        let a = ts.add_slot(750);
        let b = ts.add_slot(250);
        let excl = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut joins = Vec::new();
        for slot in [a, b] {
            let ts = ts.clone();
            let excl = excl.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let _g = ts.acquire(slot);
                    assert!(!excl.swap(true, std::sync::atomic::Ordering::SeqCst));
                    std::thread::yield_now();
                    excl.store(false, std::sync::atomic::Ordering::SeqCst);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(ts.counters(a).grants, 50);
        assert_eq!(ts.counters(b).grants, 50);
    }

    #[test]
    fn retired_slot_releases_the_turn() {
        let ts = Arc::new(TimeSlice::new(0.0));
        let a = ts.add_slot(500);
        let b = ts.add_slot(500);
        {
            let _g = ts.acquire(a);
        }
        ts.remove_slot(a);
        // B acquires immediately even though the rotation points at the
        // retired slot.
        let _g = ts.acquire(b);
        assert_eq!(ts.counters(b).grants, 1);
    }
}
