//! Synthetic tokenizer substrate.
//!
//! The paper serves real Qwen models with real BPE vocabularies; our
//! scaled models (DESIGN.md §7) use a deterministic hash tokenizer over
//! whitespace-split words plus byte fallback.  What matters for the
//! serving system is the *token stream shape* (ids in-vocab, stable
//! round-trip length), not linguistic fidelity.

/// Reserved special ids, aligned with `python/compile/configs.py`.
pub const PAD_ID: u32 = 0;
pub const BOS_ID: u32 = 1;
pub const EOS_ID: u32 = 2;
/// First non-special id.
pub const FIRST_ID: u32 = 8;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: u32,
}

impl Tokenizer {
    pub fn new(vocab: u32) -> Self {
        assert!(vocab > FIRST_ID, "vocab too small");
        Self { vocab }
    }

    pub fn vocab(&self) -> u32 {
        self.vocab
    }

    /// Deterministic word hash into `[FIRST_ID, vocab)`.
    fn word_id(&self, w: &str) -> u32 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in w.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        FIRST_ID + (h % (self.vocab - FIRST_ID) as u64) as u32
    }

    /// Encode text (BOS + one id per whitespace word; long words split
    /// into 4-byte subword pieces to mimic BPE length scaling).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids = vec![BOS_ID];
        for w in text.split_whitespace() {
            if w.len() <= 6 {
                ids.push(self.word_id(w));
            } else {
                for chunk in w.as_bytes().chunks(4) {
                    ids.push(self.word_id(std::str::from_utf8(chunk).unwrap_or("?")));
                }
            }
        }
        ids
    }

    /// Decode ids to a printable placeholder string (hash tokenizers are
    /// not invertible; serving only needs a stable surface form).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut out = String::new();
        for &id in ids {
            match id {
                PAD_ID | BOS_ID => {}
                EOS_ID => break,
                id => {
                    if !out.is_empty() {
                        out.push(' ');
                    }
                    out.push_str(&format!("w{id}"));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;
    use crate::util::Prng;

    #[test]
    fn encode_is_deterministic_and_in_vocab() {
        let t = Tokenizer::new(4096);
        let a = t.encode("the quick brown fox");
        let b = t.encode("the quick brown fox");
        assert_eq!(a, b);
        assert_eq!(a[0], BOS_ID);
        assert!(a.iter().all(|&id| id < 4096));
    }

    #[test]
    fn longer_text_longer_ids() {
        let t = Tokenizer::new(4096);
        let short = t.encode("hi there");
        let long = t.encode("hi there this is a much longer sentence with many words");
        assert!(long.len() > short.len());
    }

    #[test]
    fn decode_stops_at_eos() {
        let t = Tokenizer::new(64);
        let s = t.decode(&[BOS_ID, 10, 11, EOS_ID, 12]);
        assert!(s.contains("w10") && s.contains("w11") && !s.contains("w12"));
    }

    #[test]
    fn prop_ids_always_in_vocab() {
        quick("tokenizer_in_vocab", |rng: &mut Prng| {
            let vocab = rng.range(16, 8192) as u32;
            let t = Tokenizer::new(vocab);
            let n_words = rng.range(0, 30);
            let text: Vec<String> =
                (0..n_words).map(|_| format!("word{}", rng.below(1000))).collect();
            for id in t.encode(&text.join(" ")) {
                assert!(id < vocab, "id {id} vocab {vocab}");
            }
        });
    }
}
