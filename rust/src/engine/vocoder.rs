//! Single-forward stages: the CNN vocoder (Qwen3-Omni) and the MiMo
//! patch decoder.  Each submitted chunk of codec tokens is one batched
//! forward; no iterative state.

use std::collections::VecDeque;

use anyhow::{Context, Result};

use crate::engine::StageItem;
use crate::runtime::{Artifacts, HostTensor, StageRuntime};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VocoderKind {
    /// `voc_cnn3`-style: entry `vocode.bN`, tokens [B, T] -> wave [B, T*up].
    Cnn,
    /// `mimo_codec`-style: entry `decode.bN`, tokens [B, T] ->
    /// patches [B, T, samples_per_patch].
    PatchDecoder,
}

#[derive(Debug, Clone)]
pub struct VocoderJob {
    pub req_id: u64,
    pub chunk_idx: usize,
    /// Codec token ids for this chunk (<= frame capacity; padded here).
    pub tokens: Vec<u32>,
    pub final_chunk: bool,
}

#[derive(Debug, Default, Clone)]
pub struct VocoderStats {
    pub chunks_done: u64,
    pub calls: u64,
    pub exec_seconds: f64,
}

/// Batched single-forward engine.
pub struct VocoderEngine {
    rt: StageRuntime,
    kind: VocoderKind,
    /// Frames per call (t_frames / t_max from the manifest).
    t_frames: usize,
    /// Output samples per frame.
    upsample: usize,
    max_batch: usize,
    queue: VecDeque<VocoderJob>,
    pub stats: VocoderStats,
}

impl VocoderEngine {
    pub fn new(
        artifacts: &Artifacts,
        model: &str,
        kind: VocoderKind,
        max_batch: usize,
        lazy_compile: bool,
    ) -> Result<Self> {
        let rt = StageRuntime::new(artifacts, model)
            .with_context(|| format!("creating vocoder engine for {model}"))?;
        let spec = rt.model().clone();
        let (t_frames, upsample) = match kind {
            VocoderKind::Cnn => (spec.cfg_usize("t_frames")?, spec.cfg_usize("upsample")?),
            VocoderKind::PatchDecoder => {
                (spec.cfg_usize("t_max")?, spec.cfg_usize("samples_per_patch")?)
            }
        };
        let mut eng = Self {
            rt,
            kind,
            t_frames,
            upsample,
            max_batch,
            queue: VecDeque::new(),
            stats: VocoderStats::default(),
        };
        if !lazy_compile {
            let fam = eng.family();
            let entries: Vec<String> = eng
                .rt
                .model()
                .buckets(fam)
                .into_iter()
                .filter(|&b| b <= max_batch.next_power_of_two())
                .map(|b| format!("{fam}.b{b}"))
                .collect();
            eng.rt.precompile(&entries)?;
        }
        Ok(eng)
    }

    fn family(&self) -> &'static str {
        match self.kind {
            VocoderKind::Cnn => "vocode",
            VocoderKind::PatchDecoder => "decode",
        }
    }

    /// Frames consumed per chunk.
    pub fn frames_per_chunk(&self) -> usize {
        self.t_frames
    }

    pub fn samples_per_frame(&self) -> usize {
        self.upsample
    }

    pub fn submit(&mut self, job: VocoderJob) {
        self.queue.push_back(job);
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Abort a request: its queued chunks are dropped (a single-forward
    /// engine holds no other per-request state).
    pub fn cancel(&mut self, req_id: u64) -> bool {
        let before = self.queue.len();
        self.queue.retain(|j| j.req_id != req_id);
        before != self.queue.len()
    }

    /// Process one batch of queued chunks.
    pub fn step(&mut self) -> Result<Vec<StageItem>> {
        if self.queue.is_empty() {
            return Ok(vec![]);
        }
        let take = self.queue.len().min(self.max_batch);
        let jobs: Vec<VocoderJob> = self.queue.drain(..take).collect();
        let buckets = self.rt.model().buckets(self.family());
        let b = buckets
            .iter()
            .copied()
            .find(|&x| x >= jobs.len())
            .or(buckets.last().copied())
            .ok_or_else(|| anyhow::anyhow!("no buckets for {}", self.model_name()))?;

        let t = self.t_frames;
        let mut tokens = vec![0i32; b * t];
        for (bi, job) in jobs.iter().enumerate() {
            for (ti, &tok) in job.tokens.iter().take(t).enumerate() {
                tokens[bi * t + ti] = tok as i32;
            }
        }
        let entry = format!("{}.b{b}", self.family());
        let t0 = std::time::Instant::now();
        let outputs = self.rt.run(&entry, &[HostTensor::i32(vec![b, t], tokens)])?;
        self.stats.exec_seconds += t0.elapsed().as_secs_f64();
        self.stats.calls += 1;
        let wave = outputs[0].as_f32()?;
        let per_lane = wave.len() / b;

        let mut out = Vec::with_capacity(jobs.len());
        for (bi, job) in jobs.iter().enumerate() {
            // Trim padding: only real frames produce audio.
            let real = job.tokens.len().min(t) * self.upsample;
            let w = wave[bi * per_lane..bi * per_lane + real].to_vec();
            self.stats.chunks_done += 1;
            let mut item = StageItem::new(job.req_id)
                .with("wave", HostTensor::f32(vec![w.len()], w))
                .with("chunk_idx", HostTensor::i32(vec![1], vec![job.chunk_idx as i32]))
                .with(
                    "n_frames",
                    HostTensor::i32(vec![1], vec![job.tokens.len().min(t) as i32]),
                );
            if job.final_chunk {
                item = item.finished();
            }
            out.push(item);
        }
        Ok(out)
    }

    /// Drop every compiled executable (baseline per-request recompile).
    pub fn evict_compiled(&mut self) {
        self.rt.evict_all();
    }

    pub fn run_to_completion(&mut self) -> Result<Vec<StageItem>> {
        let mut all = Vec::new();
        while !self.idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }

    pub fn model_name(&self) -> &str {
        &self.rt.model().name
    }
}
