//! Stage execution engines (paper §3.3).
//!
//! Each stage of a pipeline is served by an independent engine owning its
//! own PJRT client, compiled executables, scheduler, and (for AR stages)
//! KV manager:
//!
//! * [`ar`] — vLLM-like autoregressive engine: continuous batching,
//!   chunked prefill, paged-KV admission, per-iteration preprocess,
//!   multi-step fused decode.
//! * [`diffusion`] — DiT engine: batched denoising with CFG and a
//!   TeaCache-style step cache.
//! * [`vocoder`] — single-forward stages (CNN vocoder, patch decoder).
//!
//! Engines are synchronous state machines (`step()` advances one
//! iteration) so they are unit-testable; [`crate::orchestrator`] wraps
//! them in threads and wires connectors between them.

pub mod ar;
pub mod diffusion;
pub mod encoder;
pub mod vocoder;

use std::collections::BTreeMap;

use crate::runtime::HostTensor;

/// One unit of data flowing between stages: named tensors + lifecycle
/// flags.  Produced by engines, mapped by edge transfer functions, and
/// consumed by downstream engines.
#[derive(Debug, Clone)]
pub struct StageItem {
    pub req_id: u64,
    /// Named payload tensors ("tokens", "hiddens", "wave", "cond", ...).
    pub tensors: BTreeMap<String, HostTensor>,
    /// True when this is the request's final item from the stage.
    pub finished: bool,
}

impl StageItem {
    pub fn new(req_id: u64) -> Self {
        Self { req_id, tensors: BTreeMap::new(), finished: false }
    }

    pub fn with(mut self, name: &str, t: HostTensor) -> Self {
        self.tensors.insert(name.to_string(), t);
        self
    }

    pub fn finished(mut self) -> Self {
        self.finished = true;
        self
    }

    pub fn tensor(&self, name: &str) -> Option<&HostTensor> {
        self.tensors.get(name)
    }

    pub fn payload_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.byte_len()).sum()
    }
}

/// Sampling parameters for AR stages.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    pub max_new_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f32,
    pub top_k: usize,
    pub ignore_eos: bool,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { max_new_tokens: 64, temperature: 0.0, top_k: 0, ignore_eos: false, seed: 0 }
    }
}
