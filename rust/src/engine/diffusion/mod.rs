//! Diffusion engine (paper §3.3 "DiT stage support").
//!
//! Serves DiT stages — the Qwen2.5-Omni vocoder and the image/video
//! generators (BAGEL, Qwen-Image, Wan2.2 sims) — with:
//! * batched denoising across requests (per-stage request batching);
//! * classifier-free guidance folded into the AOT step executable;
//! * a **TeaCache-style step cache** ([`stepcache`]): when the timestep
//!   modulation embedding barely moves between steps, the previous
//!   epsilon is reused instead of running the trunk;
//! * streaming input (vocoder jobs arrive as codec-chunk items while the
//!   Talker is still generating).

pub mod denoise;
pub mod stepcache;

pub use denoise::{DiffusionEngine, DiffusionJob, DiffusionOptions, DiffusionStats};
pub use stepcache::StepCache;
