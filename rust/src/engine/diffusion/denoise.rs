//! The diffusion engine core: batched flow-matching (Euler) denoising
//! over the AOT `step` executable, with per-lane timesteps (continuous
//! batching for diffusion) and the TeaCache-style step cache.

use std::collections::VecDeque;

use anyhow::{Context, Result};

use super::stepcache::StepCache;
use crate::engine::StageItem;
use crate::runtime::{Artifacts, HostTensor, StageRuntime};
use crate::util::Prng;

#[derive(Debug, Clone)]
pub struct DiffusionOptions {
    pub max_batch: usize,
    pub steps: usize,
    pub cfg_scale: f32,
    /// TeaCache threshold (0 disables the step cache).
    pub stepcache_threshold: f32,
    /// Baseline mode: evict compiled executables after every call.
    pub lazy_compile: bool,
}

impl Default for DiffusionOptions {
    fn default() -> Self {
        Self { max_batch: 2, steps: 20, cfg_scale: 3.0, stepcache_threshold: 0.0, lazy_compile: false }
    }
}

/// One denoising job (a whole image, a video clip, or one vocoder chunk).
#[derive(Debug, Clone)]
pub struct DiffusionJob {
    pub req_id: u64,
    /// Chunk index for streaming stages (0 for one-shot jobs).
    pub chunk_idx: usize,
    /// Conditioning vector (`cond_dim` floats; empty if the model is
    /// unconditioned — it is zero-padded to the manifest width).
    pub cond: Vec<f32>,
    /// Per-token conditioning stream (vocoder codec embeds), row-major
    /// `[n_tokens, cond_tokens_dim]`; empty if unused.
    pub cond_tokens: Vec<f32>,
    pub seed: u64,
    /// Overrides engine default when > 0.
    pub steps: usize,
    /// Marks the request's final chunk (propagates `finished`).
    pub final_chunk: bool,
}

#[derive(Debug, Default, Clone)]
pub struct DiffusionStats {
    pub jobs_done: u64,
    pub steps_run: u64,
    pub steps_skipped: u64,
    pub calls: u64,
    pub exec_seconds: f64,
}

struct Lane {
    job: DiffusionJob,
    latent: Vec<f32>,
    step: usize,
    steps_total: usize,
    cache: StepCache,
}

/// The engine.  Owns a thread-local PJRT runtime; not `Send`.
pub struct DiffusionEngine {
    rt: StageRuntime,
    opts: DiffusionOptions,
    n_tokens: usize,
    latent_dim: usize,
    cond_dim: usize,
    cond_tokens_dim: usize,
    queue: VecDeque<DiffusionJob>,
    lanes: Vec<Lane>,
    pub stats: DiffusionStats,
}

impl DiffusionEngine {
    pub fn new(artifacts: &Artifacts, model: &str, opts: DiffusionOptions) -> Result<Self> {
        let rt = StageRuntime::new(artifacts, model)
            .with_context(|| format!("creating diffusion engine for {model}"))?;
        let spec = rt.model().clone();
        let mut eng = Self {
            rt,
            n_tokens: spec.cfg_usize("n_tokens")?,
            latent_dim: spec.cfg_usize("latent_dim")?,
            cond_dim: spec.cfg_usize("cond_dim").unwrap_or(0),
            cond_tokens_dim: spec.cfg_usize("cond_tokens_dim").unwrap_or(0),
            opts,
            queue: VecDeque::new(),
            lanes: Vec::new(),
            stats: DiffusionStats::default(),
        };
        if !eng.opts.lazy_compile {
            let entries: Vec<String> = eng
                .rt
                .model()
                .buckets("step")
                .into_iter()
                .filter(|&b| b <= eng.opts.max_batch.next_power_of_two())
                .map(|b| format!("step.b{b}"))
                .collect();
            eng.rt.precompile(&entries)?;
        }
        Ok(eng)
    }

    pub fn model_name(&self) -> &str {
        &self.rt.model().name
    }

    pub fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    pub fn cond_dim(&self) -> usize {
        self.cond_dim
    }

    pub fn cond_tokens_dim(&self) -> usize {
        self.cond_tokens_dim
    }

    pub fn submit(&mut self, job: DiffusionJob) {
        self.queue.push_back(job);
    }

    /// Submit a batch of jobs at one step boundary (a step-aligned
    /// cohort starting together).
    pub fn submit_many<I: IntoIterator<Item = DiffusionJob>>(&mut self, jobs: I) {
        for job in jobs {
            self.submit(job);
        }
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.lanes.is_empty()
    }

    /// Abort a request: queued chunks are dropped and in-flight lanes
    /// stop denoising (their remaining steps are never run).  Returns
    /// whether anything was dropped.
    pub fn cancel(&mut self, req_id: u64) -> bool {
        let before = self.queue.len() + self.lanes.len();
        self.queue.retain(|j| j.req_id != req_id);
        self.lanes.retain(|l| l.job.req_id != req_id);
        before != self.queue.len() + self.lanes.len()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Lanes currently denoising.
    pub fn running(&self) -> usize {
        self.lanes.len()
    }

    /// Current denoise step of every active lane (the step-level batching
    /// policy's cohort-alignment signal).
    pub fn lane_steps(&self) -> Vec<usize> {
        self.lanes.iter().map(|l| l.step).collect()
    }

    /// Advance one engine iteration: admit jobs, run one denoise step for
    /// every active lane (batched), emit finished jobs.
    pub fn step(&mut self) -> Result<Vec<StageItem>> {
        // Admit.
        while self.lanes.len() < self.opts.max_batch {
            let Some(job) = self.queue.pop_front() else { break };
            let mut prng = Prng::new(job.seed ^ 0xD1F);
            let latent: Vec<f32> =
                (0..self.n_tokens * self.latent_dim).map(|_| prng.normal() as f32).collect();
            let steps_total = if job.steps > 0 { job.steps } else { self.opts.steps };
            self.lanes.push(Lane { job, latent, step: 0, steps_total, cache: StepCache::default() });
        }
        if self.lanes.is_empty() {
            return Ok(vec![]);
        }

        // Split lanes into cache-hits (skip) and real computation.
        let thr = self.opts.stepcache_threshold;
        let mut run_ids: Vec<usize> = Vec::new();
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            let t = lane_t(lane.step, lane.steps_total);
            // Cache signal: relative drift of the noise level since the
            // last real trunk run (cheap host-side proxy for the
            // modulation-embedding drift TeaCache tracks).
            let sig = [t];
            if lane.cache.should_reuse(&sig, thr) {
                let eps = lane.cache.reused(&sig).to_vec();
                advance(lane, &eps);
            } else {
                run_ids.push(i);
            }
        }
        self.stats.steps_skipped += (self.lanes.len() - run_ids.len()) as u64;

        // Batched trunk execution for the rest.
        let buckets = self.rt.model().buckets("step");
        let mut idx = 0;
        while idx < run_ids.len() {
            let remaining = run_ids.len() - idx;
            let b = buckets
                .iter()
                .copied()
                .find(|&b| b >= remaining)
                .or(buckets.last().copied())
                .ok_or_else(|| anyhow::anyhow!("no step buckets for {}", self.model_name()))?;
            let group: Vec<usize> = run_ids[idx..(idx + b.min(remaining))].to_vec();
            idx += group.len();
            self.run_group(&group, b)?;
        }

        // Collect finished lanes.
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.lanes.len() {
            if self.lanes[i].step >= self.lanes[i].steps_total {
                let lane = self.lanes.swap_remove(i);
                self.stats.jobs_done += 1;
                let mut wave: Vec<f32> = lane.latent.iter().map(|&x| x.tanh()).collect();
                wave.truncate(self.n_tokens * self.latent_dim);
                let mut item = StageItem::new(lane.job.req_id)
                    .with(
                        "latent",
                        HostTensor::f32(vec![self.n_tokens, self.latent_dim], lane.latent),
                    )
                    .with("wave", HostTensor::f32(vec![wave.len()], wave))
                    .with(
                        "chunk_idx",
                        HostTensor::i32(vec![1], vec![lane.job.chunk_idx as i32]),
                    )
                    .with("n_frames", HostTensor::i32(vec![1], vec![self.n_tokens as i32]));
                if lane.job.final_chunk {
                    item = item.finished();
                }
                out.push(item);
            } else {
                i += 1;
            }
        }
        Ok(out)
    }

    /// Drop every compiled executable (baseline per-request recompile).
    pub fn evict_compiled(&mut self) {
        self.rt.evict_all();
    }

    pub fn run_to_completion(&mut self) -> Result<Vec<StageItem>> {
        let mut all = Vec::new();
        while !self.idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }

    fn run_group(&mut self, lane_ids: &[usize], b: usize) -> Result<()> {
        let n = self.n_tokens;
        let ld = self.latent_dim;
        let cd = self.cond_dim.max(1);
        let ctd = self.cond_tokens_dim.max(1);
        let mut latent = vec![0f32; b * n * ld];
        let mut cond = vec![0f32; b * cd];
        let mut cond_tokens = vec![0f32; b * n * ctd];
        let mut t = vec![0f32; b];
        let mut g = vec![1f32; b];
        for (bi, &li) in lane_ids.iter().enumerate() {
            let lane = &self.lanes[li];
            latent[bi * n * ld..(bi + 1) * n * ld].copy_from_slice(&lane.latent);
            if !lane.job.cond.is_empty() {
                let m = lane.job.cond.len().min(cd);
                cond[bi * cd..bi * cd + m].copy_from_slice(&lane.job.cond[..m]);
            }
            if !lane.job.cond_tokens.is_empty() {
                let m = lane.job.cond_tokens.len().min(n * ctd);
                cond_tokens[bi * n * ctd..bi * n * ctd + m]
                    .copy_from_slice(&lane.job.cond_tokens[..m]);
            }
            t[bi] = lane_t(lane.step, lane.steps_total);
            g[bi] = self.opts.cfg_scale;
        }
        let entry = format!("step.b{b}");
        let inputs = vec![
            HostTensor::f32(vec![b, n, ld], latent),
            HostTensor::f32(vec![b, cd], cond),
            HostTensor::f32(vec![b, n, ctd], cond_tokens),
            HostTensor::f32(vec![b], t),
            HostTensor::f32(vec![b], g),
        ];
        let t0 = std::time::Instant::now();
        let outputs = self.rt.run(&entry, &inputs)?;
        self.stats.exec_seconds += t0.elapsed().as_secs_f64();
        self.stats.calls += 1;
        let eps = outputs[0].as_f32()?;
        for (bi, &li) in lane_ids.iter().enumerate() {
            let lane = &mut self.lanes[li];
            let e = &eps[bi * n * ld..(bi + 1) * n * ld];
            let tt = lane_t(lane.step, lane.steps_total);
            lane.cache.store(&[tt], e);
            advance(lane, e);
            self.stats.steps_run += 1;
        }
        Ok(())
    }
}

/// Noise level for step `i` of `n`: linear 1 -> 1/n (flow-matching grid).
fn lane_t(i: usize, n: usize) -> f32 {
    1.0 - i as f32 / n as f32
}

/// Euler update: latent <- latent - dt * eps.
fn advance(lane: &mut Lane, eps: &[f32]) {
    let dt = 1.0 / lane.steps_total as f32;
    for (x, &e) in lane.latent.iter_mut().zip(eps) {
        *x -= dt * e;
    }
    lane.step += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t_grid_monotone() {
        let n = 10;
        for i in 1..n {
            assert!(lane_t(i, n) < lane_t(i - 1, n));
        }
        assert!((lane_t(0, n) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cache_skips_with_wide_threshold() {
        // 20-step schedule, threshold 0.3: early steps (small relative
        // drift of t) must be reusable.
        let mut c = crate::engine::diffusion::stepcache::StepCache::default();
        c.store(&[1.0], &[0.5]);
        assert!(c.should_reuse(&[0.95], 0.3));
    }
}
