//! TeaCache-style denoising step cache (paper §3.3 cites TeaCache /
//! cache-dit as the diffusion engine's caching strategies).
//!
//! TeaCache's observation: the timestep (modulation) embedding is a cheap,
//! accurate proxy for how much the model output will change between
//! consecutive denoising steps.  We accumulate the relative L1 change of
//! the modulation embedding; while the accumulated change stays under a
//! threshold, the trunk is skipped and the cached epsilon is reused.

/// Per-job cache state.
#[derive(Debug, Clone, Default)]
pub struct StepCache {
    /// Previous step's modulation embedding.
    prev_mod: Vec<f32>,
    /// Cached model output (epsilon).
    cached_eps: Vec<f32>,
    /// Accumulated relative change since the last real trunk run.
    accum: f32,
    pub hits: usize,
    pub misses: usize,
}

impl StepCache {
    /// Decide whether the cached epsilon may be reused given the new
    /// modulation embedding.  `threshold <= 0` disables caching.
    /// Call [`Self::store`] after a real run; on reuse call [`Self::reused`].
    pub fn should_reuse(&mut self, t_mod: &[f32], threshold: f32) -> bool {
        if threshold <= 0.0 || self.cached_eps.is_empty() || self.prev_mod.len() != t_mod.len() {
            return false;
        }
        let mut num = 0.0f32;
        let mut den = 0.0f32;
        for (&a, &b) in self.prev_mod.iter().zip(t_mod) {
            num += (a - b).abs();
            den += a.abs();
        }
        let rel = if den > 0.0 { num / den } else { f32::INFINITY };
        self.accum + rel < threshold
    }

    /// Record a real trunk run; accumulation restarts.
    pub fn store(&mut self, t_mod: &[f32], eps: &[f32]) {
        self.prev_mod = t_mod.to_vec();
        self.cached_eps = eps.to_vec();
        self.accum = 0.0;
        self.misses += 1;
    }

    /// Record a cache reuse, accumulating the skipped drift.
    pub fn reused(&mut self, t_mod: &[f32]) -> &[f32] {
        let mut num = 0.0f32;
        let mut den = 0.0f32;
        for (&a, &b) in self.prev_mod.iter().zip(t_mod) {
            num += (a - b).abs();
            den += a.abs();
        }
        self.accum += if den > 0.0 { num / den } else { 0.0 };
        self.prev_mod = t_mod.to_vec();
        self.hits += 1;
        &self.cached_eps
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_when_threshold_zero() {
        let mut c = StepCache::default();
        c.store(&[1.0, 1.0], &[0.5]);
        assert!(!c.should_reuse(&[1.0, 1.0], 0.0));
    }

    #[test]
    fn identical_mod_reuses() {
        let mut c = StepCache::default();
        c.store(&[1.0, 2.0], &[0.5, 0.6]);
        assert!(c.should_reuse(&[1.0, 2.0], 0.05));
        assert_eq!(c.reused(&[1.0, 2.0]), &[0.5, 0.6]);
        assert_eq!(c.hits, 1);
    }

    #[test]
    fn large_change_misses() {
        let mut c = StepCache::default();
        c.store(&[1.0, 1.0], &[0.5]);
        assert!(!c.should_reuse(&[3.0, -1.0], 0.05));
    }

    #[test]
    fn accumulated_drift_eventually_misses() {
        let mut c = StepCache::default();
        c.store(&[1.0; 8], &[0.5]);
        let mut m = vec![1.0f32; 8];
        let mut reuses = 0;
        for _ in 0..100 {
            for x in &mut m {
                *x += 0.001; // small per-step drift
            }
            if c.should_reuse(&m, 0.02) {
                c.reused(&m);
                reuses += 1;
            } else {
                break;
            }
        }
        assert!(reuses > 0, "some reuse expected");
        assert!(reuses < 100, "drift must eventually force a real run");
    }

    #[test]
    fn empty_cache_never_reuses() {
        let mut c = StepCache::default();
        assert!(!c.should_reuse(&[1.0], 1.0));
    }
}
