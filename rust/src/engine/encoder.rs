//! Standalone multimodal-encoder engine (paper §3.4: the unified
//! connector "remains compatible with existing EPD (encode–prefill–
//! decode) disaggregation").
//!
//! By default the encoder runs inside the Thinker stage (Fig. 4
//! footnote 4); with EPD disaggregation it becomes its own stage on its
//! own device, producing embedding items that an `embeds2prompt`
//! transfer turns into Thinker submissions.  Batched across requests.

use std::collections::{HashMap, VecDeque};

use anyhow::{Context, Result};

use crate::engine::StageItem;
use crate::runtime::{Artifacts, HostTensor, StageRuntime};

#[derive(Debug, Clone)]
pub struct EncodeJob {
    pub req_id: u64,
    /// Feature rows, row-major `[frames, feat_dim]` (padded here).
    pub feats: Vec<f32>,
    pub frames: usize,
}

#[derive(Debug, Default, Clone)]
pub struct EncoderStats {
    pub jobs_done: u64,
    pub calls: u64,
    pub exec_seconds: f64,
    /// Jobs answered from the encoder-output cache without touching the
    /// device (identical input content re-submitted, ISSUE 7).
    pub cache_hits: u64,
    /// Jobs that had to encode (cache enabled but content unseen).
    pub cache_misses: u64,
}

/// Content identity of an encode input: FNV-style hash over the feature
/// bit patterns and frame count.  Identical media (duplicate images /
/// audio clips) hash equal; any bit of difference diverges.
fn content_hash(feats: &[f32], frames: usize) -> u64 {
    let mut h = 0xCBF29CE484222325u64 ^ (frames as u64);
    for &f in feats {
        h ^= f.to_bits() as u64;
        h = h.wrapping_mul(0x100000001B3);
        h ^= h >> 29;
    }
    h
}

/// Default encoder-output cache bound (entries) when no
/// [`crate::config::CacheConfig`] overrides it.
pub const DEFAULT_CACHE_CAPACITY: usize = 256;

/// Batched single-forward encoder engine with a content-addressed
/// output cache in front of the device (Cornserve-style: duplicated
/// media across requests encodes once).
pub struct EncoderEngine {
    rt: StageRuntime,
    t_max: usize,
    feat_dim: usize,
    d_out: usize,
    max_batch: usize,
    queue: VecDeque<EncodeJob>,
    /// Cache hits resolved at submit, emitted by the next `step`.
    ready: Vec<StageItem>,
    /// content hash -> (LRU tick, embed rows).  Bounded by
    /// `cache_capacity` entries; 0 disables the cache.
    cache: HashMap<u64, (u64, Vec<f32>)>,
    cache_capacity: usize,
    tick: u64,
    pub stats: EncoderStats,
}

impl EncoderEngine {
    pub fn new(artifacts: &Artifacts, model: &str, max_batch: usize) -> Result<Self> {
        let rt = StageRuntime::new(artifacts, model)
            .with_context(|| format!("creating encoder engine for {model}"))?;
        let spec = rt.model().clone();
        let mut eng = Self {
            t_max: spec.cfg_usize("t_max")?,
            feat_dim: spec.cfg_usize("feat_dim")?,
            d_out: spec.cfg_usize("d_out")?,
            rt,
            max_batch,
            queue: VecDeque::new(),
            ready: Vec::new(),
            cache: HashMap::new(),
            cache_capacity: DEFAULT_CACHE_CAPACITY,
            tick: 0,
            stats: EncoderStats::default(),
        };
        let entries: Vec<String> = eng
            .rt
            .model()
            .buckets("encode")
            .into_iter()
            .filter(|&b| b <= max_batch.next_power_of_two())
            .map(|b| format!("encode.b{b}"))
            .collect();
        eng.rt.precompile(&entries)?;
        Ok(eng)
    }

    pub fn t_max(&self) -> usize {
        self.t_max
    }

    pub fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Bound (entries) of the encoder-output cache; 0 disables it.
    pub fn set_cache_capacity(&mut self, capacity: usize) {
        self.cache_capacity = capacity;
        if capacity == 0 {
            self.cache.clear();
        }
        while self.cache.len() > self.cache_capacity {
            self.evict_one();
        }
    }

    pub fn submit(&mut self, job: EncodeJob) {
        if self.cache_capacity > 0 {
            let h = content_hash(&job.feats, job.frames);
            if let Some((last, rows)) = self.cache.get_mut(&h) {
                self.tick += 1;
                *last = self.tick;
                let rows = rows.clone();
                let frames = rows.len() / self.d_out.max(1);
                self.stats.cache_hits += 1;
                self.stats.jobs_done += 1;
                self.ready.push(
                    StageItem::new(job.req_id)
                        .with("embeds", HostTensor::f32(vec![frames, self.d_out], rows))
                        .finished(),
                );
                return;
            }
            self.stats.cache_misses += 1;
        }
        self.queue.push_back(job);
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.ready.is_empty()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Abort a request: its queued encode jobs (and any cache-served
    /// items not yet emitted) are dropped.
    pub fn cancel(&mut self, req_id: u64) -> bool {
        let before = self.queue.len() + self.ready.len();
        self.queue.retain(|j| j.req_id != req_id);
        self.ready.retain(|i| i.req_id != req_id);
        before != self.queue.len() + self.ready.len()
    }

    fn evict_one(&mut self) {
        if let Some(&h) = self
            .cache
            .iter()
            .min_by_key(|(_, (last, _))| *last)
            .map(|(h, _)| h)
        {
            self.cache.remove(&h);
        }
    }

    fn cache_insert(&mut self, h: u64, rows: Vec<f32>) {
        if self.cache_capacity == 0 {
            return;
        }
        while self.cache.len() >= self.cache_capacity && !self.cache.contains_key(&h) {
            self.evict_one();
        }
        self.tick += 1;
        self.cache.insert(h, (self.tick, rows));
    }

    /// Encode one batch of queued jobs; emits one finished item per job
    /// carrying `embeds [frames, d_out]` (cache-served items first).
    pub fn step(&mut self) -> Result<Vec<StageItem>> {
        let served = std::mem::take(&mut self.ready);
        if self.queue.is_empty() {
            return Ok(served);
        }
        let take = self.queue.len().min(self.max_batch);
        let jobs: Vec<EncodeJob> = self.queue.drain(..take).collect();
        let buckets = self.rt.model().buckets("encode");
        let b = buckets
            .iter()
            .copied()
            .find(|&x| x >= jobs.len())
            .or(buckets.last().copied())
            .ok_or_else(|| anyhow::anyhow!("no encode buckets"))?;

        let (t, fd, d) = (self.t_max, self.feat_dim, self.d_out);
        let mut feats = vec![0f32; b * t * fd];
        let mut mask = vec![0f32; b * t];
        for (bi, job) in jobs.iter().enumerate() {
            let frames = job.frames.min(t);
            let n = (frames * fd).min(job.feats.len());
            feats[bi * t * fd..bi * t * fd + n].copy_from_slice(&job.feats[..n]);
            for m in mask[bi * t..bi * t + frames].iter_mut() {
                *m = 1.0;
            }
        }
        let t0 = std::time::Instant::now();
        let outs = self.rt.run(
            &format!("encode.b{b}"),
            &[
                HostTensor::f32(vec![b, t, fd], feats),
                HostTensor::f32(vec![b, t], mask),
            ],
        )?;
        self.stats.exec_seconds += t0.elapsed().as_secs_f64();
        self.stats.calls += 1;
        let embeds = outs[0].as_f32()?;

        let mut items = served;
        items.reserve(jobs.len());
        for (bi, job) in jobs.iter().enumerate() {
            let frames = job.frames.min(t);
            let rows = embeds[bi * t * d..bi * t * d + frames * d].to_vec();
            self.cache_insert(content_hash(&job.feats, job.frames), rows.clone());
            self.stats.jobs_done += 1;
            items.push(
                StageItem::new(job.req_id)
                    .with("embeds", HostTensor::f32(vec![frames, d], rows))
                    .finished(),
            );
        }
        Ok(items)
    }

    pub fn run_to_completion(&mut self) -> Result<Vec<StageItem>> {
        let mut all = Vec::new();
        while !self.idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }
}
