//! Standalone multimodal-encoder engine (paper §3.4: the unified
//! connector "remains compatible with existing EPD (encode–prefill–
//! decode) disaggregation").
//!
//! By default the encoder runs inside the Thinker stage (Fig. 4
//! footnote 4); with EPD disaggregation it becomes its own stage on its
//! own device, producing embedding items that an `embeds2prompt`
//! transfer turns into Thinker submissions.  Batched across requests.

use std::collections::VecDeque;

use anyhow::{Context, Result};

use crate::engine::StageItem;
use crate::runtime::{Artifacts, HostTensor, StageRuntime};

#[derive(Debug, Clone)]
pub struct EncodeJob {
    pub req_id: u64,
    /// Feature rows, row-major `[frames, feat_dim]` (padded here).
    pub feats: Vec<f32>,
    pub frames: usize,
}

#[derive(Debug, Default, Clone)]
pub struct EncoderStats {
    pub jobs_done: u64,
    pub calls: u64,
    pub exec_seconds: f64,
}

/// Batched single-forward encoder engine.
pub struct EncoderEngine {
    rt: StageRuntime,
    t_max: usize,
    feat_dim: usize,
    d_out: usize,
    max_batch: usize,
    queue: VecDeque<EncodeJob>,
    pub stats: EncoderStats,
}

impl EncoderEngine {
    pub fn new(artifacts: &Artifacts, model: &str, max_batch: usize) -> Result<Self> {
        let rt = StageRuntime::new(artifacts, model)
            .with_context(|| format!("creating encoder engine for {model}"))?;
        let spec = rt.model().clone();
        let mut eng = Self {
            t_max: spec.cfg_usize("t_max")?,
            feat_dim: spec.cfg_usize("feat_dim")?,
            d_out: spec.cfg_usize("d_out")?,
            rt,
            max_batch,
            queue: VecDeque::new(),
            stats: EncoderStats::default(),
        };
        let entries: Vec<String> = eng
            .rt
            .model()
            .buckets("encode")
            .into_iter()
            .filter(|&b| b <= max_batch.next_power_of_two())
            .map(|b| format!("encode.b{b}"))
            .collect();
        eng.rt.precompile(&entries)?;
        Ok(eng)
    }

    pub fn t_max(&self) -> usize {
        self.t_max
    }

    pub fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    pub fn d_out(&self) -> usize {
        self.d_out
    }

    pub fn submit(&mut self, job: EncodeJob) {
        self.queue.push_back(job);
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Abort a request: its queued encode jobs are dropped.
    pub fn cancel(&mut self, req_id: u64) -> bool {
        let before = self.queue.len();
        self.queue.retain(|j| j.req_id != req_id);
        before != self.queue.len()
    }

    /// Encode one batch of queued jobs; emits one finished item per job
    /// carrying `embeds [frames, d_out]`.
    pub fn step(&mut self) -> Result<Vec<StageItem>> {
        if self.queue.is_empty() {
            return Ok(vec![]);
        }
        let take = self.queue.len().min(self.max_batch);
        let jobs: Vec<EncodeJob> = self.queue.drain(..take).collect();
        let buckets = self.rt.model().buckets("encode");
        let b = buckets
            .iter()
            .copied()
            .find(|&x| x >= jobs.len())
            .or(buckets.last().copied())
            .ok_or_else(|| anyhow::anyhow!("no encode buckets"))?;

        let (t, fd, d) = (self.t_max, self.feat_dim, self.d_out);
        let mut feats = vec![0f32; b * t * fd];
        let mut mask = vec![0f32; b * t];
        for (bi, job) in jobs.iter().enumerate() {
            let frames = job.frames.min(t);
            let n = (frames * fd).min(job.feats.len());
            feats[bi * t * fd..bi * t * fd + n].copy_from_slice(&job.feats[..n]);
            for m in mask[bi * t..bi * t + frames].iter_mut() {
                *m = 1.0;
            }
        }
        let t0 = std::time::Instant::now();
        let outs = self.rt.run(
            &format!("encode.b{b}"),
            &[
                HostTensor::f32(vec![b, t, fd], feats),
                HostTensor::f32(vec![b, t], mask),
            ],
        )?;
        self.stats.exec_seconds += t0.elapsed().as_secs_f64();
        self.stats.calls += 1;
        let embeds = outs[0].as_f32()?;

        let mut items = Vec::with_capacity(jobs.len());
        for (bi, job) in jobs.iter().enumerate() {
            let frames = job.frames.min(t);
            let rows = embeds[bi * t * d..bi * t * d + frames * d].to_vec();
            self.stats.jobs_done += 1;
            items.push(
                StageItem::new(job.req_id)
                    .with("embeds", HostTensor::f32(vec![frames, d], rows))
                    .finished(),
            );
        }
        Ok(items)
    }

    pub fn run_to_completion(&mut self) -> Result<Vec<StageItem>> {
        let mut all = Vec::new();
        while !self.idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }
}
