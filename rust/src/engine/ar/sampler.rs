//! Token sampling for AR stages: greedy, temperature, and top-k.

use crate::util::Prng;

/// Sample one token from a logits row.
pub fn sample(logits: &[f32], temperature: f32, top_k: usize, rng: &mut Prng) -> u32 {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    // Collect candidate (index, logit) pairs, top-k if requested.
    let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
    if top_k > 0 && top_k < logits.len() {
        idx.select_nth_unstable_by(top_k - 1, |&a, &b| {
            logits[b as usize].partial_cmp(&logits[a as usize]).unwrap()
        });
        idx.truncate(top_k);
    }
    // Softmax over candidates at the given temperature.
    let max = idx.iter().map(|&i| logits[i as usize]).fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i as usize] - max) / temperature) as f64).exp())
        .collect();
    let sum: f64 = probs.iter().sum();
    for p in &mut probs {
        *p /= sum;
    }
    let mut u = rng.f64();
    for (i, &p) in probs.iter().enumerate() {
        if u < p {
            return idx[i];
        }
        u -= p;
    }
    *idx.last().unwrap()
}

pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;

    #[test]
    fn greedy_is_argmax() {
        let logits = [0.1, 2.0, -1.0, 1.9];
        let mut rng = Prng::new(0);
        assert_eq!(sample(&logits, 0.0, 0, &mut rng), 1);
    }

    #[test]
    fn low_temperature_concentrates() {
        let logits = [0.0, 5.0, 0.0, 0.0];
        let mut rng = Prng::new(1);
        let mut hits = 0;
        for _ in 0..200 {
            if sample(&logits, 0.1, 0, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 195, "hits {hits}");
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [10.0, 9.0, -50.0, -60.0];
        let mut rng = Prng::new(2);
        for _ in 0..100 {
            let t = sample(&logits, 1.0, 2, &mut rng);
            assert!(t == 0 || t == 1, "sampled {t}");
        }
    }

    #[test]
    fn high_temperature_spreads() {
        let logits = [1.0, 1.0, 1.0, 1.0];
        let mut rng = Prng::new(3);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[sample(&logits, 1.0, 0, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn prop_sample_in_vocab() {
        quick("sampler_in_vocab", |rng| {
            let n = rng.range(1, 64);
            let logits: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 10.0).collect();
            let temp = if rng.bool(0.5) { 0.0 } else { rng.f32() * 2.0 };
            let top_k = rng.range(0, n + 2);
            let t = sample(&logits, temp, top_k, rng);
            assert!((t as usize) < n);
        });
    }
}
