//! vLLM-like autoregressive engine (paper §3.3 "AR stage support").
//!
//! Serves one AR model stage with:
//! * **continuous batching** — sequences join/leave the running batch at
//!   every iteration (Orca-style), with bucketed executables;
//! * **chunked prefill** — prompts enter the cache in fixed-size chunks
//!   interleaved with decode iterations (Sarathi-style);
//! * **paged-KV admission & preemption** — [`crate::kv_cache`] gates
//!   admission; on pool exhaustion the youngest sequence is preempted and
//!   recomputed (vLLM recompute-preemption);
//! * **per-iteration preprocess** — a hook recomputes each sequence's
//!   conditioning vector before every decode step (the paper's
//!   `process_input`, e.g. Talker consuming Thinker hidden states);
//! * **multi-step fused decode** — `multi_step > 1` replays the AOT
//!   `scan` executable, amortizing per-step dispatch + KV marshaling
//!   ("execution-graph compilation" mode);
//! * **streaming stage output** — partial outputs emitted every
//!   `stream_chunk` tokens so downstream stages overlap (paper §3.3).

pub mod core;
pub mod sampler;
pub mod sequence;

pub use self::core::{embed_job, token_job, ArEngine, ArEngineOptions, ArJob, EngineStats, Preprocess};
pub use sequence::{PromptItem, SeqPhase, Sequence};

/// Decode steps fused by the AOT scan executable (lockstep with
/// `python/compile/configs.py::SCAN_STEPS`).
pub const SCAN_STEPS: usize = 8;

/// Prefill chunk size (lockstep with `configs.py::PREFILL_CHUNK`).
pub const PREFILL_CHUNK: usize = 32;
