//! Per-sequence state for the AR engine.

use crate::engine::SamplingParams;
use crate::kv_cache::BlockTable;
use crate::util::Prng;

/// One element of the prompt stream: a vocabulary token or a row of the
/// embedding stream (multimodal encoder output / upstream hidden state).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PromptItem {
    Token(u32),
    /// Index into the request's `mm_embeds` rows.
    Embed(usize),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqPhase {
    /// Waiting for admission.
    Waiting,
    /// Prefilling; `usize` = prompt items already in cache.
    Prefill(usize),
    /// Decoding.
    Decode,
    /// Finished (EOS / caps); terminal.
    Done,
}

/// Why a sequence stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    CacheCap,
}

#[derive(Debug)]
pub struct Sequence {
    pub id: u64,
    pub prompt: Vec<PromptItem>,
    /// Embedding-stream rows, row-major `[n_rows, emb_dim]`.
    pub mm_embeds: Vec<f32>,
    pub emb_dim: usize,
    pub sampling: SamplingParams,
    pub phase: SeqPhase,
    /// Generated token ids.
    pub generated: Vec<u32>,
    /// Hidden state per generated token, row-major `[n, d_model]`
    /// (streamed to downstream stages, e.g. Thinker -> Talker).
    pub hiddens: Vec<f32>,
    /// Tokens already streamed out.
    pub streamed: usize,
    /// KV accounting table (admission handled by the engine).
    pub block_table: BlockTable,
    /// Conditioning summary (cond_dim floats) recomputed by the
    /// preprocess hook before every decode iteration.
    pub cond: Vec<f32>,
    /// Upstream hidden rows received so far (for cond computation),
    /// row-major `[n, upstream_dim]`, plus running sum for O(1) mean.
    pub upstream: UpstreamBuffer,
    pub finish_reason: Option<FinishReason>,
    pub prng: Prng,
    /// Engine-iteration timestamp of admission (for fairness metrics).
    pub admitted_iter: u64,
    /// Decode-role engines: the KV handoff this sequence was built from.
    /// Kept after import so recompute-preemption can re-import instead of
    /// re-prefilling a prompt this engine never had.
    pub handoff: Option<Box<crate::kv_transfer::KvHandoff>>,
    /// Whether [`Self::handoff`] still needs importing (set at submit and
    /// again on preemption).
    pub needs_import: bool,
    /// Prompt tokens resident in the imported cache (handoff sequences
    /// carry no prompt items of their own).
    imported_len: usize,
}

impl Sequence {
    pub fn new(id: u64, prompt: Vec<PromptItem>, mm_embeds: Vec<f32>, emb_dim: usize, sampling: SamplingParams) -> Self {
        let seed = sampling.seed ^ id.wrapping_mul(0x9E3779B97F4A7C15);
        Self {
            id,
            prompt,
            mm_embeds,
            emb_dim,
            sampling,
            phase: SeqPhase::Waiting,
            generated: Vec::new(),
            hiddens: Vec::new(),
            streamed: 0,
            block_table: BlockTable::default(),
            cond: Vec::new(),
            upstream: UpstreamBuffer::default(),
            finish_reason: None,
            prng: Prng::new(seed),
            admitted_iter: 0,
            handoff: None,
            needs_import: false,
            imported_len: 0,
        }
    }

    /// A sequence picking up where a prefill engine left off: the first
    /// token is already sampled, the sampler PRNG resumes mid-stream, and
    /// the KV state imports at admission (see `ArEngine::submit_handoff`).
    pub fn from_handoff(h: Box<crate::kv_transfer::KvHandoff>) -> Self {
        let mut s = Self::new(h.req_id, vec![], vec![], 0, h.sampling.clone());
        s.generated = vec![h.first_token];
        s.hiddens = h.hidden.clone();
        s.prng = Prng::from_state(h.prng_state);
        s.imported_len = h.len;
        s.needs_import = true;
        s.handoff = Some(h);
        s
    }

    /// Reset for re-admission after a recompute preemption: prompt-built
    /// sequences re-prefill from scratch; handoff-built sequences rewind
    /// to the handoff state and re-import.
    pub fn reset_for_requeue(&mut self) {
        self.block_table = BlockTable::default();
        self.phase = SeqPhase::Waiting;
        self.streamed = 0;
        match &self.handoff {
            Some(h) => {
                self.generated = vec![h.first_token];
                self.hiddens = h.hidden.clone();
                self.prng = Prng::from_state(h.prng_state);
                self.needs_import = true;
            }
            None => {
                self.generated.clear();
                self.hiddens.clear();
            }
        }
    }

    pub fn prompt_len(&self) -> usize {
        if self.prompt.is_empty() && self.imported_len > 0 {
            self.imported_len
        } else {
            self.prompt.len()
        }
    }

    /// Total tokens in cache once fully prefetched + generated.
    pub fn cache_len(&self) -> usize {
        match self.phase {
            SeqPhase::Waiting => 0,
            SeqPhase::Prefill(done) => done,
            SeqPhase::Decode | SeqPhase::Done => self.prompt_len() + self.generated.len(),
        }
    }

    /// The token fed to the next decode step (last generated, or a BOS-
    /// like start token after prefill).
    pub fn next_input_token(&self) -> u32 {
        *self.generated.last().unwrap_or(&crate::tokenizer::BOS_ID)
    }

    pub fn is_done(&self) -> bool {
        self.phase == SeqPhase::Done
    }
}

/// Accumulates upstream hidden rows and exposes an O(1) running mean —
/// the "concatenate Thinker hidden states at every decoding step"
/// summary (see DESIGN.md: running mean instead of full concat).
#[derive(Debug, Default)]
pub struct UpstreamBuffer {
    pub rows: usize,
    pub dim: usize,
    sum: Vec<f32>,
    pub last: Vec<f32>,
    /// Upstream stage finished producing.
    pub complete: bool,
}

impl UpstreamBuffer {
    pub fn push_rows(&mut self, data: &[f32], dim: usize) {
        assert!(dim > 0 && data.len() % dim == 0, "bad upstream rows");
        if self.dim == 0 {
            self.dim = dim;
            self.sum = vec![0.0; dim];
            self.last = vec![0.0; dim];
        }
        assert_eq!(self.dim, dim, "upstream dim changed");
        for row in data.chunks_exact(dim) {
            for (s, &x) in self.sum.iter_mut().zip(row) {
                *s += x;
            }
            self.rows += 1;
        }
        if let Some(last) = data.chunks_exact(dim).last() {
            self.last.copy_from_slice(last);
        }
    }

    /// Running mean (zeros if nothing received yet).
    pub fn mean(&self, dim: usize) -> Vec<f32> {
        if self.rows == 0 {
            return vec![0.0; dim];
        }
        assert_eq!(dim, self.dim);
        self.sum.iter().map(|&s| s / self.rows as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upstream_mean() {
        let mut u = UpstreamBuffer::default();
        u.push_rows(&[1.0, 2.0, 3.0, 4.0], 2); // rows [1,2], [3,4]
        assert_eq!(u.rows, 2);
        assert_eq!(u.mean(2), vec![2.0, 3.0]);
        assert_eq!(u.last, vec![3.0, 4.0]);
        u.push_rows(&[5.0, 6.0], 2);
        assert_eq!(u.mean(2), vec![3.0, 4.0]);
    }

    #[test]
    fn empty_mean_is_zero() {
        let u = UpstreamBuffer::default();
        assert_eq!(u.mean(3), vec![0.0; 3]);
    }

    #[test]
    fn cache_len_by_phase() {
        let mut s = Sequence::new(
            1,
            vec![PromptItem::Token(1), PromptItem::Token(5)],
            vec![],
            0,
            SamplingParams::default(),
        );
        assert_eq!(s.cache_len(), 0);
        s.phase = SeqPhase::Prefill(1);
        assert_eq!(s.cache_len(), 1);
        s.phase = SeqPhase::Decode;
        s.generated.push(9);
        assert_eq!(s.cache_len(), 3);
        assert_eq!(s.next_input_token(), 9);
    }
}
