//! The AR engine core: scheduler + model runner, advanced by `step()`.

use std::collections::{HashMap, VecDeque};

use anyhow::{bail, Context, Result};

use super::sampler;
use super::sequence::{FinishReason, PromptItem, SeqPhase, Sequence};
use super::{PREFILL_CHUNK, SCAN_STEPS};
use crate::config::StageRole;
use crate::engine::{SamplingParams, StageItem};
use crate::kv_cache::{BlockManager, BlockTable, EvictionPolicy};
use crate::kv_transfer::KvHandoff;
use crate::runtime::{Artifacts, HostTensor, StageRuntime};
use crate::tokenizer::BOS_ID;

/// How each sequence's conditioning vector is recomputed before every
/// decode iteration (the paper's `process_input`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preprocess {
    /// No conditioning stream (Thinker, MiMo backbone).
    None,
    /// Running mean of upstream hidden rows (Talker default — the
    /// "concatenate Thinker hidden states each step" summary).
    UpstreamMean,
    /// Most recent upstream hidden row.
    UpstreamLast,
}

/// Engine construction options (derived from [`crate::config::StageConfig`]).
#[derive(Debug, Clone)]
pub struct ArEngineOptions {
    pub max_batch: usize,
    pub chunked_prefill: bool,
    /// 1 = per-step decode; SCAN_STEPS = fused scan decode.
    pub multi_step: usize,
    /// Emit partial outputs every N tokens (0 = only on completion).
    pub stream_chunk: usize,
    pub preprocess: Preprocess,
    /// KV pool size in blocks (admission accounting).
    pub kv_blocks: usize,
    pub kv_block_size: usize,
    /// Baseline mode: evict compiled executables after every call, paying
    /// compilation on the next one (eager-framework analog; §4 baselines).
    pub lazy_compile: bool,
    /// Emit hidden-state rows alongside tokens (needed when a downstream
    /// stage consumes them; costs an extra d_model floats per token).
    pub emit_hiddens: bool,
    /// Serving phase (paper §3.4 P/D disaggregation): `Prefill` engines
    /// export a [`KvHandoff`] instead of decoding; `Decode` engines
    /// import handoffs via [`ArEngine::submit_handoff`].  `Fused` is the
    /// classic behaviour.
    pub role: StageRole,
    /// Cross-request prefix cache (ISSUE 7): released hashed blocks stay
    /// resident, and a new prompt's leading matched blocks skip prefill
    /// via the engine's host-side KV stash.
    pub prefix_cache: bool,
    /// Which refcount-0 cached block to reclaim under memory pressure.
    pub eviction: EvictionPolicy,
}

impl Default for ArEngineOptions {
    fn default() -> Self {
        Self {
            max_batch: 4,
            chunked_prefill: true,
            multi_step: 1,
            stream_chunk: 16,
            preprocess: Preprocess::None,
            kv_blocks: 512,
            kv_block_size: 16,
            lazy_compile: false,
            emit_hiddens: true,
            role: StageRole::Fused,
            prefix_cache: true,
            eviction: EvictionPolicy::Lru,
        }
    }
}

/// A request submitted to the engine.
#[derive(Debug, Clone)]
pub struct ArJob {
    pub req_id: u64,
    pub prompt: Vec<PromptItem>,
    /// Embedding-stream rows `[n, emb_dim]` referenced by
    /// `PromptItem::Embed` indices.
    pub mm_embeds: Vec<f32>,
    pub emb_dim: usize,
    pub sampling: SamplingParams,
}

/// Aggregate engine counters (drained by benches / orchestrator).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub iterations: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub prefill_calls: u64,
    pub decode_calls: u64,
    pub scan_calls: u64,
    pub preemptions: u64,
    pub exec_seconds: f64,
    /// Seconds spent assembling/scattering batch KV (marshaling).
    pub marshal_seconds: f64,
    /// KV handoffs exported (prefill role) / imported (decode role).
    pub kv_exports: u64,
    pub kv_imports: u64,
    /// Bytes of encoded handoff frames produced by this engine.
    pub kv_export_bytes: u64,
    /// Prefix blocks an import reused instead of allocating (hash dedup).
    pub kv_reused_blocks: u64,
    /// Requests aborted mid-flight by [`ArEngine::cancel`].
    pub cancelled: u64,
    /// Prompt tokens whose prefill was skipped because their KV was
    /// restored from the cross-request prefix cache.
    pub prefix_tokens_skipped: u64,
    /// Requests admitted with at least one prefix-cache block restored.
    pub prefix_restored_seqs: u64,
}

/// The engine.  Owns a thread-local PJRT runtime; not `Send` — run it on
/// its own thread (see [`crate::orchestrator`]).
pub struct ArEngine {
    rt: StageRuntime,
    opts: ArEngineOptions,
    // Model dims (from the manifest).
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    d_head: usize,
    max_seq: usize,
    cond_dim: usize,
    eos_id: u32,
    // Scheduler state.
    waiting: VecDeque<Sequence>,
    slots: Vec<Option<Sequence>>,
    /// Per-slot KV storage `[L, 2, H, S, dh]` row-major.
    slot_kv: Vec<Vec<f32>>,
    /// Batch-layout KV cache: the last executable's output KV kept in
    /// `[L, 2, b, H, S, dh]` layout together with its slot mapping.
    /// While batch membership is stable (the common case: a decode run
    /// of hundreds of steps), assemble/scatter round trips are skipped
    /// entirely — see EXPERIMENTS.md §Perf.
    batch_kv: Option<(Vec<usize>, usize, Vec<f32>)>,
    blocks: BlockManager,
    /// Host-side content stash backing the cross-request prefix cache:
    /// full-block prefix hash -> that block's KV rows (per (layer, k/v,
    /// head), `block_size * d_head` floats each).  The block manager is
    /// accounting-only — dense KV lives per slot — so a prefix-cache hit
    /// needs these rows copied back into the new sequence's slot before
    /// its (shortened) prefill runs.  Keyed by content hash, entries are
    /// never wrong (the chain hash identifies the token prefix and KV is
    /// a deterministic function of it); they are dropped when the
    /// manager retires the hash, which bounds the stash by pool size.
    prefix_kv: HashMap<u64, Vec<f32>>,
    iter: u64,
    pub stats: EngineStats,
}

impl ArEngine {
    pub fn new(artifacts: &Artifacts, model: &str, opts: ArEngineOptions) -> Result<Self> {
        let rt = StageRuntime::new(artifacts, model)
            .with_context(|| format!("creating AR engine for {model}"))?;
        let spec = rt.model().clone();
        let d_model = spec.cfg_usize("d_model")?;
        let n_layers = spec.cfg_usize("n_layers")?;
        let n_heads = spec.cfg_usize("n_heads")?;
        let d_head = spec.cfg_usize("d_head")?;
        let max_seq = spec.cfg_usize("max_seq")?;
        let cond_dim = spec.cfg_usize("cond_dim").unwrap_or(0);
        let eos_id = spec.cfg_usize("eos_id").unwrap_or(2) as u32;
        let slot_len = n_layers * 2 * n_heads * max_seq * d_head;
        let max_batch = opts.max_batch;
        let blocks = BlockManager::with_cache(
            opts.kv_blocks,
            opts.kv_block_size,
            opts.prefix_cache,
            opts.eviction,
        );
        let mut eng = Self {
            rt,
            opts,
            d_model,
            n_layers,
            n_heads,
            d_head,
            max_seq,
            cond_dim,
            eos_id,
            waiting: VecDeque::new(),
            slots: (0..max_batch).map(|_| None).collect(),
            slot_kv: (0..max_batch).map(|_| vec![0.0f32; slot_len]).collect(),
            batch_kv: None,
            blocks,
            prefix_kv: HashMap::new(),
            iter: 0,
            stats: EngineStats::default(),
        };
        if !eng.opts.lazy_compile {
            eng.precompile()?;
        }
        Ok(eng)
    }

    /// Compile the entries the configured policy will use.  Split-role
    /// engines compile only their phase's family — a prefill pool never
    /// dispatches decode/scan executables and vice versa.
    fn precompile(&mut self) -> Result<()> {
        let mut entries = vec![];
        if self.opts.role != StageRole::Prefill {
            for b in self.rt.model().buckets("decode") {
                if b <= self.opts.max_batch.next_power_of_two() {
                    entries.push(format!("decode.b{b}"));
                }
            }
        }
        if self.opts.role != StageRole::Decode {
            for b in self.rt.model().buckets("prefill") {
                if b <= self.opts.max_batch.next_power_of_two() {
                    entries.push(format!("prefill.b{b}.c{PREFILL_CHUNK}"));
                }
            }
        }
        if self.opts.multi_step > 1 && self.opts.role != StageRole::Prefill {
            for b in self.rt.model().buckets("scan") {
                if b <= self.opts.max_batch.next_power_of_two() {
                    entries.push(format!("scan.b{b}.k{SCAN_STEPS}"));
                }
            }
        }
        self.rt.precompile(&entries)
    }

    pub fn model_name(&self) -> &str {
        &self.rt.model().name
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    pub fn cond_dim(&self) -> usize {
        self.cond_dim
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Submit a new request.
    pub fn submit(&mut self, job: ArJob) {
        let seq = Sequence::new(job.req_id, job.prompt, job.mm_embeds, job.emb_dim, job.sampling);
        self.waiting.push_back(seq);
    }

    /// Submit a batch of requests at one token boundary: everything
    /// submitted together joins the running batch at the same iteration.
    pub fn submit_many<I: IntoIterator<Item = ArJob>>(&mut self, jobs: I) {
        for job in jobs {
            self.submit(job);
        }
    }

    /// Submit a prefill engine's exported KV state (decode role; also
    /// accepted by fused engines, e.g. for tests).  Validates the
    /// handoff's geometry against this engine's model up front so a
    /// mis-wired pipeline fails with a clear error instead of corrupting
    /// a slot; the actual block import happens at admission.
    pub fn submit_handoff(&mut self, h: KvHandoff) -> Result<()> {
        // A prefill-role engine compiles no decode/scan executables
        // (precompile skips them), so importing a sequence it could
        // never step is rejected up front.
        if self.opts.role == StageRole::Prefill {
            bail!(
                "kv handoff req {}: prefill-role engine `{}` cannot serve decode",
                h.req_id,
                self.model_name()
            );
        }
        h.check()?;
        if h.n_layers != self.n_layers || h.n_heads != self.n_heads || h.d_head != self.d_head {
            bail!(
                "kv handoff req {}: geometry [{}x{}x{}] does not match engine `{}` [{}x{}x{}]",
                h.req_id,
                h.n_layers,
                h.n_heads,
                h.d_head,
                self.model_name(),
                self.n_layers,
                self.n_heads,
                self.d_head
            );
        }
        // Only a payload that cannot physically fit the slot store is an
        // error.  A boundary-length sequence (len + 1 == max_seq) is
        // admitted and finishes immediately with `CacheCap` at import —
        // exactly how the fused engine completes the same request.
        if h.len >= self.max_seq {
            bail!(
                "kv handoff req {}: {} resident tokens exceed engine max_seq {}",
                h.req_id,
                h.len,
                self.max_seq
            );
        }
        if self.opts.emit_hiddens && !h.hidden.is_empty() && h.hidden.len() != self.d_model {
            bail!(
                "kv handoff req {}: hidden row has {} floats, engine d_model is {}",
                h.req_id,
                h.hidden.len(),
                self.d_model
            );
        }
        self.waiting.push_back(Sequence::from_handoff(Box::new(h)));
        Ok(())
    }

    /// Feed upstream hidden rows for a request's conditioning stream
    /// (whether waiting or running).
    pub fn push_upstream(&mut self, req_id: u64, rows: &[f32], dim: usize, complete: bool) {
        for seq in self
            .waiting
            .iter_mut()
            .chain(self.slots.iter_mut().flatten())
        {
            if seq.id == req_id {
                if !rows.is_empty() {
                    seq.upstream.push_rows(rows, dim);
                }
                seq.upstream.complete |= complete;
                return;
            }
        }
    }

    /// Abort a request wherever it lives: waiting (including imported
    /// handoffs not yet admitted), prefilling, or decoding.  Its KV
    /// blocks are released exactly as on completion, so
    /// [`BlockManager`] invariants hold and the freed blocks are
    /// immediately reusable.  No further items are emitted for the
    /// request.  Returns whether anything was dropped.
    pub fn cancel(&mut self, req_id: u64) -> bool {
        let mut found = false;
        let mut i = 0;
        while i < self.waiting.len() {
            if self.waiting[i].id == req_id {
                let seq = self.waiting.remove(i).expect("index in range");
                // Waiting sequences hold no blocks (requeues release
                // before rewinding); releasing the empty table is a
                // no-op that keeps this robust if that ever changes.
                self.blocks.release(&seq.block_table);
                found = true;
            } else {
                i += 1;
            }
        }
        for sid in 0..self.slots.len() {
            if self.slots[sid].as_ref().map(|s| s.id == req_id).unwrap_or(false) {
                let seq = self.slots[sid].take().expect("checked above");
                // Work already done survives the cancel: blocks up to the
                // prefill watermark stash their content, so a retry (or
                // any prompt sharing the prefix) skips that prefill.
                let computed = match seq.phase {
                    SeqPhase::Prefill(done) => done,
                    _ => seq.prompt_len(),
                };
                self.stash_prefix_kv(sid, &seq.block_table, computed);
                self.blocks.release(&seq.block_table);
                // The batch KV cache may still name this slot; that is
                // fine — membership changes flush it before the slot is
                // reused (same as normal completion).
                found = true;
            }
        }
        if found {
            self.stats.cancelled += 1;
        }
        found
    }

    /// The engine's paged KV accounting (cancellation/invariant tests).
    pub fn block_manager(&self) -> &BlockManager {
        &self.blocks
    }

    /// Anything left to do?
    pub fn idle(&self) -> bool {
        self.waiting.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    pub fn queued(&self) -> usize {
        self.waiting.len()
    }

    pub fn running(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Sum of token commitments (prompt + generation budget) of every
    /// sequence in flight — waiting or running.  The continuous-batching
    /// policy's admission signal for the max-batch-tokens budget.
    pub fn committed_tokens(&self) -> usize {
        self.waiting
            .iter()
            .chain(self.slots.iter().flatten())
            .map(|s| s.prompt_len() + s.sampling.max_new_tokens)
            .sum()
    }

    // ------------------------------------------------------------------
    // Scheduler iteration
    // ------------------------------------------------------------------

    /// Advance one engine iteration; returns emitted stage items.
    pub fn step(&mut self) -> Result<Vec<StageItem>> {
        self.iter += 1;
        self.stats.iterations += 1;
        // Hashes the manager retired since the last iteration (evicted or
        // force-freed blocks) leave the content stash too, keeping it
        // bounded by the pool's resident set.
        for h in self.blocks.take_retired_hashes() {
            self.prefix_kv.remove(&h);
        }
        let mut out = Vec::new();

        self.admit(&mut out);

        // 1) prefill phase (one chunk per prefilling sequence).
        let prefilling: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s, Some(q) if matches!(q.phase, SeqPhase::Prefill(_))))
            .map(|(i, _)| i)
            .take(self.rt.model().buckets("prefill").last().copied().unwrap_or(1))
            .collect();
        if !prefilling.is_empty() {
            self.run_prefill(&prefilling, &mut out)?;
            if !self.opts.chunked_prefill {
                // Non-chunked mode: keep prefilling until all prompts are
                // fully in cache before any decode runs (HF-style stall).
                while self
                    .slots
                    .iter()
                    .any(|s| matches!(s, Some(q) if matches!(q.phase, SeqPhase::Prefill(_))))
                {
                    let again: Vec<usize> = self
                        .slots
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| {
                            matches!(s, Some(q) if matches!(q.phase, SeqPhase::Prefill(_)))
                        })
                        .map(|(i, _)| i)
                        .collect();
                    self.run_prefill(&again, &mut out)?;
                }
            }
        }

        // 2) decode phase.
        let decoding: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s, Some(q) if q.phase == SeqPhase::Decode))
            .map(|(i, _)| i)
            .collect();
        if !decoding.is_empty() {
            let use_scan = self.opts.multi_step > 1
                && decoding.iter().all(|&i| {
                    let s = self.slots[i].as_ref().unwrap();
                    s.sampling.temperature <= 0.0
                        && s.sampling.max_new_tokens.saturating_sub(s.generated.len())
                            >= SCAN_STEPS
                        && s.prompt_len() + s.generated.len() + SCAN_STEPS < self.max_seq
                });
            if use_scan {
                self.run_scan(&decoding, &mut out)?;
            } else {
                self.run_decode(&decoding, &mut out)?;
            }
        }

        Ok(out)
    }

    /// Run until every submitted request has completed; returns all items.
    pub fn run_to_completion(&mut self) -> Result<Vec<StageItem>> {
        let mut all = Vec::new();
        while !self.idle() {
            let items = self.step()?;
            all.extend(items);
        }
        Ok(all)
    }

    fn admit(&mut self, out: &mut Vec<StageItem>) {
        while let Some(front) = self.waiting.front() {
            let Some(slot) = self.slots.iter().position(|s| s.is_none()) else { break };
            // Decode admission for imported sequences is gated on the KV
            // import fitting the memory budget, exactly like a prompt.
            let worst_case = front.prompt_len() + front.sampling.max_new_tokens + 1;
            if !self.blocks.can_allocate(worst_case.min(self.max_seq)) {
                break;
            }
            let mut seq = self.waiting.pop_front().unwrap();
            if seq.needs_import {
                match self.import_handoff(slot, seq) {
                    Ok(sid) => {
                        // EOS/caps already satisfied at the first token
                        // finish here (the request never decodes).
                        self.post_token_checks(sid, out);
                    }
                    Err(seq) => {
                        self.waiting.push_front(seq);
                        break;
                    }
                }
                continue;
            }
            let hash_tokens = prompt_hash_tokens(&seq);
            match self.blocks.allocate_prompt_matched(&hash_tokens) {
                Ok((table, matched)) => {
                    seq.block_table = table;
                    seq.admitted_iter = self.iter;
                    // The slot's KV may live in the batch cache; flush
                    // before clearing so neighbours are preserved.
                    self.flush_batch_kv();
                    self.slot_kv[slot].iter_mut().for_each(|x| *x = 0.0);
                    // Prefix-cache hit: restore the leading matched
                    // blocks' KV rows from the stash and start prefill at
                    // the first miss instead of position 0.
                    let skip = if self.opts.prefix_cache && matched > 0 {
                        self.restore_prefix(slot, &seq.block_table, matched, seq.prompt_len())
                    } else {
                        0
                    };
                    if skip > 0 {
                        self.stats.prefix_tokens_skipped += skip as u64;
                        self.stats.prefix_restored_seqs += 1;
                    }
                    seq.phase = SeqPhase::Prefill(skip);
                    self.slots[slot] = Some(seq);
                }
                Err(_) => {
                    self.waiting.push_front(seq);
                    break;
                }
            }
        }
    }

    /// Import an exported sequence into `slot`: block accounting through
    /// [`BlockManager::import_seq`] (resident prefix blocks dedup by
    /// hash), then the KV payload scattered into the slot store.  Gives
    /// the sequence back on pool exhaustion so the caller can requeue.
    fn import_handoff(&mut self, slot: usize, mut seq: Sequence) -> std::result::Result<usize, Sequence> {
        let h = seq.handoff.take().expect("needs_import implies a handoff");
        let (mut table, reused) = match self.blocks.import_seq(&h.blocks) {
            Ok(r) => r,
            Err(_) => {
                seq.handoff = Some(h);
                return Err(seq);
            }
        };
        // Account the already-sampled first token's cache row (the fused
        // engine does this at the end of prefill).
        if self.blocks.append_token(&mut table).is_err() {
            self.blocks.release(&table);
            seq.handoff = Some(h);
            return Err(seq);
        }
        self.stats.kv_imports += 1;
        self.stats.kv_reused_blocks += reused as u64;
        // Scatter the resident KV rows into the slot store: handoff
        // layout [L, 2, H, len, dh] -> slot layout [L, 2, H, S, dh].
        self.flush_batch_kv();
        self.slot_kv[slot].iter_mut().for_each(|x| *x = 0.0);
        let (chunk, s_max, dh) = (self.kv_chunk(), self.max_seq, self.d_head);
        let lk = self.n_layers * 2;
        let len = h.len;
        for li in 0..lk {
            for hd in 0..self.n_heads {
                let src_off = (li * self.n_heads + hd) * len * dh;
                let dst_off = li * chunk + hd * s_max * dh;
                self.slot_kv[slot][dst_off..dst_off + len * dh]
                    .copy_from_slice(&h.kv[src_off..src_off + len * dh]);
            }
        }
        seq.handoff = Some(h);
        seq.needs_import = false;
        seq.block_table = table;
        seq.phase = SeqPhase::Decode;
        seq.admitted_iter = self.iter;
        if self.opts.emit_hiddens && seq.hiddens.len() != self.d_model {
            // Exporter did not carry a hidden row; keep the stream shaped.
            seq.hiddens = vec![0.0; self.d_model];
        }
        if !self.opts.emit_hiddens {
            seq.hiddens.clear();
        }
        self.slots[slot] = Some(seq);
        Ok(slot)
    }

    /// Prefill role: package the finished sequence's KV state as a
    /// [`KvHandoff`] item and free its slot + blocks.  The first decode
    /// token (and its hidden row) rides along for observability and so
    /// the decode stage continues from it.
    fn export_handoff(&mut self, sid: usize) -> Result<StageItem> {
        // The just-finished prefill call's KV lives in the batch cache.
        self.flush_batch_kv();
        let seq = self.slots[sid].take().expect("exporting a live slot");
        let len = seq.prompt_len();
        let (chunk, s_max, dh) = (self.kv_chunk(), self.max_seq, self.d_head);
        let lk = self.n_layers * 2;
        let mut kv = Vec::with_capacity(lk * self.n_heads * len * dh);
        for li in 0..lk {
            for hd in 0..self.n_heads {
                let off = li * chunk + hd * s_max * dh;
                kv.extend_from_slice(&self.slot_kv[sid][off..off + len * dh]);
            }
        }
        let blocks = self.blocks.export_seq(&seq.block_table);
        // Prompt signature for cache-aware routing: the first full
        // block's chain hash (None for sub-block prompts).  Rides the
        // item as a tiny side tensor so the stage loop can hint the
        // prefill→decode router before forwarding.
        let sig = blocks.full_hashes.first().copied().flatten();
        self.stash_prefix_kv(sid, &seq.block_table, len);
        self.blocks.release(&seq.block_table);
        let first = *seq.generated.first().expect("prefill sampled the first token");
        let hidden = if self.opts.emit_hiddens { seq.hiddens.clone() } else { vec![] };
        let h = KvHandoff {
            req_id: seq.id,
            len,
            first_token: first,
            hidden: hidden.clone(),
            sampling: seq.sampling.clone(),
            prng_state: seq.prng.state(),
            n_layers: self.n_layers,
            n_heads: self.n_heads,
            d_head: self.d_head,
            blocks,
            kv,
        };
        let tensor = h.to_tensor();
        self.stats.kv_exports += 1;
        self.stats.kv_export_bytes += tensor.byte_len() as u64;
        let mut item = StageItem::new(h.req_id)
            .with("tokens", HostTensor::i32(vec![1], vec![first as i32]));
        if self.opts.emit_hiddens {
            item = item.with("hiddens", HostTensor::f32(vec![1, self.d_model], hidden));
        }
        if let Some(sig) = sig {
            item = item.with(
                crate::kv_transfer::KV_SIG_TENSOR,
                crate::kv_transfer::sig_to_tensor(sig),
            );
        }
        Ok(item.with(crate::kv_transfer::KV_TENSOR, tensor).finished())
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    fn run_prefill(&mut self, slot_ids: &[usize], out: &mut Vec<StageItem>) -> Result<()> {
        let b = self.bucket_for("prefill", slot_ids.len())?;
        let ids = &slot_ids[..slot_ids.len().min(b)];
        let c = PREFILL_CHUNK;
        let emb_dim = if self.cond_dim > 0 { self.cond_dim } else { self.d_model };

        let mut tokens = vec![0i32; b * c];
        let mut mm = vec![0f32; b * c * emb_dim];
        let mut mask = vec![0f32; b * c];
        let mut base = vec![0i32; b];
        for (bi, &sid) in ids.iter().enumerate() {
            let seq = self.slots[sid].as_ref().unwrap();
            let SeqPhase::Prefill(done) = seq.phase else { unreachable!() };
            base[bi] = done as i32;
            for ci in 0..c {
                let idx = done + ci;
                if idx >= seq.prompt_len() {
                    break;
                }
                match seq.prompt[idx] {
                    PromptItem::Token(t) => tokens[bi * c + ci] = t as i32,
                    PromptItem::Embed(row) => {
                        mask[bi * c + ci] = 1.0;
                        let src = &seq.mm_embeds[row * seq.emb_dim..(row + 1) * seq.emb_dim];
                        debug_assert_eq!(seq.emb_dim, emb_dim);
                        mm[(bi * c + ci) * emb_dim..(bi * c + ci + 1) * emb_dim]
                            .copy_from_slice(src);
                    }
                }
            }
        }

        let kv = self.assemble_kv(ids, b);
        let entry = format!("prefill.b{b}.c{c}");
        let inputs = vec![
            HostTensor::i32(vec![b, c], tokens),
            HostTensor::f32(vec![b, c, emb_dim], mm),
            HostTensor::f32(vec![b, c], mask),
            kv,
            HostTensor::i32(vec![b], base),
        ];
        let mut outputs = self.execute(&entry, &inputs)?;
        let logits = outputs[0].as_f32()?.to_vec();
        let hidden = outputs[1].as_f32()?.to_vec();
        let vocab = outputs[0].shape[2];
        self.store_batch_kv(ids, b, outputs.remove(2))?;

        for (bi, &sid) in ids.iter().enumerate() {
            let seq = self.slots[sid].as_mut().unwrap();
            let SeqPhase::Prefill(done) = seq.phase else { unreachable!() };
            let remaining = seq.prompt_len() - done;
            let consumed = remaining.min(c);
            self.stats.prefill_tokens += consumed as u64;
            if remaining <= c {
                // Final chunk: sample the first token from the last real
                // prompt position's logits.
                let last_row = remaining - 1;
                let row =
                    &logits[(bi * c + last_row) * vocab..(bi * c + last_row + 1) * vocab];
                let tok = sampler::sample(
                    row,
                    seq.sampling.temperature,
                    seq.sampling.top_k,
                    &mut seq.prng,
                );
                seq.generated.push(tok);
                if self.opts.emit_hiddens {
                    let h = &hidden
                        [(bi * c + last_row) * self.d_model..(bi * c + last_row + 1) * self.d_model];
                    seq.hiddens.extend_from_slice(h);
                }
                if self.opts.role == StageRole::Prefill {
                    // P/D split: the sequence's work here is done — export
                    // its KV state downstream instead of decoding.
                    let item = self.export_handoff(sid)?;
                    out.push(item);
                    continue;
                }
                seq.phase = SeqPhase::Decode;
                // Account the generated token's cache row.
                let mut table = std::mem::take(&mut seq.block_table);
                let grew = self.blocks.append_token(&mut table);
                self.slots[sid].as_mut().unwrap().block_table = table;
                if grew.is_err() {
                    self.preempt_for(sid)?;
                }
                // EOS straight out of prefill.
                self.post_token_checks(sid, out);
            } else {
                seq.phase = SeqPhase::Prefill(done + consumed);
            }
        }
        self.stats.prefill_calls += 1;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Decode (per-step)
    // ------------------------------------------------------------------

    fn run_decode(&mut self, slot_ids: &[usize], out: &mut Vec<StageItem>) -> Result<()> {
        let b = self.bucket_for("decode", slot_ids.len())?;
        // Oversized active sets are processed in bucket-size groups.
        for group in slot_ids.chunks(b) {
            self.run_decode_group(group, b, out)?;
        }
        Ok(())
    }

    fn run_decode_group(&mut self, ids: &[usize], b: usize, out: &mut Vec<StageItem>) -> Result<()> {
        let mut token = vec![0i32; b];
        let mut length = vec![0i32; b];
        let mut cond = vec![0f32; b * self.cond_dim.max(1)];
        for (bi, &sid) in ids.iter().enumerate() {
            // Preprocess hook: recompute conditioning every iteration.
            self.apply_preprocess(sid);
            let seq = self.slots[sid].as_ref().unwrap();
            token[bi] = seq.next_input_token() as i32;
            length[bi] = (seq.prompt_len() + seq.generated.len() - 1) as i32;
            if self.cond_dim > 0 {
                cond[bi * self.cond_dim..(bi + 1) * self.cond_dim].copy_from_slice(&seq.cond);
            }
        }
        let kv = self.assemble_kv(ids, b);
        let entry = format!("decode.b{b}");
        let mut inputs = vec![HostTensor::i32(vec![b], token)];
        if self.cond_dim > 0 {
            inputs.push(HostTensor::f32(vec![b, self.cond_dim], cond));
        }
        inputs.push(kv);
        inputs.push(HostTensor::i32(vec![b], length));
        let mut outputs = self.execute(&entry, &inputs)?;
        let kv_out = outputs.remove(2);
        let logits = outputs[0].as_f32()?;
        let hidden = outputs[1].as_f32()?;
        let vocab = outputs[0].shape[1];
        self.store_batch_kv(ids, b, kv_out)?;

        for (bi, &sid) in ids.iter().enumerate() {
            let seq = self.slots[sid].as_mut().unwrap();
            let row = &logits[bi * vocab..(bi + 1) * vocab];
            let tok =
                sampler::sample(row, seq.sampling.temperature, seq.sampling.top_k, &mut seq.prng);
            seq.generated.push(tok);
            if self.opts.emit_hiddens {
                seq.hiddens
                    .extend_from_slice(&hidden[bi * self.d_model..(bi + 1) * self.d_model]);
            }
            self.stats.decode_tokens += 1;
            let mut table = std::mem::take(&mut seq.block_table);
            let grew = self.blocks.append_token(&mut table);
            self.slots[sid].as_mut().unwrap().block_table = table;
            if grew.is_err() {
                self.preempt_for(sid)?;
            }
            self.post_token_checks(sid, out);
        }
        self.stats.decode_calls += 1;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Decode (fused multi-step scan)
    // ------------------------------------------------------------------

    fn run_scan(&mut self, slot_ids: &[usize], out: &mut Vec<StageItem>) -> Result<()> {
        let b = self.bucket_for("scan", slot_ids.len())?;
        for group in slot_ids.chunks(b) {
            self.run_scan_group(group, b, out)?;
        }
        Ok(())
    }

    fn run_scan_group(&mut self, ids: &[usize], b: usize, out: &mut Vec<StageItem>) -> Result<()> {
        let k = SCAN_STEPS;
        let mut token = vec![0i32; b];
        let mut length = vec![0i32; b];
        let mut active = vec![0f32; b];
        let mut cond = vec![0f32; b * self.cond_dim.max(1)];
        let mut eos = vec![0i32; b];
        for (bi, &sid) in ids.iter().enumerate() {
            self.apply_preprocess(sid);
            let seq = self.slots[sid].as_ref().unwrap();
            token[bi] = seq.next_input_token() as i32;
            length[bi] = (seq.prompt_len() + seq.generated.len() - 1) as i32;
            active[bi] = 1.0;
            // ignore_eos: pass an unreachable id so the scan never freezes.
            eos[bi] = if seq.sampling.ignore_eos { -1 } else { self.eos_id as i32 };
            if self.cond_dim > 0 {
                cond[bi * self.cond_dim..(bi + 1) * self.cond_dim].copy_from_slice(&seq.cond);
            }
        }
        let kv = self.assemble_kv(ids, b);
        let entry = format!("scan.b{b}.k{k}");
        let mut inputs = vec![HostTensor::i32(vec![b], token)];
        if self.cond_dim > 0 {
            inputs.push(HostTensor::f32(vec![b, self.cond_dim], cond));
        }
        inputs.push(kv);
        inputs.push(HostTensor::i32(vec![b], length));
        inputs.push(HostTensor::f32(vec![b], active));
        inputs.push(HostTensor::i32(vec![b], eos));
        let mut outputs = self.execute(&entry, &inputs)?;
        let kv_out = outputs.remove(2);
        let toks = outputs[0].as_i32()?;
        let hiddens = outputs[1].as_f32()?;
        self.store_batch_kv(ids, b, kv_out)?;

        for (bi, &sid) in ids.iter().enumerate() {
            let seq = self.slots[sid].as_mut().unwrap();
            let mut stopped = false;
            for ki in 0..k {
                let t = toks[bi * k + ki];
                if stopped {
                    break;
                }
                let tok = t as u32;
                seq.generated.push(tok);
                if self.opts.emit_hiddens {
                    let off = (bi * k + ki) * self.d_model;
                    seq.hiddens.extend_from_slice(&hiddens[off..off + self.d_model]);
                }
                self.stats.decode_tokens += 1;
                if !seq.sampling.ignore_eos && tok == self.eos_id {
                    stopped = true;
                }
                let mut table = std::mem::take(&mut seq.block_table);
                let grew = self.blocks.append_token(&mut table);
                seq.block_table = table;
                if grew.is_err() {
                    self.preempt_for(sid)?;
                    break;
                }
            }
            self.post_token_checks(sid, out);
        }
        self.stats.scan_calls += 1;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Bookkeeping
    // ------------------------------------------------------------------

    fn apply_preprocess(&mut self, sid: usize) {
        if self.cond_dim == 0 {
            return;
        }
        let seq = self.slots[sid].as_mut().unwrap();
        seq.cond = match self.opts.preprocess {
            Preprocess::None => vec![0.0; self.cond_dim],
            Preprocess::UpstreamMean => seq.upstream.mean(self.cond_dim),
            Preprocess::UpstreamLast => {
                if seq.upstream.rows > 0 {
                    seq.upstream.last.clone()
                } else {
                    vec![0.0; self.cond_dim]
                }
            }
        };
    }

    /// EOS / cap checks + streaming + completion for a slot.
    fn post_token_checks(&mut self, sid: usize, out: &mut Vec<StageItem>) {
        let Some(seq) = self.slots[sid].as_mut() else { return };
        if seq.phase == SeqPhase::Done {
            return;
        }
        let total = seq.prompt_len() + seq.generated.len();
        if !seq.sampling.ignore_eos && seq.generated.last() == Some(&self.eos_id) {
            seq.finish_reason = Some(FinishReason::Eos);
            seq.phase = SeqPhase::Done;
        } else if seq.generated.len() >= seq.sampling.max_new_tokens {
            seq.finish_reason = Some(FinishReason::MaxTokens);
            seq.phase = SeqPhase::Done;
        } else if total + 1 >= self.max_seq {
            seq.finish_reason = Some(FinishReason::CacheCap);
            seq.phase = SeqPhase::Done;
        }
        let done = seq.phase == SeqPhase::Done;
        let should_stream = self.opts.stream_chunk > 0
            && seq.generated.len() - seq.streamed >= self.opts.stream_chunk;
        if done || should_stream {
            out.push(self.make_item(sid, done));
        }
        if done {
            let seq = self.slots[sid].take().unwrap();
            self.stash_prefix_kv(sid, &seq.block_table, seq.prompt_len());
            self.blocks.release(&seq.block_table);
        }
    }

    fn make_item(&mut self, sid: usize, finished: bool) -> StageItem {
        let seq = self.slots[sid].as_mut().unwrap();
        let from = seq.streamed;
        let to = seq.generated.len();
        let toks: Vec<i32> = seq.generated[from..to].iter().map(|&t| t as i32).collect();
        let mut item = StageItem::new(seq.id)
            .with("tokens", HostTensor::i32(vec![to - from], toks));
        if self.opts.emit_hiddens {
            let h = seq.hiddens[from * self.d_model..to * self.d_model].to_vec();
            item = item.with("hiddens", HostTensor::f32(vec![to - from, self.d_model], h));
        }
        seq.streamed = to;
        if finished {
            item = item.finished();
        }
        item
    }

    /// Preempt the youngest running sequence to free KV blocks (recompute
    /// preemption).  `for_sid` is the slot that failed to grow; if it is
    /// itself the only candidate it finishes with `CacheCap`.
    fn preempt_for(&mut self, for_sid: usize) -> Result<()> {
        self.stats.preemptions += 1;
        let youngest = self
            .slots
            .iter()
            .enumerate()
            .filter(|(i, s)| *i != for_sid && s.is_some())
            .max_by_key(|(_, s)| s.as_ref().unwrap().admitted_iter)
            .map(|(i, _)| i);
        match youngest {
            Some(v) => {
                let mut seq = self.slots[v].take().unwrap();
                let computed = match seq.phase {
                    SeqPhase::Prefill(done) => done,
                    _ => seq.prompt_len(),
                };
                self.stash_prefix_kv(v, &seq.block_table, computed);
                self.blocks.release(&seq.block_table);
                // Prompt sequences re-prefill; imported sequences rewind
                // to their handoff and re-import at the next admission.
                seq.reset_for_requeue();
                self.waiting.push_front(seq);
                // Retry the failed growth for the original slot.
                if let Some(seq) = self.slots[for_sid].as_mut() {
                    // The failed append neither allocated nor counted, so
                    // retrying it is clean.
                    let mut table = std::mem::take(&mut seq.block_table);
                    let r = self.blocks.append_token(&mut table);
                    self.slots[for_sid].as_mut().unwrap().block_table = table;
                    if r.is_err() {
                        return self.preempt_for(for_sid);
                    }
                }
                Ok(())
            }
            None => {
                if let Some(seq) = self.slots[for_sid].as_mut() {
                    seq.finish_reason = Some(FinishReason::CacheCap);
                    seq.phase = SeqPhase::Done;
                }
                Ok(())
            }
        }
    }

    fn bucket_for(&self, family: &str, n: usize) -> Result<usize> {
        let buckets = self.rt.model().buckets(family);
        buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .or(buckets.last().copied())
            .ok_or_else(|| anyhow::anyhow!("no {family} buckets for {}", self.model_name()))
    }

    fn execute(&mut self, entry: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let t0 = std::time::Instant::now();
        let r = self.rt.run(entry, inputs);
        self.stats.exec_seconds += t0.elapsed().as_secs_f64();
        r
    }

    /// Drop every compiled executable (the baseline's per-request
    /// recompilation mode — no cross-request graph reuse).
    pub fn evict_compiled(&mut self) {
        self.rt.evict_all();
    }

    // ------------------------------------------------------------------
    // KV marshaling: slot store <-> bucket-shaped batch tensor
    // ------------------------------------------------------------------

    fn kv_chunk(&self) -> usize {
        self.n_heads * self.max_seq * self.d_head
    }

    /// Build the `[L, 2, b, H, S, dh]` input KV for a batch call.  Fast
    /// path: if the previous call had the same slot mapping, its output
    /// is reused verbatim (zero copies).
    fn assemble_kv(&mut self, ids: &[usize], b: usize) -> HostTensor {
        let t0 = std::time::Instant::now();
        let shape = vec![self.n_layers, 2, b, self.n_heads, self.max_seq, self.d_head];
        // §Perf escape hatch: OMNI_DISABLE_BATCH_KV=1 forces the original
        // assemble/scatter-every-step path (before/after measurements).
        if std::env::var_os("OMNI_DISABLE_BATCH_KV").is_some() {
            self.flush_batch_kv();
        }
        if let Some((cached_ids, cached_b, _)) = &self.batch_kv {
            if cached_ids == ids && *cached_b == b {
                let (_, _, data) = self.batch_kv.take().unwrap();
                self.stats.marshal_seconds += t0.elapsed().as_secs_f64();
                return HostTensor::f32(shape, data);
            }
        }
        // Slow path: membership changed — flush the cache into slots,
        // then gather the requested slots.
        self.flush_batch_kv();
        let chunk = self.kv_chunk();
        let lk = self.n_layers * 2;
        let mut out = vec![0f32; lk * b * chunk];
        for li in 0..lk {
            for (bi, &sid) in ids.iter().enumerate() {
                let src = &self.slot_kv[sid][li * chunk..(li + 1) * chunk];
                out[(li * b + bi) * chunk..(li * b + bi + 1) * chunk].copy_from_slice(src);
            }
        }
        self.stats.marshal_seconds += t0.elapsed().as_secs_f64();
        HostTensor::f32(shape, out)
    }

    /// Record a call's output KV in batch layout (deferred scatter).
    fn store_batch_kv(&mut self, ids: &[usize], b: usize, kv: HostTensor) -> Result<()> {
        let chunk = self.kv_chunk();
        let lk = self.n_layers * 2;
        let data = match kv.data {
            crate::runtime::TensorData::F32(v) => v,
            _ => bail!("store_batch_kv: kv must be f32"),
        };
        if data.len() != lk * b * chunk {
            bail!("store_batch_kv: unexpected kv size {}", data.len());
        }
        self.batch_kv = Some((ids.to_vec(), b, data));
        Ok(())
    }

    /// Write the cached batch-layout KV back into per-slot storage
    /// (called when membership changes or a slot is re-used).
    fn flush_batch_kv(&mut self) {
        let Some((ids, b, data)) = self.batch_kv.take() else { return };
        let t0 = std::time::Instant::now();
        let chunk = self.kv_chunk();
        let lk = self.n_layers * 2;
        for li in 0..lk {
            for (bi, &sid) in ids.iter().enumerate() {
                let src = &data[(li * b + bi) * chunk..(li * b + bi + 1) * chunk];
                self.slot_kv[sid][li * chunk..(li + 1) * chunk].copy_from_slice(src);
            }
        }
        self.stats.marshal_seconds += t0.elapsed().as_secs_f64();
    }

    // ------------------------------------------------------------------
    // Cross-request prefix cache: slot store <-> host content stash
    // ------------------------------------------------------------------

    /// Copy the stashed KV rows of the table's leading `matched` blocks
    /// into `slot`'s store, returning how many prompt tokens prefill may
    /// skip.  Stops at the first block with no stashed content (the
    /// manager's match is accounting-level; skipping additionally needs
    /// the rows), and always leaves at least one prompt position for
    /// prefill to run — sampling the first token needs its logits.  Any
    /// position not skipped is recomputed over the restored rows, which
    /// is bit-identical (KV is a deterministic function of the prefix).
    fn restore_prefix(
        &mut self,
        slot: usize,
        table: &BlockTable,
        matched: usize,
        prompt_len: usize,
    ) -> usize {
        let bs = self.blocks.block_size();
        let (chunk, s_max, dh) = (self.kv_chunk(), self.max_seq, self.d_head);
        let lk = self.n_layers * 2;
        let row = bs * dh;
        let mut restored = 0usize;
        for i in 0..matched {
            let Some(h) = self.blocks.block_hash(table.blocks[i]) else { break };
            let Some(rows) = self.prefix_kv.get(&h) else { break };
            for li in 0..lk {
                for hd in 0..self.n_heads {
                    let dst = li * chunk + hd * s_max * dh + i * row;
                    let src = (li * self.n_heads + hd) * row;
                    self.slot_kv[slot][dst..dst + row].copy_from_slice(&rows[src..src + row]);
                }
            }
            restored += 1;
        }
        (restored * bs).min(prompt_len.saturating_sub(1))
    }

    /// Stash the computed full prompt blocks' KV rows keyed by prefix
    /// hash, so future prompts sharing the prefix skip their prefill.
    /// `computed` is the prefill watermark — only positions with valid
    /// KV (a cancelled mid-prefill sequence stashes just its finished
    /// blocks).  Called on every release path: completion, handoff
    /// export, preemption, and cancel.
    fn stash_prefix_kv(&mut self, sid: usize, table: &BlockTable, computed: usize) {
        if !self.opts.prefix_cache {
            return;
        }
        let bs = self.blocks.block_size();
        let n = computed / bs;
        if n == 0 {
            return;
        }
        // The slot's latest KV may still live in the batch cache.
        self.flush_batch_kv();
        let (chunk, s_max, dh) = (self.kv_chunk(), self.max_seq, self.d_head);
        let lk = self.n_layers * 2;
        let row = bs * dh;
        for i in 0..n.min(table.blocks.len()) {
            let Some(h) = self.blocks.block_hash(table.blocks[i]) else { continue };
            if self.prefix_kv.contains_key(&h) {
                continue;
            }
            let mut rows = Vec::with_capacity(lk * self.n_heads * row);
            for li in 0..lk {
                for hd in 0..self.n_heads {
                    let off = li * chunk + hd * s_max * dh + i * row;
                    rows.extend_from_slice(&self.slot_kv[sid][off..off + row]);
                }
            }
            self.prefix_kv.insert(h, rows);
        }
    }
}

/// Token vector used for block-table hashing: real tokens hash as
/// themselves (prefix sharing), embed rows hash uniquely per request so
/// multimodal prefixes never falsely share.
fn prompt_hash_tokens(seq: &Sequence) -> Vec<u32> {
    seq.prompt
        .iter()
        .map(|p| match p {
            PromptItem::Token(t) => *t,
            PromptItem::Embed(i) => {
                0x8000_0000u32 | ((seq.id as u32).wrapping_mul(2654435761) ^ (*i as u32))
            }
        })
        .collect()
}

/// Convenience: build an [`ArJob`] from a plain token prompt.
pub fn token_job(req_id: u64, tokens: &[u32], sampling: SamplingParams) -> ArJob {
    ArJob {
        req_id,
        prompt: tokens.iter().map(|&t| PromptItem::Token(t)).collect(),
        mm_embeds: vec![],
        emb_dim: 0,
        sampling,
    }
}

/// Convenience: prompt = BOS + embedding rows (Talker-style).
pub fn embed_job(req_id: u64, rows: &[f32], dim: usize, sampling: SamplingParams) -> ArJob {
    let n = if dim == 0 { 0 } else { rows.len() / dim };
    let mut prompt = vec![PromptItem::Token(BOS_ID)];
    prompt.extend((0..n).map(PromptItem::Embed));
    ArJob { req_id, prompt, mm_embeds: rows.to_vec(), emb_dim: dim, sampling }
}
