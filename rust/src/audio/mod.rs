//! Audio substrate: codec-frame bookkeeping, RTF computation, WAV output.
//!
//! The Talker emits *codec tokens*; the Vocoder turns codec frames into
//! waveform samples.  RTF (real-time factor, the paper's §4.1 metric) is
//! `processing_time / generated_audio_duration`, so the system needs an
//! authoritative mapping from token counts to audio seconds.

/// Global audio clock for the reproduction (samples per second).
pub const SAMPLE_RATE: u32 = 16_000;

/// Codec frame rate used by all talkers (frames per second of audio).
/// 50 Hz matches the common 20 ms codec frame.
pub const CODEC_FRAME_HZ: u32 = 50;

/// Seconds of audio represented by `n` codec tokens (1 token = 1 frame).
pub fn codec_tokens_to_seconds(n: usize) -> f64 {
    n as f64 / CODEC_FRAME_HZ as f64
}

/// Samples represented by `n` codec tokens.
pub fn codec_tokens_to_samples(n: usize) -> usize {
    n * (SAMPLE_RATE / CODEC_FRAME_HZ) as usize
}

/// Seconds of audio represented by `n` waveform samples (the duration a
/// client can compute from streamed `AudioChunk` deltas).
pub fn samples_to_seconds(n: usize) -> f64 {
    n as f64 / SAMPLE_RATE as f64
}

/// Real-time factor: processing seconds per generated-audio second.
/// Returns `f64::INFINITY` when no audio was produced.
pub fn rtf(processing_s: f64, audio_tokens: usize) -> f64 {
    let audio_s = codec_tokens_to_seconds(audio_tokens);
    if audio_s <= 0.0 {
        f64::INFINITY
    } else {
        processing_s / audio_s
    }
}

/// Minimal mono 16-bit PCM WAV writer (for the streaming-TTS example).
pub fn write_wav(path: &std::path::Path, samples: &[f32]) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    let n = samples.len() as u32;
    let data_len = n * 2;
    let byte_rate = SAMPLE_RATE * 2;

    f.write_all(b"RIFF")?;
    f.write_all(&(36 + data_len).to_le_bytes())?;
    f.write_all(b"WAVE")?;
    f.write_all(b"fmt ")?;
    f.write_all(&16u32.to_le_bytes())?;
    f.write_all(&1u16.to_le_bytes())?; // PCM
    f.write_all(&1u16.to_le_bytes())?; // mono
    f.write_all(&SAMPLE_RATE.to_le_bytes())?;
    f.write_all(&byte_rate.to_le_bytes())?;
    f.write_all(&2u16.to_le_bytes())?; // block align
    f.write_all(&16u16.to_le_bytes())?; // bits
    f.write_all(b"data")?;
    f.write_all(&data_len.to_le_bytes())?;
    for &s in samples {
        let v = (s.clamp(-1.0, 1.0) * i16::MAX as f32) as i16;
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_time_mapping() {
        assert_eq!(codec_tokens_to_seconds(50), 1.0);
        assert_eq!(codec_tokens_to_samples(50), SAMPLE_RATE as usize);
    }

    #[test]
    fn rtf_definition() {
        // 2 s of processing for 4 s of audio -> RTF 0.5 (faster than RT).
        assert!((rtf(2.0, 200) - 0.5).abs() < 1e-12);
        assert!(rtf(1.0, 0).is_infinite());
    }

    #[test]
    fn wav_header() {
        let dir = std::env::temp_dir().join("omni_serve_wav_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.wav");
        write_wav(&p, &[0.0, 0.5, -0.5, 1.0]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..4], b"RIFF");
        assert_eq!(&bytes[8..12], b"WAVE");
        assert_eq!(bytes.len(), 44 + 8);
        std::fs::remove_file(&p).ok();
    }
}
