//! The typed client-facing request: a builder over [`crate::trace::Request`]
//! that adds the serving-API surface the raw trace record never had —
//! streaming on/off, scheduling priority, and an optional deadline.
//!
//! ```no_run
//! use omni_serve::serving::{OmniRequest, Priority};
//! use omni_serve::trace::Modality;
//!
//! let req = OmniRequest::text(1, vec![1, 17, 23])
//!     .modality(Modality::Audio)
//!     .mm_frames(48)
//!     .max_text_tokens(24)
//!     .max_audio_tokens(96)
//!     .streaming(true)
//!     .priority(Priority::High)
//!     .deadline_s(5.0);
//! ```
//!
//! [`crate::serving::ServingSession::submit_request`] consumes one and
//! returns a [`crate::serving::ResponseStream`].

use std::time::Duration;

use anyhow::Result;

use crate::trace::{Modality, Request};

/// Admission priority.  Higher-priority submissions are enqueued ahead
/// of lower-priority ones at every stage's admission queue
/// ([`crate::scheduler::StageScheduler`]); ordering within a class stays
/// FIFO, and nothing already admitted to an engine is ever displaced.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    /// Numeric rank carried through [`crate::stage_graph::transfers::ReqMeta`]
    /// into the per-stage schedulers (higher = sooner).
    pub fn rank(self) -> u8 {
        match self {
            Priority::Low => 0,
            Priority::Normal => 1,
            Priority::High => 2,
        }
    }
}

/// A typed serving request (see module docs).
#[derive(Debug, Clone)]
pub struct OmniRequest {
    req: Request,
    stream: bool,
    priority: Priority,
    deadline_s: Option<f64>,
    tenant: Option<String>,
}

impl From<Request> for OmniRequest {
    /// Wrap a raw trace request with the defaults of the pre-streaming
    /// API: no mid-flight deltas, normal priority, no deadline, the
    /// anonymous tenant.
    fn from(req: Request) -> Self {
        Self { req, stream: false, priority: Priority::Normal, deadline_s: None, tenant: None }
    }
}

impl OmniRequest {
    /// A text request with the workload-substrate defaults (everything
    /// overridable through the builder methods).
    pub fn text(id: u64, prompt_tokens: Vec<u32>) -> Self {
        Self::from(Request {
            id,
            arrival_s: 0.0,
            modality: Modality::Text,
            prompt_tokens,
            mm_frames: 0,
            seed: id,
            max_text_tokens: 24,
            max_audio_tokens: 0,
            diffusion_steps: 0,
            ignore_eos: true,
        })
    }

    pub fn modality(mut self, m: Modality) -> Self {
        self.req.modality = m;
        self
    }

    pub fn mm_frames(mut self, frames: usize) -> Self {
        self.req.mm_frames = frames;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.req.seed = seed;
        self
    }

    pub fn max_text_tokens(mut self, n: usize) -> Self {
        self.req.max_text_tokens = n;
        self
    }

    pub fn max_audio_tokens(mut self, n: usize) -> Self {
        self.req.max_audio_tokens = n;
        self
    }

    pub fn diffusion_steps(mut self, n: usize) -> Self {
        self.req.diffusion_steps = n;
        self
    }

    pub fn ignore_eos(mut self, on: bool) -> Self {
        self.req.ignore_eos = on;
        self
    }

    /// Deliver typed [`crate::serving::OutputDelta`]s mid-flight (off =
    /// the stream carries only the terminal `Done`).
    pub fn streaming(mut self, on: bool) -> Self {
        self.stream = on;
        self
    }

    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    /// Cancel the request automatically `s` seconds after submission
    /// (it resolves with `Done { cancelled: true }`).
    pub fn deadline_s(mut self, s: f64) -> Self {
        self.deadline_s = Some(s);
        self
    }

    pub fn deadline(self, d: Duration) -> Self {
        self.deadline_s(d.as_secs_f64())
    }

    /// Attribute the request to a named tenant for weighted fair
    /// queueing (see [`crate::config::AdmissionConfig::tenant_weights`]).
    /// Unset = the anonymous tenant at weight 1.0.
    pub fn tenant(mut self, name: impl Into<String>) -> Self {
        self.tenant = Some(name.into());
        self
    }

    pub fn tenant_name(&self) -> Option<&str> {
        self.tenant.as_deref()
    }

    pub fn id(&self) -> u64 {
        self.req.id
    }

    /// The underlying trace request.
    pub fn request(&self) -> &Request {
        &self.req
    }

    pub fn is_streaming(&self) -> bool {
        self.stream
    }

    pub(crate) fn validate(&self) -> Result<()> {
        if let Some(d) = self.deadline_s {
            anyhow::ensure!(
                d.is_finite() && d > 0.0,
                "request {}: deadline must be a positive number of seconds, got {d}",
                self.req.id
            );
        }
        Ok(())
    }

    pub(crate) fn into_parts(self) -> (Request, bool, Priority, Option<f64>, Option<String>) {
        (self.req, self.stream, self.priority, self.deadline_s, self.tenant)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_round_trip() {
        let r = OmniRequest::text(9, vec![1, 2, 3])
            .modality(Modality::Video)
            .mm_frames(64)
            .seed(7)
            .max_text_tokens(32)
            .max_audio_tokens(96)
            .diffusion_steps(4)
            .ignore_eos(false)
            .streaming(true)
            .priority(Priority::High)
            .deadline_s(2.5)
            .tenant("acme");
        assert!(r.validate().is_ok());
        assert!(r.is_streaming());
        assert_eq!(r.tenant_name(), Some("acme"));
        let (req, stream, prio, deadline, tenant) = r.into_parts();
        assert_eq!(req.id, 9);
        assert_eq!(req.modality, Modality::Video);
        assert_eq!(req.mm_frames, 64);
        assert_eq!(req.seed, 7);
        assert_eq!(req.max_text_tokens, 32);
        assert_eq!(req.max_audio_tokens, 96);
        assert_eq!(req.diffusion_steps, 4);
        assert!(!req.ignore_eos);
        assert!(stream);
        assert_eq!(prio, Priority::High);
        assert_eq!(deadline, Some(2.5));
        assert_eq!(tenant.as_deref(), Some("acme"));
    }

    #[test]
    fn from_request_keeps_batch_defaults() {
        let r = OmniRequest::text(1, vec![5]);
        let o = OmniRequest::from(r.request().clone());
        assert!(!o.is_streaming());
        assert_eq!(o.priority, Priority::Normal);
        assert!(o.deadline_s.is_none());
    }

    #[test]
    fn bad_deadline_rejected() {
        for d in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let r = OmniRequest::text(1, vec![]).deadline_s(d);
            assert!(r.validate().is_err(), "deadline {d} must be rejected");
        }
        assert!(OmniRequest::text(1, vec![]).deadline(Duration::from_millis(10)).validate().is_ok());
    }

    #[test]
    fn priority_ranks_are_ordered() {
        assert!(Priority::High.rank() > Priority::Normal.rank());
        assert!(Priority::Normal.rank() > Priority::Low.rank());
        assert_eq!(Priority::default(), Priority::Normal);
    }
}
