//! End-to-end request cancellation (the tombstone set).
//!
//! Cancelling a request ([`crate::serving::ResponseStream::cancel`], a
//! deadline expiry, or the server's `cancel` op) marks a per-request
//! *tombstone* here.  Every stage thread consults the set:
//!
//! * items pulled from the frontend or a routed edge for a tombstoned
//!   request are dropped before their transfer runs (the router leg of
//!   the propagation — queued work never reaches an engine);
//! * on every generation change the stage sweeps its admission queue
//!   ([`crate::scheduler::StageScheduler::cancel`]) and its engine
//!   (`cancel(req_id)` on each engine type), releasing KV blocks of
//!   in-flight AR sequences;
//! * exported-but-unimported prefill handoffs are covered by the item
//!   drop: the prefill pool released its blocks at export, and the
//!   decode pool never imports a tombstoned handoff.
//!
//! The hot path is kept cheap: with no cancellations ever (the common
//! case) every check is one relaxed atomic load.  Stage threads rescan
//! only when the *generation* counter moved, so a tombstone costs one
//! sweep per stage, not one per loop iteration.  Entries are purged
//! after a TTL by the session collector — late items of a long-dead
//! request are already filtered out of the stream map by then.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::RwLock;

/// How long a tombstone stays visible to stage threads before the
/// collector purges it.  Far longer than any item can sit in a
/// connector channel of a live pipeline.
pub const TOMBSTONE_TTL_S: f64 = 120.0;

/// The shared set of cancelled request ids (see module docs).
#[derive(Debug, Default)]
pub struct Tombstones {
    /// Bumped on every [`Self::mark`]; stage threads sweep their local
    /// state only when this moves.
    gen: AtomicU64,
    /// Live entry count — the fast-path empty check.
    count: AtomicUsize,
    map: RwLock<HashMap<u64, f64>>,
}

impl Tombstones {
    pub fn new() -> Self {
        Self::default()
    }

    /// One relaxed load; true iff no request is currently tombstoned.
    pub fn is_empty(&self) -> bool {
        self.count.load(Ordering::Relaxed) == 0
    }

    /// Current sweep generation (moves on every [`Self::mark`]).
    pub fn generation(&self) -> u64 {
        self.gen.load(Ordering::Acquire)
    }

    /// Tombstone `req` at session time `t`.
    pub fn mark(&self, req: u64, t: f64) {
        {
            let mut m = self.map.write().unwrap();
            if m.insert(req, t).is_none() {
                self.count.fetch_add(1, Ordering::Relaxed);
            }
        }
        // After the entry is visible, so a sweep triggered by this bump
        // always sees it.
        self.gen.fetch_add(1, Ordering::Release);
    }

    pub fn contains(&self, req: u64) -> bool {
        !self.is_empty() && self.map.read().unwrap().contains_key(&req)
    }

    /// All live tombstoned request ids (a sweep's worklist).
    pub fn snapshot(&self) -> Vec<u64> {
        if self.is_empty() {
            return vec![];
        }
        self.map.read().unwrap().keys().copied().collect()
    }

    /// Drop entries older than `ttl_s`.  Does NOT bump the generation —
    /// a purge removes work, it never creates any.
    pub fn purge_older(&self, now: f64, ttl_s: f64) {
        if self.is_empty() {
            return;
        }
        let mut m = self.map.write().unwrap();
        let before = m.len();
        m.retain(|_, &mut t| now - t < ttl_s);
        let removed = before - m.len();
        if removed > 0 {
            self.count.fetch_sub(removed, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_fast_path() {
        let t = Tombstones::new();
        assert!(t.is_empty());
        assert!(!t.contains(7));
        assert!(t.snapshot().is_empty());
        assert_eq!(t.generation(), 0);
    }

    #[test]
    fn mark_bumps_generation_and_is_visible() {
        let t = Tombstones::new();
        t.mark(7, 1.0);
        assert!(!t.is_empty());
        assert!(t.contains(7));
        assert!(!t.contains(8));
        assert_eq!(t.generation(), 1);
        // Re-marking the same request still moves the generation (a
        // sweep must run even if the entry already existed)...
        t.mark(7, 2.0);
        assert_eq!(t.generation(), 2);
        // ...but the count stays correct.
        t.mark(8, 2.0);
        let mut s = t.snapshot();
        s.sort_unstable();
        assert_eq!(s, vec![7, 8]);
    }

    #[test]
    fn purge_respects_ttl_and_keeps_generation() {
        let t = Tombstones::new();
        t.mark(1, 0.0);
        t.mark(2, 50.0);
        let gen = t.generation();
        t.purge_older(100.0, 60.0); // entry 1 is 100s old, entry 2 is 50s
        assert!(!t.contains(1));
        assert!(t.contains(2));
        assert_eq!(t.generation(), gen, "purge must not trigger sweeps");
        t.purge_older(1000.0, 60.0);
        assert!(t.is_empty());
    }
}
