//! The elastic autoscaler control loop (paper §3 "flexible GPU
//! allocation" under live traffic).
//!
//! Every `interval_s` the loop samples the scheduler load each engine
//! replica publishes ([`super::ReplicaSlot`]: pending admission-queue
//! depth + engine busyness) and makes at most one decision per stage:
//!
//! * **scale up** — mean queue depth per live replica ≥ `scale_up_queue`:
//!   pack a device group on the least-loaded devices
//!   ([`pack_group`]), pass memory admission on the session's
//!   [`crate::device::DevicePool`], wire the replica into every routed
//!   edge, and spawn its engine thread.  Gated by the per-stage
//!   `max_replicas` cap and the global `gpu_budget`, counted in
//!   milli-GPUs so fractional replicas ([`crate::gpu_share`]) scale by
//!   their share first — spare slivers of carved devices are spent
//!   before a whole fresh device is.
//! * **scale down** — mean queue depth < `scale_down_queue` and an idle
//!   replica exists: *drain before retire*.  The victim's incoming edges
//!   stop routing new requests to it
//!   ([`crate::connector::router::EdgeCtl::drain_consumer`]); once
//!   nothing is in flight, no sticky request is assigned, and its engine
//!   and queue are empty, the replica thread is told to exit, joined,
//!   unwired, and its devices released.
//!
//! Decisions are recorded as [`Event::Scale`] so the run report carries
//! the scale-event log and replica-count timeline.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::time::Duration;

use anyhow::Result;

use crate::config::{AutoscalerConfig, RoutingKind};
use crate::gpu_share::DEVICE_MILLI;
use crate::metrics::Event;
use crate::scheduler::allocator::{commit_group, pack_group, release_group};

use super::{spawn_replica, SessionInner};

/// Control-loop entry point (runs on the session's autoscaler thread
/// until the session stops or fails).
pub(crate) fn run(inner: &Arc<SessionInner>, cfg: &AutoscalerConfig) {
    loop {
        std::thread::sleep(Duration::from_secs_f64(cfg.interval_s));
        if inner.stop.load(Ordering::SeqCst) || inner.failed.load(Ordering::SeqCst) {
            return;
        }
        if let Err(e) = tick(inner, cfg) {
            eprintln!("autoscaler tick failed: {e:#}");
        }
    }
}

/// Whether a stage's incoming edges allow adding replicas: per-item
/// routing into a stateful transfer would scramble per-request state, so
/// such stages stay at their configured replica count.
fn scalable(inner: &SessionInner, stage_name: &str) -> bool {
    for (ei, e) in inner.graph.config.edges.iter().enumerate() {
        if e.to != stage_name {
            continue;
        }
        let per_item = matches!(
            inner.edge_routing[ei],
            RoutingKind::RoundRobin | RoutingKind::LeastDepth
        );
        if per_item && !inner.registry.is_stateless(&e.transfer) {
            return false;
        }
    }
    true
}

pub(crate) fn tick(inner: &Arc<SessionInner>, cfg: &AutoscalerConfig) -> Result<()> {
    let now = inner.clock.now();
    let mut stages = inner.stages.lock().unwrap();

    // ---- 1. Progress draining replicas (drain → retire → reap). ----
    for (si, st) in stages.iter_mut().enumerate() {
        for r in st.replicas.iter() {
            if r.draining && !r.retire.load(Ordering::SeqCst) {
                let quiesced = r
                    .in_edges
                    .iter()
                    .all(|&(ei, uid)| inner.edges[ei].consumer_quiesced(uid))
                    && r.slot.queued() == 0
                    && !r.slot.busy();
                if quiesced {
                    // The replica thread exits once its engine drains;
                    // wake it so a parked worker sees the retire flag
                    // now instead of at its liveness backstop.
                    r.retire.store(true, Ordering::SeqCst);
                    r.wake.wake(crate::event_core::WAKE_CTL);
                }
            }
        }
        let mut k = 0;
        while k < st.replicas.len() {
            if !(st.replicas[k].draining && st.replicas[k].join.is_finished()) {
                k += 1;
                continue;
            }
            let r = st.replicas.remove(k);
            for &(ei, uid) in &r.in_edges {
                inner.edges[ei].remove_consumer(uid);
            }
            for &(ei, uid) in &r.out_edges {
                inner.edges[ei].remove_producer(uid);
            }
            for res in &r.reservations {
                inner.pool.release(res);
            }
            release_group(&mut inner.dev_load.lock().unwrap(), &r.devices);
            {
                let cm = inner.plan.assignment(si).compute_milli;
                let mut m = inner.dev_milli.lock().unwrap();
                for g in &r.devices {
                    m.release(g.0, cm);
                }
            }
            match r.join.join() {
                Ok(Ok(summary)) => inner.retired.lock().unwrap().push(summary),
                Ok(Err(e)) => inner.record_error(e),
                Err(_) => inner.record_error(anyhow::anyhow!("stage thread panicked")),
            }
        }
    }

    // ---- 2. Scale decisions (at most one per stage per tick). ----
    // Compute currently held by every replica, live or draining (a
    // draining replica's devices free only when it is reaped), counted
    // in milli-GPUs: a fractional replica charges only its share, so
    // fractions scale up before whole devices are spent.
    let mut milli_used: u64 = stages
        .iter()
        .enumerate()
        .map(|(si, st)| {
            let m = inner.plan.assignment(si).compute_milli as u64;
            st.replicas.iter().map(|r| r.devices.len() as u64 * m).sum::<u64>()
        })
        .sum();

    for si in 0..stages.len() {
        let stage_name = inner.graph.stage(si).name.clone();
        let st = &mut stages[si];
        if now - st.last_scale_t < cfg.cooldown_s {
            continue;
        }
        let live: Vec<usize> = (0..st.replicas.len())
            .filter(|&k| !st.replicas[k].draining)
            .collect();
        let n_live = live.len();
        if n_live == 0 {
            continue;
        }
        let queued: usize = live.iter().map(|&k| st.replicas[k].slot.queued()).sum();
        let pressure = queued as f64 / n_live as f64;

        // Scale down: drain the newest fully idle replica.
        if n_live > cfg.min_replicas && pressure < cfg.scale_down_queue {
            let victim = live
                .iter()
                .rev()
                .find(|&&k| {
                    !st.replicas[k].slot.busy() && st.replicas[k].slot.queued() == 0
                })
                .copied();
            if let Some(k) = victim {
                // Entry replicas: unregister the front sender first so no
                // new request lands in its channel while it drains.
                if let Some(fuid) = st.replicas[k].front_uid {
                    inner.front.lock().unwrap().0.retain(|f| f.uid != fuid);
                }
                for &(ei, uid) in &st.replicas[k].in_edges {
                    inner.edges[ei].drain_consumer(uid);
                }
                st.replicas[k].draining = true;
                // A parked victim must notice the drain (publish its
                // now-empty state) without waiting for traffic.
                st.replicas[k].wake.wake(crate::event_core::WAKE_CTL);
                st.last_scale_t = now;
                inner.recorder.emit(Event::Scale {
                    stage: stage_name.clone(),
                    t: now,
                    from: n_live,
                    to: n_live - 1,
                });
                continue;
            }
        }

        // Scale up: pack, admit, wire, spawn.
        if n_live < cfg.max_replicas
            && pressure >= cfg.scale_up_queue
            && scalable(inner, &stage_name)
        {
            let a = inner.plan.assignment(si);
            let tp = a.devices.len().max(1);
            let frac = a.compute_milli < DEVICE_MILLI;
            let need = tp as u64 * a.compute_milli as u64;
            if cfg.gpu_budget > 0
                && milli_used + need > cfg.gpu_budget as u64 * DEVICE_MILLI as u64
            {
                continue;
            }
            // Fraction-first packing: a fractional replica fills spare
            // milli on an already-carved device before whole-slot
            // packing claims a fresh one.
            let group = {
                let load = inner.dev_load.lock().unwrap();
                let milli = inner.dev_milli.lock().unwrap();
                match milli.pack(a.compute_milli) {
                    Some(d) if frac => vec![crate::device::DeviceId(d)],
                    _ => pack_group(&load, tp),
                }
            };
            let model = inner.artifacts.model(&inner.graph.stage(si).model)?;
            let ord = st.next_ord;
            let label = format!("{stage_name}#r{ord}");
            let Ok(reservations) =
                inner.pool.reserve_tp(&group, model.weight_bytes(), &label)
            else {
                // Device memory is the second admission gate; try again
                // once a drain frees capacity.
                continue;
            };
            commit_group(&mut inner.dev_load.lock().unwrap(), &group);
            {
                let mut m = inner.dev_milli.lock().unwrap();
                for g in &group {
                    m.commit(g.0, a.compute_milli);
                }
            }
            let reservation_copy = reservations.clone();
            // Size-1 barrier: the replica thread's readiness rendezvous
            // returns immediately, so the control loop never holds the
            // stages lock across engine construction (stats/shutdown stay
            // responsive); the cooldown covers the build latency.
            let ready = Arc::new(Barrier::new(1));
            match spawn_replica(inner, si, ord, group.clone(), reservations, &ready) {
                Ok(h) => {
                    st.next_ord += 1;
                    st.replicas.push(h);
                    st.last_scale_t = now;
                    milli_used += need;
                    inner.recorder.emit(Event::Scale {
                        stage: stage_name,
                        t: now,
                        from: n_live,
                        to: n_live + 1,
                    });
                }
                Err(e) => {
                    for res in &reservation_copy {
                        inner.pool.release(res);
                    }
                    release_group(&mut inner.dev_load.lock().unwrap(), &group);
                    let mut m = inner.dev_milli.lock().unwrap();
                    for g in &group {
                        m.release(g.0, a.compute_milli);
                    }
                    eprintln!("autoscaler: spawning `{label}` failed: {e:#}");
                }
            }
        }
    }
    Ok(())
}
