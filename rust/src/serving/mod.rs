//! Persistent online serving runtime (paper §3: the system is a
//! *serving* system — continuous request arrivals, per-stage batching,
//! and flexible GPU allocation that follows the bottleneck).
//!
//! A [`ServingSession`] spawns the stage graph **once** and stays up:
//!
//! ```text
//!      submit_request() ──► entry replicas ──► ... stage graph ... ──► exit replicas
//!                │   ▲                                                     │
//!     ResponseStream │ front senders                                  sink channel
//!                │   │                                                     │
//!                ▼   │                                                     ▼
//!              caller└──────────────── collector thread ◄──────────────────┘
//!                         (typed OutputDeltas per exit item,
//!                          deadline expiry, stream teardown)
//!
//!              autoscaler thread ──► EdgeCtl add/drain/remove ──► replica spawn/retire
//!                     ▲                                                  │
//!                     └──────── ReplicaSlot load publications ◄──────────┘
//! ```
//!
//! * Requests are typed [`OmniRequest`]s submitted continuously through
//!   [`ServingSession::submit_request`]; each returns a
//!   [`ResponseStream`] that yields [`OutputDelta`]s mid-flight — the
//!   collector thread taps every item leaving an exit stage (text
//!   tokens, waveform chunks, image frames) instead of waiting for the
//!   final one — and always ends with `Done`.
//! * Requests are cancellable end-to-end ([`ResponseStream::cancel`],
//!   deadline expiry, [`ServingSession::cancel`]): a per-request
//!   tombstone ([`Tombstones`]) propagates through the router and every
//!   stage scheduler/engine (see [`cancel`]).
//! * The pre-streaming submit-and-block API survives as a shim:
//!   [`ServingSession::submit`] returns a deprecated [`CompletionHandle`]
//!   wrapping the stream.
//! * The optional [`autoscaler`] control loop samples every replica's
//!   published scheduler load and scales stage replicas up/down at
//!   runtime — wiring new replicas into the routed edges
//!   ([`crate::connector::router::EdgeCtl`]), packing their devices
//!   incrementally ([`crate::scheduler::allocator::pack_group`]), and
//!   retiring drained replicas without dropping in-flight requests.
//! * [`ServingSession::shutdown`] stops the control loop, joins every
//!   replica thread (in-flight work finishes first), and reports the
//!   whole session as a [`RunSummary`].
//!
//! The one-shot [`crate::orchestrator::Orchestrator::run_workload`] is a
//! thin wrapper over this runtime, and the TCP frontend
//! ([`crate::server`]) shares one session across connections.

pub mod admission;
pub mod autoscaler;
pub mod cancel;
pub mod request;
pub mod stream;

pub use admission::{AdmissionController, AdmissionStats};
pub use cancel::Tombstones;
pub use request::{OmniRequest, Priority};
pub use stream::{
    Completion, CompletionHandle, OutputDelta, ResponseStream, StreamRecv, Usage, WaitResult,
};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Barrier, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::{
    AdmissionConfig, AutoscalerConfig, CacheConfig, ConnectorKind, DriverKind, PipelineConfig,
    RoutingKind, RuntimeConfig,
};
use crate::connector::router::EdgeCtl;
use crate::connector::tcp::MooncakeStore;
use crate::device::{DeviceId, DevicePool, Reservation};
use crate::engine::StageItem;
use crate::event_core::{
    drive, EventLog, RealDriver, SimEvent, Tick, WakeSet, WAKE_CANCEL, WAKE_CTL, WAKE_FRONT,
};
use crate::metrics::{Event, Recorder};
use crate::orchestrator::{self, stage, Orchestrator, RunClock, RunOptions, RunSummary, StageSummary};
use crate::runtime::Artifacts;
use crate::scheduler::AllocationPlan;
use crate::stage_graph::transfers::{Registry, ReqMeta, ReqTable};
use crate::stage_graph::StageGraph;
use crate::trace::Request;

/// Live load one engine replica publishes every stage-loop iteration,
/// read by the autoscaler (and the drain-before-retire check).
#[derive(Debug, Default)]
pub struct ReplicaSlot {
    queued: AtomicUsize,
    busy: AtomicBool,
    /// Live cross-request cache counters ([`CacheCounters`] unpacked
    /// into relaxed atomics), published by the stage loop and read by
    /// the `stats` server op.  Monotone totals, so torn multi-field
    /// reads only ever lag, never lie.
    prefix_hits: AtomicU64,
    prefix_misses: AtomicU64,
    evictions: AtomicU64,
    encoder_hits: AtomicU64,
    encoder_misses: AtomicU64,
}

impl ReplicaSlot {
    pub fn publish(&self, queued: usize, busy: bool) {
        self.queued.store(queued, Ordering::Relaxed);
        self.busy.store(busy, Ordering::Relaxed);
    }

    pub fn queued(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    pub fn busy(&self) -> bool {
        self.busy.load(Ordering::Relaxed)
    }

    pub fn publish_cache(&self, c: &crate::metrics::CacheCounters) {
        self.prefix_hits.store(c.prefix_hits, Ordering::Relaxed);
        self.prefix_misses.store(c.prefix_misses, Ordering::Relaxed);
        self.evictions.store(c.evictions, Ordering::Relaxed);
        self.encoder_hits.store(c.encoder_hits, Ordering::Relaxed);
        self.encoder_misses.store(c.encoder_misses, Ordering::Relaxed);
    }

    pub fn cache(&self) -> crate::metrics::CacheCounters {
        crate::metrics::CacheCounters {
            prefix_hits: self.prefix_hits.load(Ordering::Relaxed),
            prefix_misses: self.prefix_misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            encoder_hits: self.encoder_hits.load(Ordering::Relaxed),
            encoder_misses: self.encoder_misses.load(Ordering::Relaxed),
        }
    }
}

/// Session start options.
#[derive(Debug, Clone, Default)]
pub struct SessionOptions {
    /// Elastic autoscaling; `None` keeps replica counts frozen at the
    /// allocation plan (the pre-serving-runtime behaviour).
    pub autoscaler: Option<AutoscalerConfig>,
    /// SLO-aware admission control + shedding (see [`admission`]);
    /// `None` admits everything (deadlines still cancel late).
    pub admission: Option<AdmissionConfig>,
    /// Prefix / encoder caching knobs for every stage engine; `None`
    /// falls back to the pipeline config's `cache` block, then to the
    /// defaults (both caches on).
    pub cache: Option<CacheConfig>,
    /// Event-core runtime knobs (driver kind, replay recording); `None`
    /// falls back to the pipeline config's `runtime` block, then to the
    /// defaults (real driver, no recording).
    pub runtime: Option<RuntimeConfig>,
}

impl SessionOptions {
    /// Honor the pipeline config's `autoscaler`/`admission`/`cache`/
    /// `runtime` blocks, if present.
    pub fn from_config(config: &PipelineConfig) -> Self {
        Self {
            autoscaler: config.autoscaler.clone(),
            admission: config.admission.clone(),
            cache: config.cache.clone(),
            runtime: config.runtime.clone(),
        }
    }
}

/// One live (or draining) engine replica of a stage.
pub(crate) struct ReplicaHandle {
    pub(crate) uid: u64,
    /// Display replica number (monotonic per stage, never reused).
    pub(crate) ord: usize,
    pub(crate) join: JoinHandle<Result<StageSummary>>,
    pub(crate) retire: Arc<AtomicBool>,
    /// The replica thread's wake mailbox: retire/drain commands and
    /// cancel tombstones interrupt a parked worker through it.
    pub(crate) wake: Arc<WakeSet>,
    pub(crate) slot: Arc<ReplicaSlot>,
    pub(crate) devices: Vec<DeviceId>,
    pub(crate) reservations: Vec<Reservation>,
    /// `(edge index, consumer uid)` for each incoming routed edge.
    pub(crate) in_edges: Vec<(usize, u64)>,
    /// `(edge index, producer uid)` for each outgoing routed edge.
    pub(crate) out_edges: Vec<(usize, u64)>,
    /// Entry replicas only: uid of the front sender registered for it.
    pub(crate) front_uid: Option<u64>,
    /// Time-slice slot on the replica's (single) device, when the
    /// session runs with fractional sharing — read for slice counters.
    pub(crate) share: Option<(Arc<crate::gpu_share::TimeSlice>, crate::gpu_share::SlotId)>,
    pub(crate) draining: bool,
}

pub(crate) struct StageState {
    pub(crate) replicas: Vec<ReplicaHandle>,
    pub(crate) next_ord: usize,
    pub(crate) last_scale_t: f64,
}

pub(crate) struct FrontTx {
    pub(crate) uid: u64,
    pub(crate) tx: mpsc::Sender<Request>,
    /// The entry replica's wake mailbox, signalled after every front
    /// send so a parked entry worker picks the request up immediately.
    pub(crate) wake: Arc<WakeSet>,
}

/// Collector-side state of one in-flight request's delta stream.
pub(crate) struct ReqStream {
    pub(crate) tx: mpsc::Sender<OutputDelta>,
    /// Deliver mid-flight deltas (off = only the terminal `Done`; the
    /// payload is never materialized, keeping submit-and-block callers
    /// as cheap as before the streaming API existed).
    pub(crate) stream: bool,
    /// Request asked for audio output (types the DiT vocoder's
    /// latent+wave items; see [`stream::classify_item`]).
    pub(crate) audio: bool,
    pub(crate) submitted_t: f64,
    pub(crate) usage: Usage,
    /// Exit stages that have not yet delivered their final item for this
    /// request.  Branching fan-out graphs have several exits; the
    /// terminal `Done` resolves only when the LAST branch finishes
    /// (single-exit graphs start at 1, preserving the old semantics).
    pub(crate) exits_left: usize,
}

/// Shared interior of a session (stage threads, the collector, the
/// autoscaler, and API callers all hold it through an `Arc`).
pub(crate) struct SessionInner {
    pub(crate) graph: StageGraph,
    pub(crate) plan: AllocationPlan,
    pub(crate) artifacts: Arc<Artifacts>,
    pub(crate) registry: Registry,
    pub(crate) opts: RunOptions,
    pub(crate) clock: RunClock,
    pub(crate) recorder: Arc<Recorder>,
    pub(crate) reqs: ReqTable,
    pub(crate) stop: Arc<AtomicBool>,
    pub(crate) failed: Arc<AtomicBool>,
    pub(crate) inflight: AtomicUsize,
    /// One control handle per config edge (same order as
    /// `graph.config.edges`).
    pub(crate) edges: Vec<Arc<EdgeCtl>>,
    /// Resolved routing per config edge (parallel to `edges`).
    pub(crate) edge_routing: Vec<RoutingKind>,
    pub(crate) stages: Mutex<Vec<StageState>>,
    /// Entry-stage request senders + rotation cursor.
    pub(crate) front: Mutex<(Vec<FrontTx>, usize)>,
    /// Per-request delta streams.  Doubles as the dedup set AND the
    /// memory bound of a long-lived session: claiming a request's entry
    /// is what resolves it (exactly once), and its metadata is evicted
    /// right there — a session serving requests for days holds state
    /// only for what is in flight.
    pub(crate) streams: Mutex<HashMap<u64, ReqStream>>,
    /// Cancelled-request tombstones swept by every stage thread.
    pub(crate) cancels: Arc<Tombstones>,
    /// SLO-aware overload control (submit-time rejection + the
    /// collector's shed sweep); `None` admits everything.
    pub(crate) admission: Option<AdmissionController>,
    /// Resolved caching knobs every spawned replica inherits.
    pub(crate) cache: CacheConfig,
    /// `(expiry_t, req_id)` deadlines enforced by the collector tick.
    pub(crate) deadlines: Mutex<Vec<(f64, u64)>>,
    /// Kept for cloning into dynamically spawned exit replicas; dropped
    /// at shutdown so the collector sees the channel close.
    pub(crate) sink_tx: Mutex<Option<mpsc::Sender<StageItem>>>,
    /// The collector thread's wake mailbox: exit replicas signal it
    /// after every sink send (and shutdown signals the close), so the
    /// collector parks instead of polling `recv_timeout`.
    pub(crate) collector_wake: Arc<WakeSet>,
    /// Replay recording (`RuntimeConfig::replay_record`): accepted
    /// request arrivals tee into this log, written to `replay_path` at
    /// shutdown for `omni-serve replay`.
    pub(crate) replay_log: Mutex<Option<EventLog>>,
    pub(crate) replay_path: Option<String>,
    pub(crate) pool: DevicePool,
    pub(crate) dev_load: Mutex<Vec<usize>>,
    /// Per-device carved-compute ledger (milli-GPUs), seeded from the
    /// plan; the autoscaler packs fractional replicas through it.
    pub(crate) dev_milli: Mutex<crate::gpu_share::MilliLedger>,
    /// Per-device time-slice schedulers — one per device when the
    /// pipeline has a `share` block, empty otherwise (whole-GPU, no
    /// slicing).  Single-device replicas register a slot weighted by
    /// their `compute_milli` and wrap every engine step in a grant.
    pub(crate) shares: Vec<Arc<crate::gpu_share::TimeSlice>>,
    pub(crate) next_uid: AtomicU64,
    /// Summaries of replicas retired mid-run.
    pub(crate) retired: Mutex<Vec<StageSummary>>,
    /// First error surfaced by a replica joined mid-run (reported at
    /// shutdown, like errors from replicas joined there).
    pub(crate) first_error: Mutex<Option<anyhow::Error>>,
    pub(crate) store_addr: Option<String>,
    _store: Option<MooncakeStore>,
}

impl SessionInner {
    pub(crate) fn record_error(&self, e: anyhow::Error) {
        let mut slot = self.first_error.lock().unwrap();
        if slot.is_none() {
            *slot = Some(e);
        }
    }

    fn dec_inflight(&self) {
        let _ = self
            .inflight
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| Some(v.saturating_sub(1)));
    }

    /// Wake every replica thread's mailbox.  Cancel tombstones and
    /// control transitions must interrupt a parked worker, not wait for
    /// its liveness backstop.  Poison-tolerant: also called from `Drop`.
    pub(crate) fn wake_replicas(&self, mask: u64) {
        if let Ok(stages) = self.stages.lock() {
            for st in stages.iter() {
                for r in &st.replicas {
                    r.wake.wake(mask);
                }
            }
        }
    }

    /// Cancel one in-flight request end-to-end.  Returns false when the
    /// request already resolved (completed or cancelled earlier).
    pub(crate) fn cancel_request(&self, req_id: u64) -> bool {
        // Claiming the stream entry is the exactly-once gate, same as
        // completion.
        let Some(st) = self.streams.lock().unwrap().remove(&req_id) else { return false };
        let t = self.clock.now();
        // Tombstone FIRST: anything of this request still flowing is
        // dropped at the next stage-thread sweep/pull.
        self.cancels.mark(req_id, t);
        self.reqs.lock().unwrap().remove(&req_id);
        self.deadlines.lock().unwrap().retain(|&(_, r)| r != req_id);
        // The affinity entry would otherwise outlive the request (its
        // finished item never flows), pinning draining replicas forever.
        for e in &self.edges {
            e.purge_request(req_id);
        }
        if let Some(a) = &self.admission {
            a.resolve(req_id, None);
        }
        // Parked workers sweep tombstones on their next tick — get them
        // there now so queued work of this request dies immediately.
        self.wake_replicas(WAKE_CANCEL);
        self.recorder.emit(Event::Cancelled { req: req_id, t });
        self.dec_inflight();
        let _ = st.tx.send(OutputDelta::Done {
            t,
            jct_s: t - st.submitted_t,
            cancelled: true,
            usage: st.usage,
        });
        true
    }

    /// Shed one *queued* request (the collector's overload sweep).
    /// Claims the stream entry through the same exactly-once gate as
    /// cancellation and completion, so a request shed concurrently with
    /// a deadline expiry or client cancel still resolves with exactly
    /// one terminal event — here a structured `Rejected`, never `Done`.
    pub(crate) fn shed_request(&self, req_id: u64, reason: String, retry_after_s: f64) -> bool {
        let Some(st) = self.streams.lock().unwrap().remove(&req_id) else { return false };
        let t = self.clock.now();
        // Tombstone FIRST: the request may sit in the front channel or a
        // stage's admission queue — it dies at the next pull/sweep and
        // never reaches an engine.
        self.cancels.mark(req_id, t);
        self.reqs.lock().unwrap().remove(&req_id);
        self.deadlines.lock().unwrap().retain(|&(_, r)| r != req_id);
        for e in &self.edges {
            e.purge_request(req_id);
        }
        self.wake_replicas(WAKE_CANCEL);
        self.recorder.emit(Event::Rejected { req: req_id, t });
        self.dec_inflight();
        let _ = st.tx.send(OutputDelta::Rejected { t, reason, retry_after_s });
        true
    }

    /// Stage-loop hook: a stage finished producing for a request —
    /// forward a `StageDone` marker to its (streaming) delta channel.
    /// On a branching graph (several exit stages), an exit's finish also
    /// emits `BranchDone`, so clients see each branch land while the
    /// terminal `Done` waits for the rest.
    pub(crate) fn stage_done_delta(&self, req: u64, stage: &'static str, t: f64) {
        let branch_exit = self.graph.exits.len() > 1
            && self.graph.exits.iter().any(|&i| self.graph.stage(i).name == stage);
        let streams = self.streams.lock().unwrap();
        if let Some(st) = streams.get(&req) {
            if st.stream {
                let _ = st.tx.send(OutputDelta::StageDone { stage, t });
                if branch_exit {
                    let _ = st.tx.send(OutputDelta::BranchDone { branch: stage, t });
                }
            }
        }
    }

    /// Collector: type one exit-stage item into deltas, stream them, and
    /// resolve the request on its final item.  (Post-completion
    /// straggler items — e.g. a Thinker still draining its final chunks
    /// after the exit stage hit its audio budget — find no entry and are
    /// dropped, matching the one-shot runner's behaviour.)
    fn collect_item(&self, item: StageItem) {
        if self.cancels.contains(item.req_id) {
            return; // late item of a cancelled request
        }
        let t = self.clock.now();
        let mut streams = self.streams.lock().unwrap();
        let Some(st) = streams.get_mut(&item.req_id) else { return };
        // Accounting (usage counters + the client-boundary Event::Delta
        // feeding TPOT) works from sizes only; the payload tensors are
        // copied into a typed delta ONLY for streaming requests, so the
        // submit-and-block path never materializes a waveform.
        let payload = stream::classify_item(&item, st.audio);
        if payload != stream::Payload::None {
            st.usage.absorb(&payload);
            self.recorder.emit(Event::Delta { req: item.req_id, t });
            if st.stream {
                if let Some(d) = stream::delta_for_payload(payload, &item, t) {
                    let _ = st.tx.send(d);
                }
            }
        }
        if item.finished {
            // One branch exit delivered its last item; the request
            // resolves only when every exit has.
            st.exits_left = st.exits_left.saturating_sub(1);
            if st.exits_left > 0 {
                return;
            }
            let st = streams.remove(&item.req_id).expect("entry held above");
            drop(streams);
            if let Some(a) = &self.admission {
                // The observed JCT recalibrates the cost projections.
                a.resolve(item.req_id, Some(t - st.submitted_t));
            }
            self.recorder.emit(Event::Completed { req: item.req_id, t });
            self.reqs.lock().unwrap().remove(&item.req_id);
            self.deadlines.lock().unwrap().retain(|&(_, r)| r != item.req_id);
            self.dec_inflight();
            let _ = st.tx.send(OutputDelta::Done {
                t,
                jct_s: t - st.submitted_t,
                cancelled: false,
                usage: st.usage,
            });
        }
    }

    /// Collector housekeeping, run between sink receives: deadline
    /// expiry, failure teardown, tombstone GC.
    fn collector_tick(&self) {
        let now = self.clock.now();
        // Pop expired entries unconditionally: a deadline whose request
        // already resolved (cancel_request returns false) must still
        // leave the list, or it would be re-collected on every tick.
        let expired: Vec<u64> = {
            let mut d = self.deadlines.lock().unwrap();
            let mut ex = Vec::new();
            d.retain(|&(t, r)| {
                if now >= t {
                    ex.push(r);
                    false
                } else {
                    true
                }
            });
            ex
        };
        for r in expired {
            self.cancel_request(r);
        }
        // Emergency shedding: while the not-yet-started backlog projects
        // past the horizon, drop queued requests earliest-deadline-first.
        // In-flight work is immune twice over: the controller skips
        // entries a stage reported started, and a race lost to a
        // just-now admission is caught by the re-check before the claim.
        if let Some(ctrl) = &self.admission {
            let lanes = self.front.lock().unwrap().0.len().max(1);
            let horizon = ctrl.shed_horizon_s();
            for id in ctrl.shed(lanes, |r| self.recorder.started(r)) {
                if self.recorder.started(id) {
                    continue; // admitted between snapshot and claim
                }
                self.shed_request(
                    id,
                    format!(
                        "shed under overload: projected backlog exceeds the \
                         {horizon:.3}s horizon"
                    ),
                    ctrl.retry_after_s(),
                );
            }
        }
        // A failed pipeline can never deliver more deltas: close every
        // live stream so blocked callers wake with `Closed` instead of
        // polling the failure flag, and retire the requests' bookkeeping
        // (they will never resolve, so they must not count as in-flight
        // or keep metadata/deadlines alive).
        if self.failed.load(Ordering::SeqCst) {
            let dead: Vec<u64> = {
                let mut s = self.streams.lock().unwrap();
                let ids = s.keys().copied().collect();
                s.clear();
                ids
            };
            if !dead.is_empty() {
                let mut reqs = self.reqs.lock().unwrap();
                for id in &dead {
                    reqs.remove(id);
                }
                drop(reqs);
                self.deadlines.lock().unwrap().clear();
                for _ in &dead {
                    self.dec_inflight();
                }
            }
        }
        self.cancels.purge_older(now, cancel::TOMBSTONE_TTL_S);
    }
}

/// Live per-stage snapshot for the `stats` server op.
#[derive(Debug, Clone)]
pub struct StageLiveStats {
    pub stage: String,
    /// Live (non-draining) engine replicas.
    pub replicas: usize,
    pub draining: usize,
    /// Σ published admission-queue depths across live replicas.
    pub queued: usize,
    /// Live replicas whose engine is mid-work.
    pub busy: usize,
    /// Cross-request cache counters summed across live replicas (zeros
    /// for stages that cache nothing).
    pub cache: crate::metrics::CacheCounters,
    /// Time-slice counters summed across live replicas (zeros when the
    /// session runs without fractional sharing).
    pub slice: crate::gpu_share::SliceCounters,
    /// Event-core wake counters summed across live replicas: parks that
    /// ended with an event pending...
    pub wakeups: u64,
    /// ...parks that ended empty (timeout / liveness backstop — a hot
    /// value means a missing wake hook)...
    pub spurious_wakeups: u64,
    /// ...and total parked time, in milliseconds.
    pub idle_ms: f64,
}

/// A persistent serving runtime over one pipeline.
pub struct ServingSession {
    inner: Arc<SessionInner>,
    collector: Mutex<Option<JoinHandle<()>>>,
    autoscaler: Mutex<Option<JoinHandle<()>>>,
    shut: Mutex<bool>,
}

impl ServingSession {
    /// Spawn the stage graph and stay up.  Blocks until every initial
    /// engine replica is constructed (compilation excluded from request
    /// timing by the clock reset), then starts the collector and — when
    /// configured — the autoscaler control loop.
    pub fn start(orch: &Orchestrator, opts: SessionOptions) -> Result<ServingSession> {
        let graph = orch.graph.clone();
        let plan = orch.plan.clone();
        let run_opts = orch.opts.clone();

        // Spawn a Mooncake store if any edge wants TCP.
        let needs_tcp =
            graph.config.edges.iter().any(|e| e.connector == ConnectorKind::Tcp);
        let mut store = None;
        let store_addr: Option<String> = if needs_tcp {
            match &run_opts.store_addr {
                Some(a) => Some(a.clone()),
                None => {
                    let s = MooncakeStore::spawn("127.0.0.1:0")?;
                    let a = s.addr().to_string();
                    store = Some(s);
                    Some(a)
                }
            }
        } else {
            None
        };

        // One mutable-endpoint EdgeCtl per config edge.  Auto routing
        // resolves to affinity: identical to pass-through at one replica,
        // and the only stateful-safe policy once the autoscaler (or a
        // `replicas` setting) replicates the consumer.
        let mut edges = Vec::with_capacity(graph.config.edges.len());
        let mut edge_routing = Vec::with_capacity(graph.config.edges.len());
        for e in &graph.config.edges {
            let routing = match e.routing {
                RoutingKind::Auto => RoutingKind::Affinity,
                explicit => explicit,
            };
            edges.push(Arc::new(
                EdgeCtl::new(
                    e.connector,
                    routing,
                    &format!("{}2{}", e.from, e.to),
                    store_addr.as_deref(),
                )
                .with_transport(&graph.config.transport),
            ));
            edge_routing.push(routing);
        }

        let admission = match &opts.admission {
            Some(cfg) => Some(AdmissionController::new(cfg.clone())?),
            None => None,
        };
        // Session options win over the pipeline's `cache` block; both
        // absent means the defaults (prefix + encoder caches on).
        let cache = opts
            .cache
            .clone()
            .or_else(|| graph.config.cache.clone())
            .unwrap_or_default();
        // Runtime block: session options win over the pipeline config.
        // A live session only runs under the real driver — the sim
        // driver belongs to `scheduler::sim`, which shares the same
        // stage-loop body through `event_core::drive`.
        let runtime = opts
            .runtime
            .clone()
            .or_else(|| graph.config.runtime.clone())
            .unwrap_or_default();
        runtime.validate()?;
        anyhow::ensure!(
            runtime.driver == DriverKind::Real,
            "serving sessions require `driver = real` (the sim driver is scheduler-only)"
        );
        let entry_lanes = plan.assignment(graph.entry).replicas as u32;

        let (sink_tx, sink_rx) = mpsc::channel::<StageItem>();
        let pool = DevicePool::new(graph.config.n_devices, graph.config.device_bytes);
        let dev_load = plan.device_load(graph.config.n_devices);
        let dev_milli = plan.device_milli(graph.config.n_devices);
        let shares: Vec<Arc<crate::gpu_share::TimeSlice>> = match &graph.config.share {
            Some(sh) => (0..graph.config.n_devices)
                .map(|_| Arc::new(crate::gpu_share::TimeSlice::new(sh.quantum_ms)))
                .collect(),
            None => Vec::new(),
        };
        let inner = Arc::new(SessionInner {
            graph,
            plan,
            artifacts: orch.artifacts.clone(),
            registry: orch.registry.clone(),
            opts: run_opts,
            clock: RunClock::new(),
            recorder: Arc::new(Recorder::new()),
            reqs: Arc::new(Mutex::new(Default::default())),
            stop: Arc::new(AtomicBool::new(false)),
            failed: Arc::new(AtomicBool::new(false)),
            inflight: AtomicUsize::new(0),
            edges,
            edge_routing,
            stages: Mutex::new(Vec::new()),
            front: Mutex::new((Vec::new(), 0)),
            streams: Mutex::new(HashMap::new()),
            cancels: Arc::new(Tombstones::new()),
            admission,
            cache,
            deadlines: Mutex::new(Vec::new()),
            sink_tx: Mutex::new(Some(sink_tx)),
            collector_wake: Arc::new(WakeSet::new()),
            replay_log: Mutex::new(if runtime.replay_record {
                Some(EventLog { seed: 0, lanes: entry_lanes, events: Vec::new() })
            } else {
                None
            }),
            replay_path: runtime.replay_record.then(|| runtime.replay_path.clone()),
            pool,
            dev_load: Mutex::new(dev_load),
            dev_milli: Mutex::new(dev_milli),
            shares,
            next_uid: AtomicU64::new(0),
            retired: Mutex::new(Vec::new()),
            first_error: Mutex::new(None),
            store_addr,
            _store: store,
        });

        // Reserve weight memory for every initial replica BEFORE any
        // thread spawns, so an over-replicated pipeline fails cleanly
        // instead of stranding threads on the readiness barrier.
        type Placement = (Vec<DeviceId>, Vec<Reservation>);
        let n_stages = inner.graph.n_stages();
        let mut placements: Vec<Vec<Placement>> = Vec::new();
        for i in 0..n_stages {
            let a = inner.plan.assignment(i);
            let s = inner.graph.stage(i);
            let model = inner.artifacts.model(&s.model)?;
            let mut per_stage = Vec::with_capacity(a.replicas);
            for (r, group) in a.replica_devices.iter().enumerate() {
                let label =
                    if r == 0 { s.name.clone() } else { format!("{}#r{r}", s.name) };
                let rs = inner
                    .pool
                    .reserve_tp(group, model.weight_bytes(), &label)
                    .with_context(|| format!("placing pipeline `{}`", inner.graph.config.name))?;
                per_stage.push((group.clone(), rs));
            }
            placements.push(per_stage);
        }

        // Spawn all initial replicas against one shared barrier so their
        // engine builds overlap; rendezvous, then zero the clock.
        let total: usize = placements.iter().map(|p| p.len()).sum();
        let ready = Arc::new(Barrier::new(total + 1));
        {
            let mut states = Vec::with_capacity(n_stages);
            for (i, per_stage) in placements.into_iter().enumerate() {
                let mut st = StageState { replicas: Vec::new(), next_ord: 0, last_scale_t: 0.0 };
                for (group, reservations) in per_stage {
                    let h = spawn_replica(&inner, i, st.next_ord, group, reservations, &ready)?;
                    st.next_ord += 1;
                    st.replicas.push(h);
                }
                states.push(st);
            }
            *inner.stages.lock().unwrap() = states;
        }
        ready.wait();
        inner.clock.reset();

        // Collector: types every exit-stage item into OutputDeltas,
        // resolves streams, enforces deadlines, and tears streams down
        // on failure/shutdown (see SessionInner::collect_item/
        // collector_tick).
        let collector = {
            let inner = inner.clone();
            std::thread::Builder::new().name("serving-collector".into()).spawn(move || {
                // Parked on the session's collector mailbox: exit
                // replicas signal every sink send and shutdown signals
                // the close, so the thread sleeps at zero CPU between
                // items.  The 50ms idle deadline keeps housekeeping
                // (deadline expiry, shed sweeps, failure teardown) on a
                // clock, matching the old `recv_timeout` cadence.
                let wake = inner.collector_wake.clone();
                let mut real = RealDriver::new(inner.clock.clone());
                let _ = drive(&mut real, &wake, |drv| {
                    let mut closed = false;
                    loop {
                        match sink_rx.try_recv() {
                            Ok(item) => inner.collect_item(item),
                            Err(mpsc::TryRecvError::Empty) => break,
                            // Every sink sender is gone (all exit
                            // replicas joined and the session dropped
                            // its clone): flush and exit — exactly once.
                            Err(mpsc::TryRecvError::Disconnected) => {
                                closed = true;
                                break;
                            }
                        }
                    }
                    inner.collector_tick();
                    if closed {
                        return Ok(Tick::Exit);
                    }
                    Ok(Tick::Idle(Some(drv.now() + 0.05)))
                });
                // Session over: close every remaining stream so blocked
                // clients see `Closed` instead of hanging.
                inner.streams.lock().unwrap().clear();
            })?
        };

        let auto_handle = match opts.autoscaler {
            Some(cfg) => {
                cfg.validate()?;
                let inner = inner.clone();
                Some(
                    std::thread::Builder::new()
                        .name("serving-autoscaler".into())
                        .spawn(move || autoscaler::run(&inner, &cfg))?,
                )
            }
            None => None,
        };

        Ok(ServingSession {
            inner,
            collector: Mutex::new(Some(collector)),
            autoscaler: Mutex::new(auto_handle),
            shut: Mutex::new(false),
        })
    }

    /// Run-relative seconds on the session clock.
    pub fn now(&self) -> f64 {
        self.inner.clock.now()
    }

    /// Whether any stage replica has failed (the error surfaces at
    /// [`Self::shutdown`]).
    pub fn failed(&self) -> bool {
        self.inner.failed.load(Ordering::SeqCst)
    }

    /// Requests submitted and not yet resolved (completed or cancelled).
    pub fn inflight(&self) -> usize {
        self.inner.inflight.load(Ordering::SeqCst)
    }

    /// DEPRECATED submit-and-block path: wraps [`Self::submit_request`]
    /// with streaming off and returns the [`CompletionHandle`] shim.
    pub fn submit(&self, req: Request) -> Result<CompletionHandle> {
        Ok(CompletionHandle::from_stream(self.submit_request(OmniRequest::from(req))?))
    }

    /// Submit one typed request.  Registers its metadata (priority
    /// included), arms its deadline, emits the `Arrived` event, and
    /// injects it into an entry-stage replica (rotating across live
    /// replicas; a dead replica costs a retry, never a clone).  The
    /// returned [`ResponseStream`] yields typed deltas mid-flight when
    /// [`OmniRequest::streaming`] is on, and always ends with `Done`.
    pub fn submit_request(&self, oreq: OmniRequest) -> Result<ResponseStream> {
        anyhow::ensure!(
            !self.inner.stop.load(Ordering::SeqCst),
            "serving session is shutting down"
        );
        oreq.validate()?;
        let (req, stream_on, priority, deadline_s, tenant) = oreq.into_parts();
        let id = req.id;
        let now = self.inner.clock.now();
        let mut tenant_id = 0u32;
        if let Some(ctrl) = &self.inner.admission {
            tenant_id = ctrl.tenant_id(tenant.as_deref());
            let lanes = self.inner.front.lock().unwrap().0.len().max(1);
            if let admission::Decision::Reject { reason, retry_after_s } =
                ctrl.decide(&req, deadline_s, now, lanes)
            {
                // Early structured rejection: the request never touches
                // a stage.  It still counts as offered (Arrived) so
                // goodput sees the refused load, and the returned stream
                // carries exactly one terminal event — `Rejected`.
                self.inner
                    .recorder
                    .emit(Event::Arrived { req: id, t: now, deadline: deadline_s.map(|d| now + d) });
                self.inner.recorder.emit(Event::Rejected { req: id, t: now });
                let (tx, rx) = mpsc::channel();
                let _ = tx.send(OutputDelta::Rejected { t: now, reason, retry_after_s });
                return Ok(ResponseStream::new(id, now, rx, self.inner.clone()));
            }
        }
        self.inner.reqs.lock().unwrap().insert(
            id,
            ReqMeta {
                seed: req.seed,
                max_audio_tokens: req.max_audio_tokens,
                diffusion_steps: req.diffusion_steps,
                ignore_eos: req.ignore_eos,
                prompt_tokens: req.prompt_tokens.clone(),
                max_text_tokens: req.max_text_tokens,
                priority: priority.rank(),
                tenant: tenant_id,
            },
        );
        let (tx, rx) = mpsc::channel();
        self.inner.streams.lock().unwrap().insert(
            id,
            ReqStream {
                tx,
                stream: stream_on,
                audio: req.max_audio_tokens > 0,
                submitted_t: now,
                usage: Usage::default(),
                exits_left: self.inner.graph.exits.len().max(1),
            },
        );
        if let Some(d) = deadline_s {
            self.inner.deadlines.lock().unwrap().push((now + d, id));
        }
        self.inner.inflight.fetch_add(1, Ordering::SeqCst);
        self.inner
            .recorder
            .emit(Event::Arrived { req: id, t: now, deadline: deadline_s.map(|d| now + d) });
        // Replay recording: tee the accepted arrival (priced by the same
        // deterministic cost model the replay executor uses) into the
        // session's event log, written out at shutdown.
        if let Some(log) = self.inner.replay_log.lock().unwrap().as_mut() {
            log.events.push(SimEvent::Arrive {
                id,
                t_us: (now * 1e6).round() as u64,
                cost_us: crate::event_core::replay::price_request_us(
                    req.total_input_tokens(),
                    req.max_text_tokens,
                    req.max_audio_tokens,
                ),
            });
        }

        let mut front = self.inner.front.lock().unwrap();
        let (txs, next) = &mut *front;
        let mut pending = Some(req);
        while !txs.is_empty() {
            let i = *next % txs.len();
            match txs[i].tx.send(pending.take().expect("requeued on failure")) {
                Ok(()) => {
                    txs[i].wake.wake(WAKE_FRONT);
                    *next = (i + 1) % txs.len();
                    return Ok(ResponseStream::new(id, now, rx, self.inner.clone()));
                }
                Err(mpsc::SendError(bounced)) => {
                    // Dead entry replica: prune its sender and retry.
                    pending = Some(bounced);
                    txs.remove(i);
                }
            }
        }
        // No live entry replica: roll the registration back.
        drop(front);
        self.inner.reqs.lock().unwrap().remove(&id);
        self.inner.streams.lock().unwrap().remove(&id);
        self.inner.deadlines.lock().unwrap().retain(|&(_, r)| r != id);
        if let Some(a) = &self.inner.admission {
            a.resolve(id, None);
        }
        self.inner.dec_inflight();
        anyhow::bail!("no live entry-stage replica to accept request {id}")
    }

    /// Cancel an in-flight request by id (the server's `cancel` op; API
    /// callers usually go through [`ResponseStream::cancel`]).  Returns
    /// false when the request already resolved.
    pub fn cancel(&self, req_id: u64) -> bool {
        self.inner.cancel_request(req_id)
    }

    /// Block until every submitted request resolved, the session failed,
    /// or `timeout` elapsed.  Returns true when fully drained.
    pub fn drain(&self, timeout: Duration) -> bool {
        let t0 = std::time::Instant::now();
        loop {
            if self.inflight() == 0 {
                return true;
            }
            if self.failed() || t0.elapsed() >= timeout {
                return self.inflight() == 0;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Live per-stage replica counts and queue depths (the `stats` op).
    pub fn stage_stats(&self) -> Vec<StageLiveStats> {
        let stages = self.inner.stages.lock().unwrap();
        stages
            .iter()
            .enumerate()
            .map(|(i, st)| {
                let mut out = StageLiveStats {
                    stage: self.inner.graph.stage(i).name.clone(),
                    replicas: 0,
                    draining: 0,
                    queued: 0,
                    busy: 0,
                    cache: Default::default(),
                    slice: Default::default(),
                    wakeups: 0,
                    spurious_wakeups: 0,
                    idle_ms: 0.0,
                };
                for r in &st.replicas {
                    if r.draining {
                        out.draining += 1;
                        continue;
                    }
                    out.replicas += 1;
                    out.queued += r.slot.queued();
                    if r.slot.busy() {
                        out.busy += 1;
                    }
                    out.cache.absorb(&r.slot.cache());
                    let wc = r.wake.counters();
                    out.wakeups += wc.wakeups;
                    out.spurious_wakeups += wc.spurious_wakeups;
                    out.idle_ms += wc.idle_ns as f64 / 1e6;
                    if let Some((ts, id)) = &r.share {
                        let c = ts.counters(*id);
                        out.slice.grants += c.grants;
                        out.slice.preemptions += c.preemptions;
                        out.slice.held_s += c.held_s;
                        out.slice.waited_s += c.waited_s;
                    }
                }
                out
            })
            .collect()
    }

    /// Live overload-control counters (`None` when the session runs
    /// without an admission controller).
    pub fn admission_stats(&self) -> Option<AdmissionStats> {
        self.inner.admission.as_ref().map(|a| a.stats())
    }

    /// Live run metrics (goodput, JCT/TTFT/TPOT so far) without shutting
    /// the session down — the server's `stats` op reads goodput here.
    pub fn live_report(&self) -> crate::metrics::RunReport {
        self.record_edge_stats();
        self.inner.recorder.report(self.inner.clock.now(), None)
    }

    /// Live per-edge transfer counters (bytes, frames, p50/p95
    /// send→resolve latency) for every edge of the stage graph — the
    /// server's `stats` op reports these alongside goodput.
    pub fn edge_stats(&self) -> Vec<crate::connector::EdgeTransferSnapshot> {
        self.inner.edges.iter().map(|e| e.transfer_snapshot()).collect()
    }

    /// Push the current edge snapshots into the recorder (absolute
    /// counters — the latest emission per edge wins in the report).
    fn record_edge_stats(&self) {
        let t = self.inner.clock.now();
        for e in self.inner.edges.iter() {
            self.inner
                .recorder
                .emit(Event::EdgeStats { t, snapshot: e.transfer_snapshot() });
        }
    }

    /// Live replica count of one stage.
    pub fn replica_count(&self, stage: &str) -> usize {
        self.stage_stats()
            .iter()
            .find(|s| s.stage == stage)
            .map(|s| s.replicas)
            .unwrap_or(0)
    }

    /// Stop the control loop, let in-flight work finish, join every
    /// replica thread, and report the whole session.  Call
    /// [`Self::drain`] first when completions must all be in the report.
    pub fn shutdown(&self, audio_stage: Option<&str>) -> Result<RunSummary> {
        {
            let mut shut = self.shut.lock().unwrap();
            anyhow::ensure!(!*shut, "serving session already shut down");
            *shut = true;
        }
        // Autoscaler first, so no replica spawns during teardown.
        self.inner.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.autoscaler.lock().unwrap().take() {
            let _ = h.join();
        }
        // Close the frontend; entry replicas drain their channels.
        self.inner.front.lock().unwrap().0.clear();

        // Join every replica (live and draining).  Stage threads exit
        // once their engine and admission queue are empty, so in-flight
        // work finishes first.
        let states: Vec<StageState> =
            std::mem::take(&mut *self.inner.stages.lock().unwrap());
        let mut summaries: Vec<StageSummary> =
            std::mem::take(&mut *self.inner.retired.lock().unwrap());
        let mut first_err: Option<anyhow::Error> =
            self.inner.first_error.lock().unwrap().take();
        for st in states {
            for r in st.replicas {
                r.retire.store(true, Ordering::SeqCst);
                r.wake.wake(WAKE_CTL);
                match r.join.join() {
                    Ok(Ok(summary)) => summaries.push(summary),
                    Ok(Err(e)) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                    Err(_) => {
                        if first_err.is_none() {
                            first_err = Some(anyhow::anyhow!("stage thread panicked"));
                        }
                    }
                }
                for res in &r.reservations {
                    self.inner.pool.release(res);
                }
            }
        }
        // Drop the session's sink sender: with all replicas joined the
        // channel closes and the collector exits after draining it
        // (closing any stream still open).
        *self.inner.sink_tx.lock().unwrap() = None;
        self.inner.collector_wake.wake(WAKE_CTL);
        if let Some(h) = self.collector.lock().unwrap().take() {
            let _ = h.join();
        }
        // Persist the recorded replay log, if the session kept one.
        if let (Some(path), Some(log)) = (
            self.inner.replay_path.as_ref(),
            self.inner.replay_log.lock().unwrap().take(),
        ) {
            std::fs::write(path, log.encode())
                .with_context(|| format!("writing replay log to {path}"))?;
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        // (stage index, ord) order, matching the pre-serving summaries.
        summaries.sort_by_key(|s| {
            (self.inner.graph.stage_index(&s.name).unwrap_or(usize::MAX), s.replica)
        });
        self.record_edge_stats();
        let wall = self.inner.clock.now();
        let report = self.inner.recorder.report(wall, audio_stage);
        Ok(RunSummary { report, stages: summaries, wall_s: wall })
    }
}

impl Drop for ServingSession {
    fn drop(&mut self) {
        // A session dropped without shutdown still signals its threads to
        // exit (they are not joined here — never panic in drop).
        self.inner.stop.store(true, Ordering::SeqCst);
        self.inner.front.lock().unwrap().0.clear();
        *self.inner.sink_tx.lock().unwrap() = None;
        self.inner.wake_replicas(WAKE_CTL);
        self.inner.collector_wake.wake(WAKE_CTL);
    }
}

/// Spawn one engine replica of stage `stage_idx`: wire it into every
/// routed edge touching the stage, register its front sender (entry
/// stages), and start its thread.  `ready` is the construction barrier:
/// the session start passes one sized for all initial replicas + itself;
/// dynamic scale-ups pass a size-1 barrier (no rendezvous — the replica
/// simply starts serving when its engine is built).
pub(crate) fn spawn_replica(
    inner: &Arc<SessionInner>,
    stage_idx: usize,
    ord: usize,
    devices: Vec<DeviceId>,
    reservations: Vec<Reservation>,
    ready: &Arc<Barrier>,
) -> Result<ReplicaHandle> {
    let graph = &inner.graph;
    let cfg = graph.stage(stage_idx).clone();
    let uid = inner.next_uid.fetch_add(1, Ordering::Relaxed);

    let mut rxs = Vec::new();
    let mut in_edges = Vec::new();
    let mut txs = Vec::new();
    let mut out_edges = Vec::new();
    for (ei, e) in graph.config.edges.iter().enumerate() {
        if e.to == cfg.name {
            let (rx, cuid) = inner.edges[ei].add_consumer()?;
            rxs.push((rx, e.transfer.clone()));
            in_edges.push((ei, cuid));
        }
        if e.from == cfg.name {
            let (tx, puid) = inner.edges[ei].add_producer()?;
            txs.push(tx);
            out_edges.push((ei, puid));
        }
    }

    let (front_tx, front_rx) = if stage_idx == graph.entry {
        let (t, r) = mpsc::channel::<Request>();
        (Some(t), Some(r))
    } else {
        (None, None)
    };
    let sink = if graph.exits.contains(&stage_idx) {
        inner.sink_tx.lock().unwrap().clone()
    } else {
        None
    };

    let retire = Arc::new(AtomicBool::new(false));
    let wake = Arc::new(WakeSet::new());
    // Exit stages signal the collector's mailbox after every sink send.
    let sink_wake = sink.as_ref().map(|_| inner.collector_wake.clone());
    let slot = Arc::new(ReplicaSlot::default());
    // Fractional sharing: a single-device replica registers a slot on
    // its device's time-slice scheduler, weighted by its compute share
    // (whole-device residents weigh 1000 — the WRR is work-conserving,
    // so a lone slot never waits).  TP replicas span devices and are
    // not sliced.
    let share = match devices.as_slice() {
        [d] => inner.shares.get(d.0).map(|ts| {
            (ts.clone(), ts.add_slot(inner.plan.assignment(stage_idx).compute_milli))
        }),
        _ => None,
    };
    // Stage-done deltas flow through a hook so the stage loop stays
    // decoupled from the session internals.
    let on_stage_done: stage::StageDoneHook = {
        let inner = inner.clone();
        Arc::new(move |req, stage_name, t| inner.stage_done_delta(req, stage_name, t))
    };
    let spec = stage::StageSpec {
        index: stage_idx,
        replica: ord,
        cfg,
        assignment: inner.plan.assignment(stage_idx).clone(),
        artifacts: inner.artifacts.clone(),
        rxs,
        txs,
        registry: inner.registry.clone(),
        reqs: inner.reqs.clone(),
        recorder: inner.recorder.clone(),
        clock: inner.clock.clone(),
        stop: inner.stop.clone(),
        retire: retire.clone(),
        slot: slot.clone(),
        failed: inner.failed.clone(),
        front_rx,
        sink,
        share: share.clone(),
        cancels: inner.cancels.clone(),
        tenant_weights: inner
            .admission
            .as_ref()
            .map(|a| a.tenant_weights())
            .unwrap_or_default(),
        on_stage_done: Some(on_stage_done),
        streaming: inner.opts.streaming,
        lazy_compile: inner.opts.lazy_compile,
        cache: inner.cache.clone(),
        device_bytes: inner.graph.config.device_bytes,
        downstream_hint: orchestrator::downstream_hint(graph, &inner.artifacts, stage_idx),
        ready: ready.clone(),
        wake: wake.clone(),
        sink_wake,
    };
    let join = stage::spawn(spec)?;
    let front_uid = front_tx.map(|t| {
        inner.front.lock().unwrap().0.push(FrontTx { uid, tx: t, wake: wake.clone() });
        uid
    });
    Ok(ReplicaHandle {
        uid,
        ord,
        join,
        retire,
        wake,
        slot,
        devices,
        reservations,
        in_edges,
        out_edges,
        front_uid,
        share,
        draining: false,
    })
}
