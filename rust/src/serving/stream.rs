//! The streaming response surface: typed [`OutputDelta`]s delivered
//! mid-flight over a per-request channel, plus the deprecated
//! [`CompletionHandle`] shim that preserves the old submit-and-block
//! contract on top of it.
//!
//! Deltas are produced by the session collector, which taps EVERY item
//! leaving an exit stage (not just the final one) and types it by
//! payload: codec waveforms become [`OutputDelta::AudioChunk`], DiT
//! latents [`OutputDelta::ImageFrame`], token batches
//! [`OutputDelta::TextDelta`].  Interior stages contribute
//! [`OutputDelta::StageDone`] markers through the stage-loop hook, and
//! the terminal [`OutputDelta::Done`] carries usage counters, the JCT,
//! and whether the request was cancelled.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::SessionInner;
use crate::engine::StageItem;

/// Aggregate output counters for one request, carried in
/// [`OutputDelta::Done`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Usage {
    /// Payload deltas emitted (text/audio/image; excludes stage markers).
    pub deltas: usize,
    pub text_tokens: usize,
    pub audio_samples: usize,
    pub image_frames: usize,
}

impl Usage {
    pub(crate) fn absorb(&mut self, p: &Payload) {
        match p {
            Payload::Text(n) => {
                self.deltas += 1;
                self.text_tokens += n;
            }
            Payload::Audio(n) => {
                self.deltas += 1;
                self.audio_samples += n;
            }
            Payload::Image(_) => {
                self.deltas += 1;
                self.image_frames += 1;
            }
            Payload::None => {}
        }
    }
}

/// One typed mid-flight event on a [`ResponseStream`].  All timestamps
/// are run-relative seconds on the session clock.
#[derive(Debug, Clone)]
pub enum OutputDelta {
    /// A batch of generated text/codec tokens from an exit AR stage.
    TextDelta { tokens: Vec<u32>, t: f64 },
    /// A synthesized waveform chunk (vocoder / patch-decoder output).
    AudioChunk { wave: Vec<f32>, t: f64 },
    /// A denoised visual frame; `tokens` is the latent token count.
    ImageFrame { tokens: usize, t: f64 },
    /// A (possibly interior) stage finished producing for this request.
    StageDone { stage: &'static str, t: f64 },
    /// One branch of a fan-out graph delivered its last output for this
    /// request (`branch` is the branch's exit stage).  Only emitted on
    /// multi-exit graphs; the terminal `Done` still waits for EVERY
    /// branch, so clients can act on a finished branch (e.g. show the
    /// image) while the other is still speaking.
    BranchDone { branch: &'static str, t: f64 },
    /// Terminal event: the request completed (`cancelled: false`) or was
    /// cancelled/deadline-expired (`cancelled: true`).  Always the last
    /// delta on the stream.
    Done { t: f64, jct_s: f64, cancelled: bool, usage: Usage },
    /// Terminal event: the admission controller refused the request at
    /// submit time, or the shedder dropped it from a queue before any
    /// stage started it.  Mutually exclusive with `Done` — a stream
    /// carries exactly one terminal event.  `retry_after_s` is the
    /// controller's backoff hint.
    Rejected { t: f64, reason: String, retry_after_s: f64 },
}

/// Outcome of [`ResponseStream::next_timeout`].
#[derive(Debug)]
pub enum StreamRecv {
    Delta(OutputDelta),
    Timeout,
    /// The stream can never yield again: the session shut down, failed,
    /// or the terminal `Done` was already consumed.
    Closed,
}

/// Per-request delta stream returned by
/// [`super::ServingSession::submit_request`].  Dropping it does NOT
/// cancel the request (use [`Self::cancel`]); unread deltas of a
/// non-streaming request are never materialized, so an unconsumed
/// stream costs nothing.
pub struct ResponseStream {
    req_id: u64,
    submitted_t: f64,
    rx: mpsc::Receiver<OutputDelta>,
    inner: Arc<SessionInner>,
    /// `(completed_t, cancelled)` once the terminal `Done` was seen.
    done: Option<(f64, bool)>,
    /// Rejection time once the terminal `Rejected` was seen.
    rejected_t: Option<f64>,
}

impl ResponseStream {
    pub(crate) fn new(
        req_id: u64,
        submitted_t: f64,
        rx: mpsc::Receiver<OutputDelta>,
        inner: Arc<SessionInner>,
    ) -> Self {
        Self { req_id, submitted_t, rx, inner, done: None, rejected_t: None }
    }

    pub fn req_id(&self) -> u64 {
        self.req_id
    }

    /// Submission time on the session clock (JCT = Done.t - this).
    pub fn submitted_t(&self) -> f64 {
        self.submitted_t
    }

    /// Whether a terminal event (`Done` or `Rejected`) has been received.
    pub fn is_done(&self) -> bool {
        self.done.is_some() || self.rejected_t.is_some()
    }

    /// Whether the stream's terminal event was a `Rejected`.
    pub fn is_rejected(&self) -> bool {
        self.rejected_t.is_some()
    }

    fn note(&mut self, d: &OutputDelta) {
        match d {
            OutputDelta::Done { t, cancelled, .. } => self.done = Some((*t, *cancelled)),
            OutputDelta::Rejected { t, .. } => self.rejected_t = Some(*t),
            _ => {}
        }
    }

    /// Blocking receive with a timeout.
    pub fn next_timeout(&mut self, d: Duration) -> StreamRecv {
        match self.rx.recv_timeout(d) {
            Ok(delta) => {
                self.note(&delta);
                StreamRecv::Delta(delta)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => StreamRecv::Timeout,
            Err(mpsc::RecvTimeoutError::Disconnected) => StreamRecv::Closed,
        }
    }

    /// Fully blocking receive; `None` once the stream is closed.  The
    /// collector closes every live stream when the session fails or
    /// shuts down, so this never hangs on a dead pipeline.
    pub fn recv(&mut self) -> Option<OutputDelta> {
        match self.rx.recv() {
            Ok(delta) => {
                self.note(&delta);
                Some(delta)
            }
            Err(_) => None,
        }
    }

    /// Cancel the request end-to-end: queued work is dropped at every
    /// stage, in-flight AR sequences are aborted with their KV blocks
    /// released, and the stream resolves with `Done { cancelled: true }`.
    /// Returns false when the request already resolved.
    pub fn cancel(&self) -> bool {
        self.inner.cancel_request(self.req_id)
    }
}

// ---------------------------------------------------------------------------
// The deprecated submit-and-block shim.
// ---------------------------------------------------------------------------

/// Delivered when a request completes (the old API's terminal event).
#[derive(Debug, Clone)]
pub struct Completion {
    pub req_id: u64,
    /// Run-relative completion time (seconds on the session clock).
    pub completed_t: f64,
}

/// Outcome of [`CompletionHandle::wait_timeout`].
#[derive(Debug)]
pub enum WaitResult {
    Done(Completion),
    /// The admission controller refused the request; it never ran.
    Rejected { req_id: u64, t: f64 },
    Timeout,
    /// The session's collector is gone (session shut down or failed);
    /// this completion can no longer arrive.
    Closed,
}

/// DEPRECATED: the pre-streaming per-request handle, kept as a thin
/// shim over [`ResponseStream`] so submit-and-block callers
/// ([`crate::orchestrator::Orchestrator::run_workload`], the bench
/// paths, existing tests) migrate mechanically.  New code should use
/// [`super::ServingSession::submit_request`] and consume the stream.
pub struct CompletionHandle {
    stream: ResponseStream,
}

impl CompletionHandle {
    /// Wrap a stream (the migration path for callers that still want
    /// submit-and-block semantics over the streaming API).
    pub fn from_stream(stream: ResponseStream) -> Self {
        Self { stream }
    }

    pub fn req_id(&self) -> u64 {
        self.stream.req_id
    }

    /// Submission time on the session clock (JCT = completed_t - this).
    pub fn submitted_t(&self) -> f64 {
        self.stream.submitted_t
    }

    /// Block until the request resolves (mid-flight deltas are
    /// discarded).  A cancelled request reports `Done` too — its
    /// completion time is the cancellation time.
    pub fn wait_timeout(&self, d: Duration) -> WaitResult {
        let deadline = Instant::now() + d;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            match self.stream.rx.recv_timeout(left) {
                Ok(OutputDelta::Done { t, .. }) => {
                    return WaitResult::Done(Completion {
                        req_id: self.stream.req_id,
                        completed_t: t,
                    });
                }
                Ok(OutputDelta::Rejected { t, .. }) => {
                    return WaitResult::Rejected { req_id: self.stream.req_id, t };
                }
                Ok(_) => continue,
                Err(mpsc::RecvTimeoutError::Timeout) => return WaitResult::Timeout,
                Err(mpsc::RecvTimeoutError::Disconnected) => return WaitResult::Closed,
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Delta taxonomy: exit-stage items -> typed deltas.
// ---------------------------------------------------------------------------

/// Payload classification of one exit item — sizes only, no tensor
/// copies.  The collector accounts EVERY request (usage counters,
/// `Event::Delta` TPOT timestamps) from this, and materializes the
/// actual delta only for streaming requests, so non-streaming
/// submit-and-block traffic never copies a waveform.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Payload {
    /// `n` generated tokens.
    Text(usize),
    /// `n` waveform samples.
    Audio(usize),
    /// `n` latent tokens.
    Image(usize),
    None,
}

/// Classify an exit-stage item's payload.  `audio` is the request-level
/// hint (it asked for audio output), which disambiguates the DiT
/// vocoder's latent+wave items from a visual pipeline's final latents.
pub(crate) fn classify_item(item: &StageItem, audio: bool) -> Payload {
    if audio {
        if let Some(w) = item.tensor("wave") {
            return if w.is_empty() { Payload::None } else { Payload::Audio(w.len()) };
        }
    } else if let Some(l) = item.tensor("latent") {
        return Payload::Image(l.shape.first().copied().unwrap_or(0));
    }
    match item.tensor("tokens") {
        Some(t) if !t.is_empty() => Payload::Text(t.len()),
        _ => Payload::None,
    }
}

/// Materialize the typed delta for an already-classified exit item (the
/// tensor copy only happens here, and only for streaming requests).
pub(crate) fn delta_for_payload(payload: Payload, item: &StageItem, t: f64) -> Option<OutputDelta> {
    match payload {
        Payload::Audio(_) => item
            .tensor("wave")
            .and_then(|w| w.as_f32().ok())
            .map(|w| OutputDelta::AudioChunk { wave: w.to_vec(), t }),
        Payload::Image(tokens) => Some(OutputDelta::ImageFrame { tokens, t }),
        Payload::Text(_) => item
            .tensor("tokens")
            .and_then(|tk| tk.as_i32().ok())
            .map(|tk| OutputDelta::TextDelta {
                tokens: tk.iter().map(|&x| x as u32).collect(),
                t,
            }),
        Payload::None => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    /// classify + materialize in one step (what the collector does for
    /// streaming requests).
    fn type_item(item: &StageItem, audio: bool, t: f64) -> Option<OutputDelta> {
        delta_for_payload(classify_item(item, audio), item, t)
    }

    #[test]
    fn vocoder_items_become_audio_chunks() {
        let item = StageItem::new(1)
            .with("wave", HostTensor::f32(vec![4], vec![0.1, 0.2, 0.3, 0.4]))
            .with("n_frames", HostTensor::i32(vec![1], vec![2]));
        let d = type_item(&item, true, 1.0).unwrap();
        assert!(matches!(&d, OutputDelta::AudioChunk { wave, t } if wave.len() == 4 && *t == 1.0));
    }

    #[test]
    fn dit_latents_type_by_request_modality() {
        // The DiT vocoder emits latent+wave; an audio request reads the
        // wave, a visual request reads the latent frame.
        let item = StageItem::new(1)
            .with("latent", HostTensor::f32(vec![8, 2], vec![0.0; 16]))
            .with("wave", HostTensor::f32(vec![16], vec![0.0; 16]));
        let audio = type_item(&item, true, 0.5).unwrap();
        assert!(matches!(&audio, OutputDelta::AudioChunk { .. }));
        let visual = type_item(&item, false, 0.5).unwrap();
        assert!(matches!(&visual, OutputDelta::ImageFrame { tokens: 8, .. }));
    }

    #[test]
    fn token_items_become_text_deltas_and_empty_items_nothing() {
        let item = StageItem::new(1).with("tokens", HostTensor::i32(vec![3], vec![5, 6, 7]));
        let d = type_item(&item, false, 0.1).unwrap();
        assert!(matches!(&d, OutputDelta::TextDelta { tokens, .. } if tokens == &vec![5, 6, 7]));
        // Zero-length token tensors (degenerate flushes) emit nothing.
        let empty = StageItem::new(1).with("tokens", HostTensor::i32(vec![0], vec![]));
        assert!(type_item(&empty, false, 0.1).is_none());
        assert!(type_item(&StageItem::new(1), true, 0.1).is_none());
    }

    #[test]
    fn classification_matches_materialization_and_feeds_usage() {
        // classify_item (the copy-free accounting path) must agree with
        // delta_for_payload (the streaming path) on every payload type.
        let audio_item = StageItem::new(1).with("wave", HostTensor::f32(vec![5], vec![0.0; 5]));
        assert_eq!(classify_item(&audio_item, true), Payload::Audio(5));
        let text_item = StageItem::new(1).with("tokens", HostTensor::i32(vec![2], vec![1, 2]));
        assert_eq!(classify_item(&text_item, false), Payload::Text(2));
        let img_item = StageItem::new(1).with("latent", HostTensor::f32(vec![8, 2], vec![0.0; 16]));
        assert_eq!(classify_item(&img_item, false), Payload::Image(8));
        assert_eq!(classify_item(&StageItem::new(1), true), Payload::None);

        let mut u = Usage::default();
        u.absorb(&Payload::Text(2));
        u.absorb(&Payload::Audio(5));
        u.absorb(&Payload::Image(8));
        u.absorb(&Payload::None);
        assert_eq!(u.deltas, 3);
        assert_eq!(u.text_tokens, 2);
        assert_eq!(u.audio_samples, 5);
        assert_eq!(u.image_frames, 1);
    }
}
