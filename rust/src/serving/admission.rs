//! SLO-aware admission control at the serving boundary (overload
//! control for the disaggregated pipeline).
//!
//! A [`ServingSession`](crate::serving::ServingSession) configured with an
//! [`AdmissionConfig`] consults an [`AdmissionController`] at submit time:
//!
//! * **Cost estimation** — every request is priced in abstract work units
//!   (prefill tokens, decode/audio budget, diffusion steps) converted to
//!   seconds through a rate the controller *learns online*: each
//!   completion's JCT recalibrates an EWMA of seconds-per-unit, so queue
//!   wait and engine speed both fold into the projection without any
//!   per-engine modelling.
//! * **Early rejection** — the projected completion time
//!   `(backlog / lanes + cost) * slack` is compared against the request's
//!   deadline; an unmeetable SLO is refused *before* the request touches
//!   a stage, with a structured
//!   [`OutputDelta::Rejected`](crate::serving::OutputDelta) carrying the
//!   reason and a `retry_after` hint instead of a connection drop.
//! * **Emergency shedding** — when the committed backlog projects past
//!   [`AdmissionConfig::shed_horizon_s`], queued requests are dropped
//!   earliest-deadline-first (the work most certainly doomed) until the
//!   projection fits.  Work a stage has already started is **never**
//!   shed — the controller only ever gives up on requests that have not
//!   consumed engine time.
//! * **Tenant interning** — tenant names from
//!   [`OmniRequest::tenant`](crate::serving::OmniRequest::tenant) map to
//!   dense ids (0 = anonymous) whose weights feed the per-stage
//!   weighted-fair queues ([`crate::scheduler::StageScheduler::enqueue_wfq`]).
//!
//! The controller is a self-contained state machine (submit → decide →
//! start/resolve/shed) so its invariants — never shed started work, no
//! admitted request silently dropped — are directly property-testable
//! without spinning up a pipeline (`tests/admission.rs`).

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::Result;

use crate::config::AdmissionConfig;
use crate::trace::Request;

/// Starting seconds-per-work-unit before any completion has calibrated
/// the EWMA (one unit ≈ one decode iteration of the toy engines).
const DEFAULT_S_PER_UNIT: f64 = 2e-3;

/// EWMA retention: `rate = KEEP * rate + (1 - KEEP) * observed`.
const EWMA_KEEP: f64 = 0.8;

/// The submit-time verdict.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    Admit,
    /// The deadline is unmeetable under the current backlog; the request
    /// must not enter the pipeline.
    Reject { reason: String, retry_after_s: f64 },
}

/// Live overload-control counters (surfaced through the server's
/// `stats` op next to the per-stage queue depths).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AdmissionStats {
    pub admitted: u64,
    pub rejected: u64,
    pub shed: u64,
    /// Estimated seconds of not-yet-started work currently queued.
    pub backlog_s: f64,
}

struct Entry {
    cost_s: f64,
    units: f64,
    /// Absolute session-clock deadline (None = no SLO; shed last).
    deadline_t: Option<f64>,
    /// A stage admitted it into an engine: immune to shedding.
    started: bool,
}

struct Ledger {
    queued: HashMap<u64, Entry>,
    s_per_unit: f64,
    admitted: u64,
    rejected: u64,
    shed: u64,
}

impl Ledger {
    fn backlog_s(&self) -> f64 {
        self.queued.values().filter(|e| !e.started).map(|e| e.cost_s).sum()
    }
}

/// See the module docs.  One per [`crate::serving::ServingSession`];
/// internally synchronized (submitters, the collector, and the stats op
/// all consult it).
pub struct AdmissionController {
    cfg: AdmissionConfig,
    /// Name-sorted tenant names; interned id = index + 1 (0 = anonymous).
    names: Vec<String>,
    /// Weight per interned id (index 0 = the anonymous tenant at 1.0).
    weights: Vec<f64>,
    state: Mutex<Ledger>,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Result<Self> {
        cfg.validate()?;
        let mut named: Vec<(String, f64)> = cfg.tenant_weights.clone();
        named.sort_by(|a, b| a.0.cmp(&b.0));
        let mut names = Vec::with_capacity(named.len());
        let mut weights = Vec::with_capacity(named.len() + 1);
        weights.push(1.0);
        for (n, w) in named {
            names.push(n);
            weights.push(w);
        }
        Ok(Self {
            cfg,
            names,
            weights,
            state: Mutex::new(Ledger {
                queued: HashMap::new(),
                s_per_unit: DEFAULT_S_PER_UNIT,
                admitted: 0,
                rejected: 0,
                shed: 0,
            }),
        })
    }

    /// Intern a tenant name: configured tenants get a stable dense id
    /// (name-sorted order + 1); unknown and anonymous tenants share
    /// id 0 at weight 1.0.
    pub fn tenant_id(&self, name: Option<&str>) -> u32 {
        match name {
            Some(n) => self
                .names
                .binary_search_by(|t| t.as_str().cmp(n))
                .map(|i| (i + 1) as u32)
                .unwrap_or(0),
            None => 0,
        }
    }

    /// WFQ weights indexed by interned tenant id, for
    /// [`crate::scheduler::StageScheduler::set_tenant_weights`].
    pub fn tenant_weights(&self) -> Vec<f64> {
        self.weights.clone()
    }

    pub fn retry_after_s(&self) -> f64 {
        self.cfg.retry_after_s
    }

    pub fn shed_horizon_s(&self) -> f64 {
        self.cfg.shed_horizon_s
    }

    /// Abstract work units of one request: its decode-side iteration
    /// budget (text + audio tokens + diffusion steps) plus discounted
    /// prefill work (prompt tokens batch; multimodal frames encode).
    fn cost_units(req: &Request) -> f64 {
        let decode = (req.max_text_tokens + req.max_audio_tokens + req.diffusion_steps).max(1);
        decode as f64
            + req.prompt_tokens.len() as f64 / 16.0
            + req.mm_frames as f64 / 4.0
    }

    /// Current cost estimate in seconds (units × the learned rate).
    pub fn estimate_cost_s(&self, req: &Request) -> f64 {
        Self::cost_units(req) * self.state.lock().unwrap().s_per_unit
    }

    /// Submit-time verdict for one request.  `lanes` is the number of
    /// live entry-stage replicas (parallel service lanes the backlog
    /// drains through).  An admitted request is entered into the ledger
    /// and MUST later be retired through [`Self::resolve`] (completion,
    /// cancellation, or rollback) or [`Self::shed`].
    pub fn decide(
        &self,
        req: &Request,
        deadline_s: Option<f64>,
        now: f64,
        lanes: usize,
    ) -> Decision {
        let units = Self::cost_units(req);
        let mut led = self.state.lock().unwrap();
        let cost_s = units * led.s_per_unit;
        if let Some(d) = deadline_s {
            let nl = lanes.max(1) as f64;
            let backlog = led.backlog_s();
            let projected = (backlog / nl + cost_s) * self.cfg.slack;
            if projected > d {
                led.rejected += 1;
                return Decision::Reject {
                    reason: format!(
                        "projected completion {projected:.3}s exceeds deadline {d:.3}s \
                         (backlog {backlog:.3}s over {} lane(s), est cost {cost_s:.3}s)",
                        lanes.max(1)
                    ),
                    retry_after_s: self.cfg.retry_after_s,
                };
            }
        }
        led.admitted += 1;
        led.queued.insert(
            req.id,
            Entry { cost_s, units, deadline_t: deadline_s.map(|d| now + d), started: false },
        );
        Decision::Admit
    }

    /// Retire one admitted request from the ledger (completion, cancel,
    /// or submit rollback).  A completion's `jct_s` recalibrates the
    /// seconds-per-unit EWMA, folding live queue wait and engine speed
    /// into future projections.  Idempotent: unknown ids are ignored
    /// (e.g. already shed).
    pub fn resolve(&self, req_id: u64, jct_s: Option<f64>) {
        let mut led = self.state.lock().unwrap();
        let Some(e) = led.queued.remove(&req_id) else { return };
        if let Some(jct) = jct_s {
            if jct.is_finite() && jct > 0.0 && e.units > 0.0 {
                let obs = (jct / e.units).clamp(1e-6, 1.0);
                led.s_per_unit = EWMA_KEEP * led.s_per_unit + (1.0 - EWMA_KEEP) * obs;
            }
        }
    }

    /// Emergency shedding sweep.  `is_started` reports whether any stage
    /// has admitted the request into an engine; such requests are
    /// **never** returned.  While the not-yet-started backlog projects
    /// past the horizon, queued requests are dropped
    /// earliest-deadline-first (deadline-less requests last; ties by id
    /// for determinism) and their ids returned for the caller to resolve
    /// their streams with a `Rejected` terminal event.
    pub fn shed(&self, lanes: usize, is_started: impl Fn(u64) -> bool) -> Vec<u64> {
        let mut led = self.state.lock().unwrap();
        // Absorb "a stage started it" facts lazily: started work is
        // immune from here on, whatever the backlog does.
        let unstarted: Vec<u64> =
            led.queued.iter().filter(|(_, e)| !e.started).map(|(&id, _)| id).collect();
        for id in unstarted {
            if is_started(id) {
                if let Some(e) = led.queued.get_mut(&id) {
                    e.started = true;
                }
            }
        }
        let nl = lanes.max(1) as f64;
        let mut out = Vec::new();
        while led.backlog_s() / nl > self.cfg.shed_horizon_s {
            let victim = led
                .queued
                .iter()
                .filter(|(_, e)| !e.started)
                .map(|(&id, e)| (e.deadline_t.unwrap_or(f64::INFINITY), id))
                .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                .map(|(_, id)| id);
            let Some(id) = victim else { break };
            led.queued.remove(&id);
            led.shed += 1;
            out.push(id);
        }
        out
    }

    /// Whether the ledger still tracks this request (admitted, not yet
    /// resolved or shed).
    pub fn tracks(&self, req_id: u64) -> bool {
        self.state.lock().unwrap().queued.contains_key(&req_id)
    }

    pub fn stats(&self) -> AdmissionStats {
        let led = self.state.lock().unwrap();
        AdmissionStats {
            admitted: led.admitted,
            rejected: led.rejected,
            shed: led.shed,
            backlog_s: led.backlog_s(),
        }
    }

    #[cfg(test)]
    fn set_rate(&self, s_per_unit: f64) {
        self.state.lock().unwrap().s_per_unit = s_per_unit;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Modality;

    fn cfg() -> AdmissionConfig {
        AdmissionConfig {
            tenant_weights: vec![("zeta".into(), 2.0), ("acme".into(), 4.0)],
            ..Default::default()
        }
    }

    fn req(id: u64, max_text: usize) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            modality: Modality::Text,
            prompt_tokens: vec![1, 2, 3, 4],
            mm_frames: 0,
            seed: id,
            max_text_tokens: max_text,
            max_audio_tokens: 0,
            diffusion_steps: 0,
            ignore_eos: true,
        }
    }

    #[test]
    fn tenants_intern_in_sorted_order_with_anonymous_zero() {
        let c = AdmissionController::new(cfg()).unwrap();
        assert_eq!(c.tenant_id(None), 0);
        assert_eq!(c.tenant_id(Some("acme")), 1, "name-sorted: acme < zeta");
        assert_eq!(c.tenant_id(Some("zeta")), 2);
        assert_eq!(c.tenant_id(Some("unlisted")), 0, "unknown tenants ride the anonymous lane");
        assert_eq!(c.tenant_weights(), vec![1.0, 4.0, 2.0]);
    }

    #[test]
    fn rejects_when_backlog_projects_past_the_deadline() {
        let c = AdmissionController::new(cfg()).unwrap();
        c.set_rate(0.01); // 100 units/s, deterministic
        // An empty ledger admits a feasible deadline...
        assert_eq!(c.decide(&req(1, 100), Some(10.0), 0.0, 1), Decision::Admit);
        // ...and each admit commits ~1s of backlog; after ten of them a
        // 1s deadline is hopeless on one lane.
        for id in 2..=10 {
            assert_eq!(c.decide(&req(id, 100), Some(100.0), 0.0, 1), Decision::Admit);
        }
        match c.decide(&req(11, 100), Some(1.0), 0.0, 1) {
            Decision::Reject { reason, retry_after_s } => {
                assert!(reason.contains("deadline"), "structured reason: {reason}");
                assert_eq!(retry_after_s, AdmissionConfig::default().retry_after_s);
            }
            Decision::Admit => panic!("a 1s deadline behind ~10s of backlog must be rejected"),
        }
        // More lanes drain the same backlog faster: a 2s deadline (room
        // for the request's own ~1s cost) fits once the queued work
        // spreads over 16 entry replicas, though it was hopeless on 1.
        assert_eq!(c.decide(&req(12, 100), Some(2.0), 0.0, 16), Decision::Admit);
        // No deadline = nothing to miss: always admitted.
        assert_eq!(c.decide(&req(13, 100), None, 0.0, 1), Decision::Admit);
        let st = c.stats();
        assert_eq!((st.admitted, st.rejected), (12, 1));
    }

    #[test]
    fn completions_recalibrate_the_cost_rate() {
        let c = AdmissionController::new(cfg()).unwrap();
        c.set_rate(0.01);
        let before = c.estimate_cost_s(&req(1, 100));
        assert_eq!(c.decide(&req(1, 100), None, 0.0, 1), Decision::Admit);
        // The request took far longer per unit than estimated (heavy
        // queueing): the learned rate, and so future projections, rise.
        c.resolve(1, Some(50.0));
        assert!(!c.tracks(1));
        assert!(c.estimate_cost_s(&req(2, 100)) > before);
        // Resolving an unknown id is a no-op.
        c.resolve(99, Some(1.0));
    }

    #[test]
    fn shed_drops_earliest_deadline_first_and_never_started_work() {
        let c = AdmissionController::new(AdmissionConfig {
            shed_horizon_s: 0.5,
            ..Default::default()
        })
        .unwrap();
        c.set_rate(0.01);
        // Four 1s-cost requests on one lane: backlog 4s >> 0.5s horizon.
        assert_eq!(c.decide(&req(1, 100), Some(2.0), 0.0, 1), Decision::Admit);
        assert_eq!(c.decide(&req(2, 100), Some(50.0), 0.0, 1), Decision::Admit);
        assert_eq!(c.decide(&req(3, 100), Some(80.0), 0.0, 1), Decision::Admit);
        assert_eq!(c.decide(&req(4, 100), None, 0.0, 1), Decision::Admit);
        // Request 1 has the earliest deadline but a stage started it:
        // immune.  Shedding then eats 2 (earliest deadline), 3, and
        // finally the deadline-less 4 until only started work remains.
        let shed = c.shed(1, |id| id == 1);
        assert_eq!(shed, vec![2, 3, 4]);
        assert!(c.tracks(1), "started work survives any backlog");
        assert!(!c.tracks(2) && !c.tracks(3) && !c.tracks(4));
        assert_eq!(c.stats().shed, 3);
        // Idempotent: nothing sheddable is left.
        assert!(c.shed(1, |_| true).is_empty());
    }

    #[test]
    fn shed_stops_once_the_backlog_fits_the_horizon() {
        let c = AdmissionController::new(AdmissionConfig {
            shed_horizon_s: 2.5,
            ..Default::default()
        })
        .unwrap();
        c.set_rate(0.01);
        for id in 1..=4 {
            assert_eq!(c.decide(&req(id, 100), Some(10.0 * id as f64), 0.0, 1), Decision::Admit);
        }
        // 4s of backlog over a 2.5s horizon: exactly two victims (the
        // two earliest deadlines) bring it to 2s.
        assert_eq!(c.shed(1, |_| false), vec![1, 2]);
        assert!(c.stats().backlog_s < 2.5 + 1e-9);
    }
}
