//! Serving metrics (paper §4.1): JCT, RTF, TTFT, per-stage TPS, and the
//! per-stage time decomposition behind Fig. 7.
//!
//! Engines and the orchestrator emit [`Event`]s into a [`Recorder`]
//! (lock-protected, cheap); [`RunReport`] aggregates a finished run into
//! the numbers the bench harness prints.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::audio;
use crate::connector::EdgeTransferSnapshot;
use crate::util::stats::Samples;

/// Lifecycle events for one request flowing through the stage graph.
#[derive(Debug, Clone)]
pub enum Event {
    /// Request entered the system (run-relative seconds).  `deadline` is
    /// the request's absolute SLO deadline, if it declared one — the
    /// goodput accounting in [`RunReport`] judges completions against it.
    Arrived { req: u64, t: f64, deadline: Option<f64> },
    /// Request was admitted to a stage's engine.
    StageAdmit { req: u64, stage: &'static str, t: f64 },
    /// A stage produced its first output item for this request.
    StageFirstOutput { req: u64, stage: &'static str, t: f64 },
    /// The request's first decode TOKEN exists somewhere in the pipeline
    /// (stage loops emit this on the first token-bearing item only, so
    /// encoder/vocoder feature items never count).  The earliest
    /// emission wins; feeds [`RunReport::first_token`].
    FirstToken { req: u64, t: f64 },
    /// A stage finished this request, having produced `tokens` items.
    StageDone { req: u64, stage: &'static str, t: f64, tokens: usize },
    /// A typed output delta crossed the client boundary (emitted by the
    /// serving collector the moment an exit-stage item is typed into a
    /// [`crate::serving::OutputDelta`]).  Consecutive deltas of one
    /// request measure TPOT — time per output token/chunk as the CLIENT
    /// observes it, not as the recorder's internal stage events do.
    Delta { req: u64, t: f64 },
    /// Request fully completed.
    Completed { req: u64, t: f64 },
    /// Request cancelled (client call, server op, or deadline expiry).
    /// Terminal like `Completed`; such requests count in
    /// [`RunReport::cancelled`], never in [`RunReport::completed`].
    Cancelled { req: u64, t: f64 },
    /// Request rejected by the admission controller (at submit time) or
    /// shed from a queue before starting.  Terminal like `Completed` and
    /// `Cancelled`; counts in [`RunReport::rejected`] only.
    Rejected { req: u64, t: f64 },
    /// Scheduler occupancy sample for one engine replica of a stage
    /// (paper §3.3 batching observability): pending admission-queue
    /// depth, engine occupancy, and the in-flight token commitment at one
    /// token boundary.  `replica` is 0 for unreplicated stages.
    SchedSample {
        stage: &'static str,
        replica: usize,
        t: f64,
        queued: usize,
        running: usize,
        committed_tokens: usize,
    },
    /// A request cleared a stage replica's admission queue after `wait_s`
    /// seconds.
    SchedAdmitted { stage: &'static str, replica: usize, req: u64, t: f64, wait_s: f64 },
    /// The elastic autoscaler changed a stage's replica count (paper §3
    /// "flexible GPU allocation" under live traffic): `from` live
    /// replicas became `to`.  Scale-downs are recorded at drain start.
    Scale { stage: String, t: f64, from: usize, to: usize },
    /// Cross-request cache counters for one engine replica of a stage
    /// (prefix cache on AR engines, output cache on encoders).  Counters
    /// are ABSOLUTE totals since engine construction — the recorder
    /// keeps the latest snapshot per (stage, replica), so stages may
    /// emit periodically or once at shutdown.
    CacheStats { stage: &'static str, replica: usize, t: f64, counters: CacheCounters },
    /// Per-edge transfer counters (ISSUE 8): bytes/frames moved and
    /// send→resolve latency percentiles for one logical edge, labelled
    /// inside the snapshot.  Counters are ABSOLUTE totals since edge
    /// construction — the latest snapshot per label wins, so edges may
    /// emit periodically or once at shutdown.
    EdgeStats { t: f64, snapshot: EdgeTransferSnapshot },
}

/// Cross-request cache counters (see [`Event::CacheStats`]): block-level
/// prefix-cache hits/misses/evictions from the KV pool plus
/// encoder-output cache hits/misses.  One engine kind populates one
/// half; stage- and run-level rollups sum both.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheCounters {
    /// Prompt blocks served from the cross-request prefix cache.
    pub prefix_hits: u64,
    /// Prompt blocks allocated cold (no resident prefix block).
    pub prefix_misses: u64,
    /// Cached blocks reclaimed to make room for new sequences.
    pub evictions: u64,
    /// Encoder jobs answered from the output cache.
    pub encoder_hits: u64,
    /// Encoder jobs that ran the encoder.
    pub encoder_misses: u64,
}

impl CacheCounters {
    pub fn absorb(&mut self, other: &CacheCounters) {
        self.prefix_hits += other.prefix_hits;
        self.prefix_misses += other.prefix_misses;
        self.evictions += other.evictions;
        self.encoder_hits += other.encoder_hits;
        self.encoder_misses += other.encoder_misses;
    }

    /// Fraction of prompt-block lookups served from the prefix cache
    /// (0.0 when nothing was looked up).
    pub fn prefix_hit_rate(&self) -> f64 {
        hit_rate(self.prefix_hits, self.prefix_misses)
    }

    /// Fraction of encoder jobs answered from the output cache.
    pub fn encoder_hit_rate(&self) -> f64 {
        hit_rate(self.encoder_hits, self.encoder_misses)
    }
}

fn hit_rate(hits: u64, misses: u64) -> f64 {
    let total = hits + misses;
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

/// One autoscaler decision, as kept by the [`Recorder`] (the replica
/// count timeline of a stage is the sequence of its scale events).
#[derive(Debug, Clone)]
pub struct ScaleEvent {
    pub stage: String,
    pub t: f64,
    pub from: usize,
    pub to: usize,
}

impl ScaleEvent {
    pub fn is_up(&self) -> bool {
        self.to > self.from
    }
}

#[derive(Debug, Default, Clone)]
struct StageRec {
    admit: Option<f64>,
    first: Option<f64>,
    done: Option<f64>,
    tokens: usize,
}

#[derive(Debug, Default, Clone)]
struct ReqRec {
    arrived: Option<f64>,
    /// Absolute SLO deadline declared at arrival, if any.
    deadline: Option<f64>,
    completed: Option<f64>,
    cancelled: Option<f64>,
    rejected: Option<f64>,
    /// Earliest [`Event::FirstToken`] timestamp.
    first_token: Option<f64>,
    /// Timestamp of the last client-boundary delta ([`Event::Delta`]).
    last_delta: Option<f64>,
    /// Inter-delta gaps (client-boundary TPOT samples).
    delta_gaps: Samples,
    stages: HashMap<&'static str, StageRec>,
}

/// Per-stage scheduler aggregates (queue depth, batch occupancy,
/// admission waits) built from [`Event::SchedSample`] /
/// [`Event::SchedAdmitted`].
#[derive(Debug, Default, Clone)]
pub struct SchedAgg {
    /// Pending admission-queue depth per sample.
    pub queue_depth: Samples,
    /// Engine occupancy (running + engine-internal queue) per sample.
    pub occupancy: Samples,
    /// In-flight token commitment per sample (AR stages).
    pub committed_tokens: Samples,
    /// Seconds requests waited in the admission queue.
    pub admit_wait: Samples,
    /// Requests admitted through the queue.
    pub admitted: u64,
}

impl SchedAgg {
    /// Fold another replica's aggregates into this one (per-stage
    /// rollup across replicas).
    pub fn merge(&mut self, other: &SchedAgg) {
        self.queue_depth.extend(&other.queue_depth);
        self.occupancy.extend(&other.occupancy);
        self.committed_tokens.extend(&other.committed_tokens);
        self.admit_wait.extend(&other.admit_wait);
        self.admitted += other.admitted;
    }
}

/// Thread-safe event sink.  Scheduler aggregates are keyed per (stage,
/// replica); [`Recorder::report`] additionally merges them per stage.
#[derive(Debug, Default)]
pub struct Recorder {
    inner: Mutex<HashMap<u64, ReqRec>>,
    sched: Mutex<HashMap<(&'static str, usize), SchedAgg>>,
    scale: Mutex<Vec<ScaleEvent>>,
    /// Latest absolute cache counters per (stage, replica) — see
    /// [`Event::CacheStats`].
    cache: Mutex<HashMap<(&'static str, usize), CacheCounters>>,
    /// Latest absolute transfer counters per edge label — see
    /// [`Event::EdgeStats`].
    edges: Mutex<HashMap<String, EdgeTransferSnapshot>>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn emit(&self, e: Event) {
        match &e {
            Event::SchedSample { stage, replica, queued, running, committed_tokens, .. } => {
                let mut s = self.sched.lock().unwrap();
                let agg = s.entry((*stage, *replica)).or_default();
                agg.queue_depth.push(*queued as f64);
                agg.occupancy.push(*running as f64);
                agg.committed_tokens.push(*committed_tokens as f64);
                return;
            }
            Event::SchedAdmitted { stage, replica, wait_s, .. } => {
                let mut s = self.sched.lock().unwrap();
                let agg = s.entry((*stage, *replica)).or_default();
                agg.admit_wait.push(*wait_s);
                agg.admitted += 1;
                return;
            }
            Event::Scale { stage, t, from, to } => {
                self.scale.lock().unwrap().push(ScaleEvent {
                    stage: stage.clone(),
                    t: *t,
                    from: *from,
                    to: *to,
                });
                return;
            }
            Event::CacheStats { stage, replica, counters, .. } => {
                // Absolute totals: the latest snapshot wins.
                self.cache.lock().unwrap().insert((*stage, *replica), *counters);
                return;
            }
            Event::EdgeStats { snapshot, .. } => {
                // Absolute totals: the latest snapshot wins.
                self.edges.lock().unwrap().insert(snapshot.label.clone(), snapshot.clone());
                return;
            }
            _ => {}
        }
        let mut m = self.inner.lock().unwrap();
        match e {
            Event::Arrived { req, t, deadline } => {
                let r = m.entry(req).or_default();
                r.arrived = Some(t);
                r.deadline = deadline;
            }
            Event::StageAdmit { req, stage, t } => {
                m.entry(req).or_default().stages.entry(stage).or_default().admit = Some(t);
            }
            Event::StageFirstOutput { req, stage, t } => {
                let s = m.entry(req).or_default().stages.entry(stage).or_default();
                if s.first.is_none() {
                    s.first = Some(t);
                }
            }
            Event::FirstToken { req, t } => {
                let r = m.entry(req).or_default();
                r.first_token = Some(r.first_token.map_or(t, |x| x.min(t)));
            }
            Event::StageDone { req, stage, t, tokens } => {
                let s = m.entry(req).or_default().stages.entry(stage).or_default();
                s.done = Some(t);
                s.tokens = tokens;
            }
            Event::Delta { req, t } => {
                let r = m.entry(req).or_default();
                if let Some(prev) = r.last_delta {
                    r.delta_gaps.push((t - prev).max(0.0));
                }
                r.last_delta = Some(t);
            }
            Event::Completed { req, t } => {
                m.entry(req).or_default().completed = Some(t);
            }
            Event::Cancelled { req, t } => {
                m.entry(req).or_default().cancelled = Some(t);
            }
            Event::Rejected { req, t } => {
                m.entry(req).or_default().rejected = Some(t);
            }
            // Handled (with an early return) above.
            Event::SchedSample { .. }
            | Event::SchedAdmitted { .. }
            | Event::Scale { .. }
            | Event::CacheStats { .. }
            | Event::EdgeStats { .. } => {
                unreachable!()
            }
        }
    }

    /// Whether any stage has admitted this request to an engine — the
    /// "in-flight" predicate the shedder consults: a started request is
    /// never sheddable, only cancellable.
    pub fn started(&self, req: u64) -> bool {
        self.inner
            .lock()
            .unwrap()
            .get(&req)
            .map(|r| r.stages.values().any(|s| s.admit.is_some()))
            .unwrap_or(false)
    }

    /// Aggregate into a [`RunReport`].  `audio_stage` names the stage whose
    /// token count measures generated audio (for RTF); `None` = no audio.
    pub fn report(&self, wall_s: f64, audio_stage: Option<&str>) -> RunReport {
        let m = self.inner.lock().unwrap();
        let mut jct = Samples::new();
        let mut ttft = Samples::new();
        let mut first_token = Samples::new();
        let mut tpot = Samples::new();
        let mut rtf = Samples::new();
        let mut per_stage: HashMap<String, StageAgg> = HashMap::new();
        let mut completed = 0usize;
        let mut cancelled = 0usize;
        let mut rejected = 0usize;
        let mut offered = 0usize;
        let mut in_slo = 0usize;

        for rec in m.values() {
            // TPOT and the cancelled count include requests that never
            // completed — a cancelled stream's deltas were still
            // observed at the client boundary.
            tpot.extend(&rec.delta_gaps);
            if rec.arrived.is_some() {
                offered += 1;
            }
            if rec.cancelled.is_some() {
                cancelled += 1;
            }
            if rec.rejected.is_some() {
                rejected += 1;
            }
            let (Some(a), Some(c)) = (rec.arrived, rec.completed) else { continue };
            completed += 1;
            // Goodput numerator: completed within the declared SLO (a
            // request without one completes "within SLO" trivially).
            if rec.deadline.map_or(true, |d| c <= d) {
                in_slo += 1;
            }
            jct.push(c - a);
            // TTFT: first output of the LAST stage that produced anything.
            if let Some(first) = rec
                .stages
                .values()
                .filter_map(|s| s.first)
                .fold(None::<f64>, |acc, t| Some(acc.map_or(t, |x| x.max(t))))
            {
                ttft.push(first - a);
            }
            // First decode token (the earliest FirstToken event — stage
            // loops emit it only for token-bearing items, so an encoder
            // stage's feature items never count).  Kept separate from
            // JCT and from the pipeline-exit TTFT above; this is the
            // latency the P/D split protects.
            if let Some(first) = rec.first_token {
                first_token.push(first - a);
            }
            for (name, s) in &rec.stages {
                let agg = per_stage.entry(name.to_string()).or_default();
                if let (Some(ad), Some(dn)) = (s.admit, s.done) {
                    agg.time.push(dn - ad);
                    agg.tokens += s.tokens;
                    agg.requests += 1;
                }
            }
            if let Some(stage) = audio_stage {
                if let Some(s) = rec.stages.get(stage) {
                    if s.tokens > 0 {
                        rtf.push(audio::rtf(c - a, s.tokens));
                    }
                }
            }
        }

        let by_replica = self.sched.lock().unwrap();
        let mut sched: HashMap<String, SchedAgg> = HashMap::new();
        let mut sched_replicas: HashMap<(String, usize), SchedAgg> = HashMap::new();
        for (&(stage, replica), agg) in by_replica.iter() {
            sched.entry(stage.to_string()).or_default().merge(agg);
            sched_replicas.insert((stage.to_string(), replica), agg.clone());
        }
        drop(by_replica);
        let mut scale_events = self.scale.lock().unwrap().clone();
        scale_events.sort_by(|a, b| a.t.total_cmp(&b.t));

        let by_replica = self.cache.lock().unwrap();
        let mut cache: HashMap<String, CacheCounters> = HashMap::new();
        for (&(stage, _), c) in by_replica.iter() {
            cache.entry(stage.to_string()).or_default().absorb(c);
        }
        drop(by_replica);

        let mut edges: Vec<EdgeTransferSnapshot> =
            self.edges.lock().unwrap().values().cloned().collect();
        edges.sort_by(|a, b| a.label.cmp(&b.label));

        RunReport {
            wall_s,
            completed,
            cancelled,
            rejected,
            offered,
            in_slo,
            jct,
            ttft,
            first_token,
            tpot,
            rtf,
            per_stage,
            sched,
            sched_replicas,
            scale_events,
            cache,
            edges,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct StageAgg {
    /// Per-request residence time in the stage (admit -> done).
    pub time: Samples,
    pub tokens: usize,
    pub requests: usize,
}

/// Aggregated results for one benchmark run.
#[derive(Debug)]
pub struct RunReport {
    pub wall_s: f64,
    pub completed: usize,
    /// Requests that resolved by cancellation (client/server/deadline);
    /// disjoint from [`Self::completed`].
    pub cancelled: usize,
    /// Requests rejected at admission or shed before starting; disjoint
    /// from both [`Self::completed`] and [`Self::cancelled`].
    pub rejected: usize,
    /// Every request that arrived (completed, cancelled, rejected, or
    /// still in flight) — the goodput denominator.
    pub offered: usize,
    /// Completions within the declared SLO deadline (all completions for
    /// deadline-less requests) — the goodput numerator.
    pub in_slo: usize,
    pub jct: Samples,
    pub ttft: Samples,
    /// Time to the FIRST decode token (earliest [`Event::FirstToken`],
    /// emitted per request on the first token-bearing stage item) —
    /// distinct from [`Self::ttft`], which measures the pipeline's last
    /// stage.  This is the metric prefill/decode splits move.
    pub first_token: Samples,
    /// Client-boundary inter-delta latency (TPOT): the gaps between
    /// consecutive [`Event::Delta`]s of each request, pooled.  Measures
    /// what a streaming client actually experiences between chunks, not
    /// the recorder-internal stage cadence.
    pub tpot: Samples,
    pub rtf: Samples,
    pub per_stage: HashMap<String, StageAgg>,
    /// Per-stage scheduler aggregates, merged across engine replicas
    /// (empty for stages that never emitted scheduler samples, e.g.
    /// baseline runs).
    pub sched: HashMap<String, SchedAgg>,
    /// Scheduler aggregates per (stage, replica) — the unmerged view
    /// behind `sched`, for replica-balance analysis.
    pub sched_replicas: HashMap<(String, usize), SchedAgg>,
    /// Autoscaler decisions in time order (empty for static runs).
    pub scale_events: Vec<ScaleEvent>,
    /// Cross-request cache counters per stage, summed across that
    /// stage's engine replicas (empty when no stage emitted
    /// [`Event::CacheStats`], e.g. caches disabled).
    pub cache: HashMap<String, CacheCounters>,
    /// Per-edge transfer counters (bytes, frames, p50/p95 send→resolve
    /// latency), sorted by edge label — empty when nothing emitted
    /// [`Event::EdgeStats`].
    pub edges: Vec<EdgeTransferSnapshot>,
}

impl RunReport {
    pub fn mean_jct(&self) -> f64 {
        self.jct.mean()
    }

    /// Goodput: the fraction of offered requests that completed within
    /// their SLO.  The headline overload metric — rejecting or shedding
    /// work only pays when it raises this.
    pub fn goodput(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.in_slo as f64 / self.offered as f64
    }

    pub fn mean_rtf(&self) -> f64 {
        self.rtf.mean()
    }

    pub fn mean_ttft(&self) -> f64 {
        self.ttft.mean()
    }

    /// Mean time to the first decode token (see [`Self::first_token`]).
    pub fn mean_first_token(&self) -> f64 {
        self.first_token.mean()
    }

    /// Mean client-boundary inter-delta latency (see [`Self::tpot`]).
    pub fn mean_tpot(&self) -> f64 {
        self.tpot.mean()
    }

    /// Percentile of the client-boundary inter-delta latency
    /// (p in `[0, 100]`) — the TPOT p50/p95 the run summary prints.
    pub fn tpot_percentile(&self, p: f64) -> f64 {
        self.tpot.clone().percentile(p)
    }

    /// Percentile of the seconds requests waited in `stage`'s admission
    /// queue (p in `[0, 100]`) — the per-stage queue-wait view the run
    /// summary prints as p50/p95.
    pub fn sched_wait_percentile(&self, stage: &str, p: f64) -> f64 {
        self.sched
            .get(stage)
            .map(|a| a.admit_wait.clone().percentile(p))
            .unwrap_or(0.0)
    }

    /// Aggregate tokens-per-second for a stage over the whole run
    /// (the paper's Thinker/Talker TPS metric).
    pub fn stage_tps(&self, stage: &str) -> f64 {
        match self.per_stage.get(stage) {
            Some(agg) if self.wall_s > 0.0 => agg.tokens as f64 / self.wall_s,
            _ => 0.0,
        }
    }

    /// Mean per-request residence time for a stage (Fig. 7 decomposition).
    pub fn stage_mean_time(&self, stage: &str) -> f64 {
        self.per_stage.get(stage).map(|a| a.time.mean()).unwrap_or(0.0)
    }

    pub fn stage_tokens(&self, stage: &str) -> usize {
        self.per_stage.get(stage).map(|a| a.tokens).unwrap_or(0)
    }

    /// Mean pending admission-queue depth observed at a stage.
    pub fn sched_mean_queue_depth(&self, stage: &str) -> f64 {
        self.sched.get(stage).map(|a| a.queue_depth.mean()).unwrap_or(0.0)
    }

    /// Mean engine occupancy (batch fullness) observed at a stage.
    pub fn sched_mean_occupancy(&self, stage: &str) -> f64 {
        self.sched.get(stage).map(|a| a.occupancy.mean()).unwrap_or(0.0)
    }

    /// Mean seconds requests spent in a stage's admission queue.
    pub fn sched_mean_admit_wait(&self, stage: &str) -> f64 {
        self.sched.get(stage).map(|a| a.admit_wait.mean()).unwrap_or(0.0)
    }

    /// Scheduler aggregates for one engine replica of a stage, if it
    /// emitted any samples.
    pub fn sched_replica(&self, stage: &str, replica: usize) -> Option<&SchedAgg> {
        self.sched_replicas.get(&(stage.to_string(), replica))
    }

    /// Number of engine replicas of `stage` that emitted scheduler
    /// events.
    pub fn sched_replica_count(&self, stage: &str) -> usize {
        self.sched_replicas.keys().filter(|(s, _)| s == stage).count()
    }

    /// Scale-up events recorded for `stage` (all stages when `None`).
    pub fn scale_ups(&self, stage: Option<&str>) -> usize {
        self.scale_events
            .iter()
            .filter(|e| e.is_up() && stage.map_or(true, |s| e.stage == s))
            .count()
    }

    /// Scale-down events recorded for `stage` (all stages when `None`).
    pub fn scale_downs(&self, stage: Option<&str>) -> usize {
        self.scale_events
            .iter()
            .filter(|e| !e.is_up() && stage.map_or(true, |s| e.stage == s))
            .count()
    }

    /// Run-wide cache counters: every stage's prefix- and encoder-cache
    /// totals folded together (the run summary's "cache" line).
    pub fn cache_totals(&self) -> CacheCounters {
        let mut acc = CacheCounters::default();
        for c in self.cache.values() {
            acc.absorb(c);
        }
        acc
    }

    /// Transfer counters for one edge by label, if it emitted any.
    pub fn edge(&self, label: &str) -> Option<&EdgeTransferSnapshot> {
        self.edges.iter().find(|e| e.label == label)
    }

    /// Replica-count timeline of `stage`: `(t, live_replicas)` starting
    /// from the stage's first recorded event.
    pub fn replica_timeline(&self, stage: &str) -> Vec<(f64, usize)> {
        let mut out = Vec::new();
        for e in &self.scale_events {
            if e.stage == stage {
                if out.is_empty() {
                    out.push((0.0, e.from));
                }
                out.push((e.t, e.to));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_lifecycle() {
        let r = Recorder::new();
        r.emit(Event::Arrived { req: 1, t: 0.0, deadline: None });
        r.emit(Event::StageAdmit { req: 1, stage: "thinker", t: 0.1 });
        r.emit(Event::StageFirstOutput { req: 1, stage: "thinker", t: 0.2 });
        r.emit(Event::StageDone { req: 1, stage: "thinker", t: 1.1, tokens: 10 });
        r.emit(Event::StageAdmit { req: 1, stage: "talker", t: 0.3 });
        r.emit(Event::StageFirstOutput { req: 1, stage: "talker", t: 0.5 });
        r.emit(Event::StageDone { req: 1, stage: "talker", t: 2.0, tokens: 100 });
        r.emit(Event::Completed { req: 1, t: 2.0 });
        let rep = r.report(2.0, Some("talker"));
        assert_eq!(rep.completed, 1);
        assert!((rep.mean_jct() - 2.0).abs() < 1e-9);
        // RTF: 2 s processing / (100 tokens / 50 Hz = 2 s audio) = 1.0
        assert!((rep.mean_rtf() - 1.0).abs() < 1e-9);
        assert!((rep.stage_tps("talker") - 50.0).abs() < 1e-9);
        assert!((rep.stage_mean_time("thinker") - 1.0).abs() < 1e-9);
        // TTFT = last stage's first output = 0.5
        assert!((rep.mean_ttft() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn first_token_follows_the_dedicated_event_not_feature_items() {
        // An EPD-shaped pipeline: the encoder's feature item is a stage
        // first-output but NOT a token, so only the prefill stage's
        // FirstToken event counts; TTFT still follows the exit stage.
        let r = Recorder::new();
        r.emit(Event::Arrived { req: 1, t: 0.0, deadline: None });
        r.emit(Event::StageFirstOutput { req: 1, stage: "encoder", t: 0.02 });
        r.emit(Event::StageAdmit { req: 1, stage: "prefill", t: 0.05 });
        r.emit(Event::StageFirstOutput { req: 1, stage: "prefill", t: 0.1 });
        r.emit(Event::FirstToken { req: 1, t: 0.1 });
        r.emit(Event::StageDone { req: 1, stage: "prefill", t: 0.1, tokens: 1 });
        r.emit(Event::StageAdmit { req: 1, stage: "decode", t: 0.12 });
        r.emit(Event::StageFirstOutput { req: 1, stage: "decode", t: 0.4 });
        // The decode stage re-emits the first token later; earliest wins.
        r.emit(Event::FirstToken { req: 1, t: 0.4 });
        r.emit(Event::StageDone { req: 1, stage: "decode", t: 0.9, tokens: 20 });
        r.emit(Event::Completed { req: 1, t: 0.9 });
        let rep = r.report(1.0, None);
        assert!((rep.mean_first_token() - 0.1).abs() < 1e-9);
        assert!((rep.mean_ttft() - 0.4).abs() < 1e-9);
        assert!((rep.mean_jct() - 0.9).abs() < 1e-9);
        // A run without FirstToken events (e.g. baseline) reports empty.
        assert_eq!(rep.first_token.len(), 1);
    }

    #[test]
    fn sched_wait_percentiles_per_stage() {
        let r = Recorder::new();
        for (i, w) in [0.1, 0.2, 0.3, 0.4, 1.0].iter().enumerate() {
            r.emit(Event::SchedAdmitted {
                stage: "decode",
                replica: 0,
                req: i as u64,
                t: 1.0,
                wait_s: *w,
            });
        }
        let rep = r.report(1.0, None);
        assert!((rep.sched_wait_percentile("decode", 50.0) - 0.3).abs() < 1e-9);
        assert!((rep.sched_wait_percentile("decode", 100.0) - 1.0).abs() < 1e-9);
        assert_eq!(rep.sched_wait_percentile("nope", 50.0), 0.0);
    }

    #[test]
    fn delta_gaps_aggregate_into_tpot() {
        let r = Recorder::new();
        r.emit(Event::Arrived { req: 1, t: 0.0, deadline: None });
        for t in [0.1, 0.2, 0.4, 0.8] {
            r.emit(Event::Delta { req: 1, t });
        }
        r.emit(Event::Completed { req: 1, t: 0.8 });
        // A second request's gaps pool into the same TPOT distribution
        // even though it was cancelled before completing.
        r.emit(Event::Arrived { req: 2, t: 0.0, deadline: None });
        r.emit(Event::Delta { req: 2, t: 0.5 });
        r.emit(Event::Delta { req: 2, t: 1.5 });
        r.emit(Event::Cancelled { req: 2, t: 2.0 });
        let rep = r.report(2.0, None);
        // Gaps: req 1 -> 0.1, 0.2, 0.4; req 2 -> 1.0.  First deltas
        // contribute no gap (that's TTFT's job).
        assert_eq!(rep.tpot.len(), 4);
        assert!((rep.mean_tpot() - 0.425).abs() < 1e-9);
        assert!((rep.tpot_percentile(100.0) - 1.0).abs() < 1e-9);
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.cancelled, 1);
    }

    #[test]
    fn cancelled_requests_never_count_as_completed() {
        let r = Recorder::new();
        r.emit(Event::Arrived { req: 1, t: 0.0, deadline: None });
        r.emit(Event::Cancelled { req: 1, t: 0.5 });
        let rep = r.report(1.0, None);
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.cancelled, 1);
        assert_eq!(rep.jct.len(), 0, "cancelled requests report no JCT");
    }

    #[test]
    fn incomplete_requests_excluded() {
        let r = Recorder::new();
        r.emit(Event::Arrived { req: 1, t: 0.0, deadline: None });
        let rep = r.report(1.0, None);
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.jct.len(), 0);
        // ...but an arrived request still counts as offered.
        assert_eq!(rep.offered, 1);
        assert_eq!(rep.goodput(), 0.0);
    }

    #[test]
    fn goodput_judges_completions_against_the_declared_deadline() {
        let r = Recorder::new();
        // In SLO: completes at 0.8 against a deadline of 1.0.
        r.emit(Event::Arrived { req: 1, t: 0.0, deadline: Some(1.0) });
        r.emit(Event::Completed { req: 1, t: 0.8 });
        // Out of SLO: completes, but late.
        r.emit(Event::Arrived { req: 2, t: 0.0, deadline: Some(1.0) });
        r.emit(Event::Completed { req: 2, t: 1.5 });
        // No deadline: any completion is in SLO.
        r.emit(Event::Arrived { req: 3, t: 0.0, deadline: None });
        r.emit(Event::Completed { req: 3, t: 9.0 });
        // Cancelled by its deadline: offered, not in SLO.
        r.emit(Event::Arrived { req: 4, t: 0.0, deadline: Some(0.5) });
        r.emit(Event::Cancelled { req: 4, t: 0.5 });
        let rep = r.report(9.0, None);
        assert_eq!(rep.offered, 4);
        assert_eq!(rep.completed, 3);
        assert_eq!(rep.in_slo, 2);
        assert!((rep.goodput() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn rejected_requests_count_only_as_rejected() {
        let r = Recorder::new();
        // Rejected at submit time (the admission controller records the
        // arrival first, so the request stays in the offered count).
        r.emit(Event::Arrived { req: 1, t: 0.0, deadline: Some(1.0) });
        r.emit(Event::Rejected { req: 1, t: 0.0 });
        // A second request completes in SLO.
        r.emit(Event::Arrived { req: 2, t: 0.0, deadline: Some(1.0) });
        r.emit(Event::Completed { req: 2, t: 0.3 });
        let rep = r.report(1.0, None);
        assert_eq!(rep.rejected, 1);
        assert_eq!(rep.cancelled, 0, "rejection is not cancellation");
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.offered, 2);
        assert!((rep.goodput() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn started_tracks_stage_admission() {
        let r = Recorder::new();
        r.emit(Event::Arrived { req: 1, t: 0.0, deadline: None });
        assert!(!r.started(1), "arrival alone is not in-flight");
        assert!(!r.started(99), "unknown requests are not in-flight");
        r.emit(Event::StageAdmit { req: 1, stage: "thinker", t: 0.1 });
        assert!(r.started(1), "stage admission makes a request in-flight");
    }

    #[test]
    fn sched_samples_aggregate_per_stage() {
        let r = Recorder::new();
        r.emit(Event::SchedSample { stage: "talker", replica: 0, t: 0.1, queued: 3, running: 2, committed_tokens: 64 });
        r.emit(Event::SchedSample { stage: "talker", replica: 0, t: 0.2, queued: 1, running: 4, committed_tokens: 96 });
        r.emit(Event::SchedAdmitted { stage: "talker", replica: 0, req: 1, t: 0.2, wait_s: 0.05 });
        let rep = r.report(1.0, None);
        assert!((rep.sched_mean_queue_depth("talker") - 2.0).abs() < 1e-9);
        assert!((rep.sched_mean_occupancy("talker") - 3.0).abs() < 1e-9);
        assert!((rep.sched_mean_admit_wait("talker") - 0.05).abs() < 1e-9);
        assert_eq!(rep.sched["talker"].admitted, 1);
        // Unsampled stages report zeros, not panics.
        assert_eq!(rep.sched_mean_queue_depth("vocoder"), 0.0);
    }

    #[test]
    fn sched_samples_split_and_merge_across_replicas() {
        let r = Recorder::new();
        r.emit(Event::SchedSample { stage: "talker", replica: 0, t: 0.1, queued: 4, running: 2, committed_tokens: 10 });
        r.emit(Event::SchedSample { stage: "talker", replica: 1, t: 0.1, queued: 0, running: 1, committed_tokens: 5 });
        r.emit(Event::SchedAdmitted { stage: "talker", replica: 0, req: 1, t: 0.2, wait_s: 0.1 });
        r.emit(Event::SchedAdmitted { stage: "talker", replica: 1, req: 2, t: 0.2, wait_s: 0.3 });
        let rep = r.report(1.0, None);
        // Per-replica views stay distinct...
        assert_eq!(rep.sched_replica_count("talker"), 2);
        assert!((rep.sched_replica("talker", 0).unwrap().queue_depth.mean() - 4.0).abs() < 1e-9);
        assert!((rep.sched_replica("talker", 1).unwrap().queue_depth.mean() - 0.0).abs() < 1e-9);
        // ...while the stage-level view merges them.
        assert!((rep.sched_mean_queue_depth("talker") - 2.0).abs() < 1e-9);
        assert_eq!(rep.sched["talker"].admitted, 2);
        assert!((rep.sched_mean_admit_wait("talker") - 0.2).abs() < 1e-9);
        assert!(rep.sched_replica("talker", 2).is_none());
    }

    #[test]
    fn scale_events_recorded_and_classified() {
        let r = Recorder::new();
        r.emit(Event::Scale { stage: "talker".into(), t: 0.5, from: 1, to: 2 });
        r.emit(Event::Scale { stage: "talker".into(), t: 2.0, from: 2, to: 1 });
        r.emit(Event::Scale { stage: "thinker".into(), t: 1.0, from: 1, to: 2 });
        let rep = r.report(3.0, None);
        assert_eq!(rep.scale_events.len(), 3);
        assert_eq!(rep.scale_ups(None), 2);
        assert_eq!(rep.scale_downs(None), 1);
        assert_eq!(rep.scale_ups(Some("talker")), 1);
        assert_eq!(rep.scale_downs(Some("thinker")), 0);
        // Events come back time-sorted regardless of emission order.
        assert!(rep.scale_events.windows(2).all(|w| w[0].t <= w[1].t));
        assert_eq!(rep.replica_timeline("talker"), vec![(0.0, 1), (0.5, 2), (2.0, 1)]);
    }

    #[test]
    fn cache_stats_keep_the_latest_snapshot_per_replica() {
        let r = Recorder::new();
        let early = CacheCounters { prefix_hits: 1, prefix_misses: 5, ..Default::default() };
        let late = CacheCounters { prefix_hits: 8, prefix_misses: 8, evictions: 2, ..Default::default() };
        // Counters are absolute: the second emission REPLACES the first.
        r.emit(Event::CacheStats { stage: "decode", replica: 0, t: 0.1, counters: early });
        r.emit(Event::CacheStats { stage: "decode", replica: 0, t: 0.9, counters: late });
        // A second replica and an encoder stage sum into the rollups.
        r.emit(Event::CacheStats {
            stage: "decode",
            replica: 1,
            t: 0.9,
            counters: CacheCounters { prefix_hits: 2, prefix_misses: 2, ..Default::default() },
        });
        r.emit(Event::CacheStats {
            stage: "encoder",
            replica: 0,
            t: 0.9,
            counters: CacheCounters { encoder_hits: 3, encoder_misses: 1, ..Default::default() },
        });
        let rep = r.report(1.0, None);
        assert_eq!(rep.cache["decode"].prefix_hits, 10);
        assert_eq!(rep.cache["decode"].prefix_misses, 10);
        assert_eq!(rep.cache["decode"].evictions, 2);
        assert!((rep.cache["decode"].prefix_hit_rate() - 0.5).abs() < 1e-9);
        assert!((rep.cache["encoder"].encoder_hit_rate() - 0.75).abs() < 1e-9);
        let tot = rep.cache_totals();
        assert_eq!(tot.prefix_hits, 10);
        assert_eq!(tot.encoder_hits, 3);
        // A counter-less run reports an empty map and zero rates.
        let empty = Recorder::new().report(1.0, None);
        assert!(empty.cache.is_empty());
        assert_eq!(empty.cache_totals().prefix_hit_rate(), 0.0);
    }

    #[test]
    fn first_output_not_overwritten() {
        let r = Recorder::new();
        r.emit(Event::Arrived { req: 1, t: 0.0, deadline: None });
        r.emit(Event::StageAdmit { req: 1, stage: "s", t: 0.0 });
        r.emit(Event::StageFirstOutput { req: 1, stage: "s", t: 0.25 });
        r.emit(Event::StageFirstOutput { req: 1, stage: "s", t: 0.9 });
        r.emit(Event::StageDone { req: 1, stage: "s", t: 1.0, tokens: 1 });
        r.emit(Event::Completed { req: 1, t: 1.0 });
        let rep = r.report(1.0, None);
        assert!((rep.mean_ttft() - 0.25).abs() < 1e-9);
    }
}
