//! Serving metrics (paper §4.1): JCT, RTF, TTFT, per-stage TPS, and the
//! per-stage time decomposition behind Fig. 7.
//!
//! Engines and the orchestrator emit [`Event`]s into a [`Recorder`]
//! (lock-protected, cheap); [`RunReport`] aggregates a finished run into
//! the numbers the bench harness prints.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::audio;
use crate::util::stats::Samples;

/// Lifecycle events for one request flowing through the stage graph.
#[derive(Debug, Clone)]
pub enum Event {
    /// Request entered the system (run-relative seconds).
    Arrived { req: u64, t: f64 },
    /// Request was admitted to a stage's engine.
    StageAdmit { req: u64, stage: &'static str, t: f64 },
    /// A stage produced its first output item for this request.
    StageFirstOutput { req: u64, stage: &'static str, t: f64 },
    /// A stage finished this request, having produced `tokens` items.
    StageDone { req: u64, stage: &'static str, t: f64, tokens: usize },
    /// Request fully completed.
    Completed { req: u64, t: f64 },
}

#[derive(Debug, Default, Clone)]
struct StageRec {
    admit: Option<f64>,
    first: Option<f64>,
    done: Option<f64>,
    tokens: usize,
}

#[derive(Debug, Default, Clone)]
struct ReqRec {
    arrived: Option<f64>,
    completed: Option<f64>,
    stages: HashMap<&'static str, StageRec>,
}

/// Thread-safe event sink.
#[derive(Debug, Default)]
pub struct Recorder {
    inner: Mutex<HashMap<u64, ReqRec>>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn emit(&self, e: Event) {
        let mut m = self.inner.lock().unwrap();
        match e {
            Event::Arrived { req, t } => {
                m.entry(req).or_default().arrived = Some(t);
            }
            Event::StageAdmit { req, stage, t } => {
                m.entry(req).or_default().stages.entry(stage).or_default().admit = Some(t);
            }
            Event::StageFirstOutput { req, stage, t } => {
                let s = m.entry(req).or_default().stages.entry(stage).or_default();
                if s.first.is_none() {
                    s.first = Some(t);
                }
            }
            Event::StageDone { req, stage, t, tokens } => {
                let s = m.entry(req).or_default().stages.entry(stage).or_default();
                s.done = Some(t);
                s.tokens = tokens;
            }
            Event::Completed { req, t } => {
                m.entry(req).or_default().completed = Some(t);
            }
        }
    }

    /// Aggregate into a [`RunReport`].  `audio_stage` names the stage whose
    /// token count measures generated audio (for RTF); `None` = no audio.
    pub fn report(&self, wall_s: f64, audio_stage: Option<&str>) -> RunReport {
        let m = self.inner.lock().unwrap();
        let mut jct = Samples::new();
        let mut ttft = Samples::new();
        let mut rtf = Samples::new();
        let mut per_stage: HashMap<String, StageAgg> = HashMap::new();
        let mut completed = 0usize;

        for rec in m.values() {
            let (Some(a), Some(c)) = (rec.arrived, rec.completed) else { continue };
            completed += 1;
            jct.push(c - a);
            // TTFT: first output of the LAST stage that produced anything.
            if let Some(first) = rec
                .stages
                .values()
                .filter_map(|s| s.first)
                .fold(None::<f64>, |acc, t| Some(acc.map_or(t, |x| x.max(t))))
            {
                ttft.push(first - a);
            }
            for (name, s) in &rec.stages {
                let agg = per_stage.entry(name.to_string()).or_default();
                if let (Some(ad), Some(dn)) = (s.admit, s.done) {
                    agg.time.push(dn - ad);
                    agg.tokens += s.tokens;
                    agg.requests += 1;
                }
            }
            if let Some(stage) = audio_stage {
                if let Some(s) = rec.stages.get(stage) {
                    if s.tokens > 0 {
                        rtf.push(audio::rtf(c - a, s.tokens));
                    }
                }
            }
        }

        RunReport { wall_s, completed, jct, ttft, rtf, per_stage }
    }
}

#[derive(Debug, Default, Clone)]
pub struct StageAgg {
    /// Per-request residence time in the stage (admit -> done).
    pub time: Samples,
    pub tokens: usize,
    pub requests: usize,
}

/// Aggregated results for one benchmark run.
#[derive(Debug)]
pub struct RunReport {
    pub wall_s: f64,
    pub completed: usize,
    pub jct: Samples,
    pub ttft: Samples,
    pub rtf: Samples,
    pub per_stage: HashMap<String, StageAgg>,
}

impl RunReport {
    pub fn mean_jct(&self) -> f64 {
        self.jct.mean()
    }

    pub fn mean_rtf(&self) -> f64 {
        self.rtf.mean()
    }

    pub fn mean_ttft(&self) -> f64 {
        self.ttft.mean()
    }

    /// Aggregate tokens-per-second for a stage over the whole run
    /// (the paper's Thinker/Talker TPS metric).
    pub fn stage_tps(&self, stage: &str) -> f64 {
        match self.per_stage.get(stage) {
            Some(agg) if self.wall_s > 0.0 => agg.tokens as f64 / self.wall_s,
            _ => 0.0,
        }
    }

    /// Mean per-request residence time for a stage (Fig. 7 decomposition).
    pub fn stage_mean_time(&self, stage: &str) -> f64 {
        self.per_stage.get(stage).map(|a| a.time.mean()).unwrap_or(0.0)
    }

    pub fn stage_tokens(&self, stage: &str) -> usize {
        self.per_stage.get(stage).map(|a| a.tokens).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_lifecycle() {
        let r = Recorder::new();
        r.emit(Event::Arrived { req: 1, t: 0.0 });
        r.emit(Event::StageAdmit { req: 1, stage: "thinker", t: 0.1 });
        r.emit(Event::StageFirstOutput { req: 1, stage: "thinker", t: 0.2 });
        r.emit(Event::StageDone { req: 1, stage: "thinker", t: 1.1, tokens: 10 });
        r.emit(Event::StageAdmit { req: 1, stage: "talker", t: 0.3 });
        r.emit(Event::StageFirstOutput { req: 1, stage: "talker", t: 0.5 });
        r.emit(Event::StageDone { req: 1, stage: "talker", t: 2.0, tokens: 100 });
        r.emit(Event::Completed { req: 1, t: 2.0 });
        let rep = r.report(2.0, Some("talker"));
        assert_eq!(rep.completed, 1);
        assert!((rep.mean_jct() - 2.0).abs() < 1e-9);
        // RTF: 2 s processing / (100 tokens / 50 Hz = 2 s audio) = 1.0
        assert!((rep.mean_rtf() - 1.0).abs() < 1e-9);
        assert!((rep.stage_tps("talker") - 50.0).abs() < 1e-9);
        assert!((rep.stage_mean_time("thinker") - 1.0).abs() < 1e-9);
        // TTFT = last stage's first output = 0.5
        assert!((rep.mean_ttft() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn incomplete_requests_excluded() {
        let r = Recorder::new();
        r.emit(Event::Arrived { req: 1, t: 0.0 });
        let rep = r.report(1.0, None);
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.jct.len(), 0);
    }

    #[test]
    fn first_output_not_overwritten() {
        let r = Recorder::new();
        r.emit(Event::Arrived { req: 1, t: 0.0 });
        r.emit(Event::StageAdmit { req: 1, stage: "s", t: 0.0 });
        r.emit(Event::StageFirstOutput { req: 1, stage: "s", t: 0.25 });
        r.emit(Event::StageFirstOutput { req: 1, stage: "s", t: 0.9 });
        r.emit(Event::StageDone { req: 1, stage: "s", t: 1.0, tokens: 1 });
        r.emit(Event::Completed { req: 1, t: 1.0 });
        let rep = r.report(1.0, None);
        assert!((rep.mean_ttft() - 0.25).abs() < 1e-9);
    }
}
