//! Synthetic dataset trace generators (paper §4.1 workloads).
//!
//! Each generator is deterministic in `(seed, n)` and produces length
//! distributions matching the paper's reported statistics scaled by
//! [`super::SCALE`].  The audio:text output ratio for Qwen-Omni tasks is
//! pinned to the paper's 545.4 / 150.9 ≈ 3.6x, which is what makes the
//! Talker stage dominate Fig. 7.

use super::{Modality, Request, Workload};
use crate::util::Prng;

/// Hard cap derived from the compiled models (max_seq 256, prefill head-
/// room for generation).
const MAX_INPUT: f64 = 200.0;

fn mk(
    rng: &mut Prng,
    id: u64,
    arrival_s: f64,
    modality: Modality,
    text_in_med: f64,
    mm_frames_med: f64,
    text_out_med: f64,
    audio_ratio: f64,
) -> Request {
    let text_in = rng.lognormal_clamped(text_in_med, 0.35, 4.0, 64.0) as usize;
    let mm = if mm_frames_med > 0.0 {
        rng.lognormal_clamped(mm_frames_med, 0.25, 8.0, 128.0) as usize
    } else {
        0
    };
    let text_in = text_in.min((MAX_INPUT as usize).saturating_sub(mm).max(4));
    let text_out = rng.lognormal_clamped(text_out_med, 0.4, 4.0, 72.0) as usize;
    let audio_out = if audio_ratio > 0.0 {
        ((text_out as f64 * audio_ratio) as usize).clamp(8, 232)
    } else {
        0
    };
    // Deterministic synthetic prompt tokens (BOS + hashed ids).
    let vocab = 4096u64;
    let mut toks = vec![crate::tokenizer::BOS_ID];
    for _ in 1..text_in {
        toks.push((crate::tokenizer::FIRST_ID as u64 + rng.below(vocab - 8)) as u32);
    }
    Request {
        id,
        arrival_s,
        modality,
        prompt_tokens: toks,
        mm_frames: mm,
        seed: rng.next_u64(),
        max_text_tokens: text_out,
        max_audio_tokens: audio_out,
        diffusion_steps: 0,
        ignore_eos: true,
    }
}

/// Poisson arrivals at `rate` req/s; `rate <= 0` = all at t=0 (offline
/// batch inference, the paper's evaluation mode).
fn arrivals(rng: &mut Prng, n: usize, rate: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0;
    for _ in 0..n {
        if rate > 0.0 {
            t += rng.exponential(rate);
        }
        out.push(t);
    }
    out
}

/// librispeech_asr sim: audio input -> text + speech answer.
pub fn librispeech(seed: u64, n: usize, rate: f64) -> Workload {
    let mut rng = Prng::new(seed ^ 0xA01);
    let at = arrivals(&mut rng, n, rate);
    let requests = (0..n)
        .map(|i| mk(&mut rng, i as u64, at[i], Modality::Audio, 12.0, 64.0, 30.0, 3.6))
        .collect();
    Workload { name: "librispeech_asr-sim".into(), requests }
}

/// food101 sim: image input -> spoken description.
pub fn food101(seed: u64, n: usize, rate: f64) -> Workload {
    let mut rng = Prng::new(seed ^ 0xF00D);
    let at = arrivals(&mut rng, n, rate);
    let requests = (0..n)
        .map(|i| mk(&mut rng, i as u64, at[i], Modality::Image, 14.0, 36.0, 34.0, 3.6))
        .collect();
    Workload { name: "food101-sim".into(), requests }
}

/// ucf101-subset sim: video input -> spoken description.  Matches the
/// paper's reported per-task averages x SCALE: input 841.6 -> ~210,
/// text out 150.9 -> ~38, audio out 545.4 -> ~136.
pub fn ucf101(seed: u64, n: usize, rate: f64) -> Workload {
    let mut rng = Prng::new(seed ^ 0x0CF1);
    let at = arrivals(&mut rng, n, rate);
    let requests = (0..n)
        .map(|i| mk(&mut rng, i as u64, at[i], Modality::Video, 26.0, 112.0, 38.0, 3.6))
        .collect();
    Workload { name: "ucf101-subset-sim".into(), requests }
}

/// SeedTTS sim (MiMo-Audio): text input -> audio tokens.
pub fn seedtts(seed: u64, n: usize, rate: f64) -> Workload {
    let mut rng = Prng::new(seed ^ 0x5EED);
    let at = arrivals(&mut rng, n, rate);
    let requests = (0..n)
        .map(|i| {
            let mut r =
                mk(&mut rng, i as u64, at[i], Modality::Text, 28.0, 0.0, 36.0, 3.8);
            // MiMo generates audio tokens directly from the backbone.
            r.max_text_tokens = r.max_audio_tokens;
            r
        })
        .collect();
    Workload { name: "seedtts-sim".into(), requests }
}

/// Bursty mixed-modality trace for the elastic-autoscaler evaluation
/// (paper §3: under live traffic the bottleneck stage *changes*; static
/// replica splits are wrong for half the trace).  Two bursts `gap_s`
/// apart: the first is analysis-heavy (video input, long Thinker
/// prefill+decode, almost no Talker work), the second is speech-heavy
/// (tiny Thinker work, long Talker audio generation).  Arrivals inside a
/// burst jitter within ~0.3 s.
pub fn bursty_mixed(seed: u64, n: usize, gap_s: f64) -> Workload {
    let mut rng = Prng::new(seed ^ 0xB0257);
    let first = n / 2;
    let requests = (0..n)
        .map(|i| {
            let analysis = i < first;
            let base = if analysis { 0.0 } else { gap_s };
            let at = base + rng.f64() * 0.3;
            if analysis {
                // Thinker-bound: mm-token dominated input, audio out
                // pinned near the 8-token floor.
                mk(&mut rng, i as u64, at, Modality::Video, 24.0, 100.0, 44.0, 0.05)
            } else {
                // Talker-bound: short prompt, long audio stream.
                mk(&mut rng, i as u64, at, Modality::Text, 10.0, 0.0, 6.0, 24.0)
            }
        })
        .collect();
    Workload { name: "bursty-mixed-sim".into(), requests }
}

/// Prefill-heavy mixed trace for the P/D-disaggregation evaluation
/// (paper §3.4): a dense online stream alternating analysis requests —
/// long multimodal prompts with near-floor answers, so the compute-bound
/// prefill phase dominates their work — with chat turns whose long
/// decodes are latency-bound.  In a fused engine the two phases fight:
/// every mixed iteration pays both phase dispatches, chat decodes convoy
/// behind prefill chunks, and long-decode requests pin batch slots that
/// arriving prompts then queue behind.  Split prefill/decode pools
/// suffer none of that, which is exactly what
/// `scheduler::sim::simulate_disagg` measures on this trace.
pub fn prefill_heavy(seed: u64, n: usize, rate: f64) -> Workload {
    let mut rng = Prng::new(seed ^ 0x9EF111);
    let at = arrivals(&mut rng, n, rate);
    let requests = (0..n)
        .map(|i| {
            if i % 2 == 0 {
                // Chat turn: tiny prompt, long decode.
                mk(&mut rng, i as u64, at[i], Modality::Text, 8.0, 0.0, 70.0, 0.0)
            } else {
                // Analysis: mm-token-dominated prompt, near-floor decode.
                mk(&mut rng, i as u64, at[i], Modality::Video, 20.0, 120.0, 8.0, 0.0)
            }
        })
        .collect();
    Workload { name: "prefill-heavy-sim".into(), requests }
}

/// Overload storm for the admission-control evaluation (ISSUE 6): a
/// sustained Poisson stream at `rate` req/s mixing short chat turns with
/// heavy multimodal analysis requests.  The cost variance is the point —
/// under 2–5x overload a FIFO queue lets doomed heavy requests convoy
/// cheap ones past their deadlines and burns service time on work that
/// is cancelled mid-flight, which is exactly the behavior
/// `scheduler::sim::simulate_admission` quantifies.  Per-request SLOs
/// are derived deterministically from `Request::seed` by the sim (the
/// trace schema itself carries no deadline).
pub fn overload_storm(seed: u64, n: usize, rate: f64) -> Workload {
    let mut rng = Prng::new(seed ^ 0x57012);
    let at = arrivals(&mut rng, n, rate);
    let requests = (0..n)
        .map(|i| {
            if i % 4 == 3 {
                // Heavy analysis: mm-dominated prompt, long spoken answer.
                mk(&mut rng, i as u64, at[i], Modality::Video, 22.0, 110.0, 40.0, 3.6)
            } else {
                // Chat turn: small prompt, short answer.
                mk(&mut rng, i as u64, at[i], Modality::Text, 10.0, 0.0, 12.0, 1.0)
            }
        })
        .collect();
    Workload { name: "overload-storm-sim".into(), requests }
}

/// Shared-prefix trace for the prefix/encoder-cache evaluation (ISSUE
/// 7): a live stream where `hot_frac` of the requests replay one of four
/// fixed "agent templates" — a class-specific system prompt of 40–64
/// tokens AND a class-specific media clip — followed by a unique user
/// tail.  Repeats of a class re-prefill the identical block-aligned
/// prompt prefix (the KV prefix cache's hit population) and re-encode
/// the identical clip (the encoder cache's hit population: the media
/// seed is pinned per class, so the synthesized features are
/// byte-identical).  The remaining requests are cold one-off chats.
/// `scheduler::sim::simulate_prefix_cache` serves this trace cached vs
/// cold at the same GPU budget.
pub fn shared_prefix(seed: u64, n: usize, rate: f64, hot_frac: f64) -> Workload {
    let hot_frac = hot_frac.clamp(0.0, 1.0);
    let mut rng = Prng::new(seed ^ 0x9F1C5);
    let at = arrivals(&mut rng, n, rate);
    const CLASSES: usize = 4;
    let vocab = 4096u64;
    // Per class: a fixed prompt prefix (40/48/56/64 tokens), a fixed
    // media seed, and a fixed clip length.  Drawn from a class-local rng
    // so the templates are independent of `n` and the arrival stream.
    let classes: Vec<(Vec<u32>, u64, usize)> = (0..CLASSES)
        .map(|c| {
            let mut crng = Prng::new(seed ^ 0xC1A55 ^ (c as u64).wrapping_mul(0x9E37_79B9));
            let plen = 40 + c * 8;
            let mut toks = vec![crate::tokenizer::BOS_ID];
            for _ in 1..plen {
                toks.push((crate::tokenizer::FIRST_ID as u64 + crng.below(vocab - 8)) as u32);
            }
            (toks, crng.next_u64(), 24 + c * 8)
        })
        .collect();
    let requests = (0..n)
        .map(|i| {
            let hot = rng.f64() < hot_frac;
            let tail = 8 + rng.below(17) as usize;
            let text_out = 16 + rng.below(25) as usize;
            if hot {
                let (ptoks, media_seed, mm) = &classes[rng.below(CLASSES as u64) as usize];
                let mut toks = ptoks.clone();
                for _ in 0..tail {
                    toks.push((crate::tokenizer::FIRST_ID as u64 + rng.below(vocab - 8)) as u32);
                }
                Request {
                    id: i as u64,
                    arrival_s: at[i],
                    modality: Modality::Video,
                    prompt_tokens: toks,
                    mm_frames: *mm,
                    seed: *media_seed,
                    max_text_tokens: text_out,
                    max_audio_tokens: 0,
                    diffusion_steps: 0,
                    ignore_eos: true,
                }
            } else {
                // Cold one-off chat: unique prompt, unique media seed.
                let mut r = mk(&mut rng, i as u64, at[i], Modality::Text, 16.0, 0.0, 24.0, 0.0);
                r.max_text_tokens = text_out;
                r
            }
        })
        .collect();
    Workload { name: "shared-prefix-sim".into(), requests }
}

/// Branching fan-out trace (ISSUE 9): every request is ONE prompt whose
/// answer is BOTH an image and a spoken reply — the stage graph forks
/// after the shared thinker prefill into a parallel DiT arm (budgeted by
/// `diffusion_steps`) and a talker→vocoder arm (budgeted by
/// `max_audio_tokens`).  The image arm dominates per-request work, which
/// is what lets fractional packing's extra DiT replica pay off in
/// `scheduler::sim::fractional_comparison`.
pub fn branching_fanout(seed: u64, n: usize, rate: f64, steps: usize) -> Workload {
    let mut rng = Prng::new(seed ^ 0xB4A9C);
    let at = arrivals(&mut rng, n, rate);
    let requests = (0..n)
        .map(|i| {
            let mut r =
                mk(&mut rng, i as u64, at[i], Modality::Text, 18.0, 0.0, 16.0, 2.4);
            r.diffusion_steps = steps;
            r
        })
        .collect();
    Workload { name: "branching-fanout-sim".into(), requests }
}

/// VBench sim: text (or image) prompts for DiT image/video generation.
pub fn vbench(seed: u64, n: usize, rate: f64, steps: usize, image_cond: bool) -> Workload {
    let mut rng = Prng::new(seed ^ 0xBE9C);
    let at = arrivals(&mut rng, n, rate);
    let requests = (0..n)
        .map(|i| {
            let mut r = mk(
                &mut rng,
                i as u64,
                at[i],
                if image_cond { Modality::Image } else { Modality::Text },
                20.0,
                if image_cond { 32.0 } else { 0.0 },
                8.0,
                0.0,
            );
            r.diffusion_steps = steps;
            r.max_audio_tokens = 0;
            r
        })
        .collect();
    Workload { name: if image_cond { "vbench-i2x-sim".into() } else { "vbench-t2x-sim".into() }, requests }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;

    #[test]
    fn deterministic() {
        let a = ucf101(7, 20, 0.0);
        let b = ucf101(7, 20, 0.0);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.max_audio_tokens, y.max_audio_tokens);
        }
    }

    #[test]
    fn ucf_statistics_track_paper_shape() {
        let w = ucf101(1, 400, 0.0);
        // audio:text output ratio ~3.6 (paper: 545.4 / 150.9).
        let ratio = w.avg_audio_out() / w.avg_text_out();
        assert!((3.0..4.2).contains(&ratio), "ratio {ratio}");
        // video tasks are mm-token dominated, like the paper's 841.6 avg.
        assert!(w.avg_input_tokens() > 100.0);
        assert!(w.avg_input_tokens() < 200.0);
    }

    #[test]
    fn offline_mode_all_arrive_at_zero() {
        let w = librispeech(3, 10, 0.0);
        assert!(w.requests.iter().all(|r| r.arrival_s == 0.0));
    }

    #[test]
    fn online_mode_arrivals_increase() {
        let w = librispeech(3, 10, 5.0);
        for win in w.requests.windows(2) {
            assert!(win[1].arrival_s >= win[0].arrival_s);
        }
        assert!(w.requests.last().unwrap().arrival_s > 0.0);
    }

    #[test]
    fn bursty_trace_has_two_phases_with_opposite_bottlenecks() {
        let w = bursty_mixed(7, 40, 2.0);
        assert_eq!(w.len(), 40);
        let (a, b) = w.requests.split_at(20);
        // Phase 1 arrivals cluster near 0, phase 2 near the gap.
        assert!(a.iter().all(|r| r.arrival_s < 0.5));
        assert!(b.iter().all(|r| (2.0..2.5).contains(&r.arrival_s)));
        // Phase 1 is Thinker-bound: big inputs, near-floor audio budgets.
        let a_in: f64 = a.iter().map(|r| r.total_input_tokens() as f64).sum::<f64>() / 20.0;
        let b_in: f64 = b.iter().map(|r| r.total_input_tokens() as f64).sum::<f64>() / 20.0;
        assert!(a_in > 4.0 * b_in, "analysis input {a_in} vs speech input {b_in}");
        // Phase 2 is Talker-bound: audio budgets dwarf phase 1's.
        let a_audio: f64 = a.iter().map(|r| r.max_audio_tokens as f64).sum::<f64>() / 20.0;
        let b_audio: f64 = b.iter().map(|r| r.max_audio_tokens as f64).sum::<f64>() / 20.0;
        assert!(b_audio > 8.0 * a_audio, "speech audio {b_audio} vs analysis audio {a_audio}");
    }

    #[test]
    fn prefill_heavy_trace_alternates_phase_pressure() {
        let w = prefill_heavy(1, 40, 56.0);
        assert_eq!(w.len(), 40);
        let (chat, analysis): (Vec<_>, Vec<_>) =
            w.requests.iter().partition(|r| r.mm_frames == 0);
        assert_eq!(chat.len(), 20);
        // Chat turns are decode-bound, analysis requests prefill-bound.
        let c_in: f64 = chat.iter().map(|r| r.total_input_tokens() as f64).sum::<f64>() / 20.0;
        let a_in: f64 =
            analysis.iter().map(|r| r.total_input_tokens() as f64).sum::<f64>() / 20.0;
        assert!(a_in > 6.0 * c_in, "analysis input {a_in} vs chat input {c_in}");
        let c_out: f64 = chat.iter().map(|r| r.max_text_tokens as f64).sum::<f64>() / 20.0;
        let a_out: f64 = analysis.iter().map(|r| r.max_text_tokens as f64).sum::<f64>() / 20.0;
        assert!(c_out > 4.0 * a_out, "chat decode {c_out} vs analysis decode {a_out}");
        // Online by construction (the P/D comparison needs live pressure).
        assert!(w.requests.last().unwrap().arrival_s > 0.0);
    }

    #[test]
    fn overload_storm_mixes_cost_classes() {
        let w = overload_storm(1, 40, 80.0);
        assert_eq!(w.len(), 40);
        let (heavy, chat): (Vec<_>, Vec<_>) = w.requests.iter().partition(|r| r.mm_frames > 0);
        assert_eq!(heavy.len(), 10, "every 4th request is heavy analysis");
        let h_in: f64 =
            heavy.iter().map(|r| r.total_input_tokens() as f64).sum::<f64>() / heavy.len() as f64;
        let c_in: f64 =
            chat.iter().map(|r| r.total_input_tokens() as f64).sum::<f64>() / chat.len() as f64;
        assert!(h_in > 5.0 * c_in, "heavy input {h_in} vs chat input {c_in}");
        // Online by construction: admission control is a live-traffic policy.
        assert!(w.requests.last().unwrap().arrival_s > 0.0);
    }

    #[test]
    fn shared_prefix_replays_hot_prefixes_and_media() {
        let w = shared_prefix(1, 64, 0.0, 0.75);
        assert_eq!(w.len(), 64);
        // Hot requests carry a class clip; cold ones are plain chats.
        let hot: Vec<_> = w.requests.iter().filter(|r| r.mm_frames > 0).collect();
        assert!(hot.len() >= 32, "hot fraction collapsed: {}", hot.len());
        assert!(hot.len() < 64, "no cold requests at hot_frac 0.75");
        // One media seed == one template class: every member replays the
        // identical clip AND the identical >= 40-token prompt prefix,
        // with a unique tail.
        let mut classes: std::collections::HashMap<u64, Vec<&crate::trace::Request>> =
            Default::default();
        for &r in &hot {
            classes.entry(r.seed).or_default().push(r);
        }
        assert!(classes.len() <= 4, "more classes than templates");
        let mut repeats = 0usize;
        for members in classes.values() {
            if members.len() < 2 {
                continue;
            }
            repeats += members.len() - 1;
            let first = members[0];
            for r in members {
                assert_eq!(r.mm_frames, first.mm_frames, "clip length drifts within a class");
                assert_eq!(
                    &r.prompt_tokens[..40],
                    &first.prompt_tokens[..40],
                    "class prefix drifts"
                );
            }
            // Tails are unique user turns: some pair must differ.
            assert!(
                members.windows(2).any(|p| p[0].prompt_tokens != p[1].prompt_tokens),
                "tails are identical — nothing distinguishes the requests"
            );
        }
        assert!(repeats >= 8, "not enough prefix repeats to exercise the cache: {repeats}");
    }

    #[test]
    fn branching_fanout_requests_carry_both_arms() {
        let w = branching_fanout(5, 32, 12.0, 20);
        assert_eq!(w.len(), 32);
        for r in &w.requests {
            assert_eq!(r.diffusion_steps, 20, "image arm budget");
            assert!(r.max_audio_tokens >= 8, "speech arm budget");
            assert!(r.max_text_tokens > 0, "shared thinker decode");
        }
        assert!(w.requests.last().unwrap().arrival_s > 0.0, "online by construction");
    }

    #[test]
    fn prop_limits_respected() {
        quick("trace_limits", |rng| {
            let seed = rng.next_u64();
            let n = rng.range(1, 40);
            for w in [
                librispeech(seed, n, 0.0),
                food101(seed, n, 0.0),
                ucf101(seed, n, 0.0),
                seedtts(seed, n, 0.0),
                vbench(seed, n, 0.0, 20, false),
                bursty_mixed(seed, n, 2.0),
                prefill_heavy(seed, n, 56.0),
                overload_storm(seed, n, 80.0),
                shared_prefix(seed, n, 24.0, 0.75),
                branching_fanout(seed, n, 12.0, 20),
            ] {
                for r in &w.requests {
                    assert!(r.total_input_tokens() <= 210, "{}", r.total_input_tokens());
                    assert!(r.max_text_tokens <= 240);
                    assert!(r.max_audio_tokens <= 232);
                    assert!(!r.prompt_tokens.is_empty());
                }
            }
        });
    }
}
