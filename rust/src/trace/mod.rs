//! Workload substrate: requests and synthetic dataset trace generators.
//!
//! The paper evaluates on librispeech_asr / food101 / ucf101-subset (audio,
//! image, video inputs to Qwen-Omni), VBench prompts (image/video DiT
//! models), and SeedTTS (MiMo-Audio).  We have none of those corpora, so
//! [`datasets`] generates traces whose *token-count statistics* match the
//! numbers the paper reports (§4.2: avg video-task input 841.6 tokens,
//! text output 150.9, audio output 545.4 — scaled by the global
//! [`SCALE`] factor to fit the laptop-scale models; the 3.6x
//! audio:text output ratio that makes the Talker the bottleneck is
//! preserved exactly).

pub mod datasets;

/// Global token-count scale factor vs the paper's workloads (DESIGN.md §7).
pub const SCALE: f64 = 0.25;

/// Input modality of the multimodal part of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Modality {
    Text,
    Audio,
    Image,
    Video,
}

impl Modality {
    pub fn name(self) -> &'static str {
        match self {
            Modality::Text => "text",
            Modality::Audio => "audio",
            Modality::Image => "image",
            Modality::Video => "video",
        }
    }
}

/// A serving request, as produced by a trace generator and consumed by the
/// orchestrator frontend.  Fields are a superset across pipeline types;
/// each stage graph interprets the ones it needs.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival offset from the start of the run (seconds).
    pub arrival_s: f64,
    pub modality: Modality,
    /// Text prompt token ids (BOS included).
    pub prompt_tokens: Vec<u32>,
    /// Number of valid multimodal encoder frames (0 = no mm input).
    pub mm_frames: usize,
    /// Deterministic per-request seed for feature synthesis / sampling.
    pub seed: u64,
    /// Generation cap for the text (Thinker / backbone) stage.
    pub max_text_tokens: usize,
    /// Generation cap for the audio (Talker) stage; 0 for non-audio jobs.
    pub max_audio_tokens: usize,
    /// Denoising steps for DiT jobs; 0 for non-visual jobs.
    pub diffusion_steps: usize,
    /// Ignore EOS and always generate the caps (benchmark-controlled
    /// lengths; random-weight models have arbitrary EOS behaviour).
    pub ignore_eos: bool,
}

impl Request {
    pub fn total_input_tokens(&self) -> usize {
        self.prompt_tokens.len() + self.mm_frames
    }
}

/// A named, reproducible batch of requests.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub requests: Vec<Request>,
}

impl Workload {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn avg_input_tokens(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.total_input_tokens() as f64).sum::<f64>()
            / self.requests.len() as f64
    }

    pub fn avg_text_out(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.max_text_tokens as f64).sum::<f64>()
            / self.requests.len() as f64
    }

    pub fn avg_audio_out(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.max_audio_tokens as f64).sum::<f64>()
            / self.requests.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals() {
        let r = Request {
            id: 0,
            arrival_s: 0.0,
            modality: Modality::Video,
            prompt_tokens: vec![1, 5, 6],
            mm_frames: 10,
            seed: 0,
            max_text_tokens: 4,
            max_audio_tokens: 8,
            diffusion_steps: 0,
            ignore_eos: true,
        };
        assert_eq!(r.total_input_tokens(), 13);
    }
}
