//! Benchmark harness (the offline registry has no `criterion`): timing
//! helpers + paper-style table rendering shared by every `[[bench]]`
//! binary under `rust/benches/`.

use crate::util::fmt;
use crate::util::stats::Samples;

/// Measure a closure `iters` times after `warmup` runs; returns samples
/// of seconds.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

/// A paper-style result table builder.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let hdrs: Vec<&str> = self.headers.iter().map(|s| s.as_str()).collect();
        print!("{}", fmt::table(&hdrs, &self.rows));
    }
}

/// Format a mean ± stddev pair.
pub fn pm(s: &Samples) -> String {
    format!("{} ±{}", fmt::dur(s.mean()), fmt::dur(s.stddev()))
}

/// Format a speedup factor baseline/ours.
pub fn speedup(baseline: f64, ours: f64) -> String {
    if ours <= 0.0 {
        "-".into()
    } else {
        format!("{:.2}x", baseline / ours)
    }
}

/// Format a reduction percentage (paper reports "reduces JCT by 91.4%").
pub fn reduction_pct(baseline: f64, ours: f64) -> String {
    if baseline <= 0.0 {
        "-".into()
    } else {
        format!("{:.1}%", (1.0 - ours / baseline) * 100.0)
    }
}

/// Standard bench prologue: resolve artifacts or exit loudly.
pub fn load_artifacts() -> std::sync::Arc<crate::runtime::Artifacts> {
    let dir = crate::runtime::Artifacts::default_dir();
    match crate::runtime::Artifacts::load(&dir) {
        Ok(a) => std::sync::Arc::new(a),
        Err(e) => {
            eprintln!("cannot load artifacts from {}: {e}\nrun `make artifacts` first", dir.display());
            std::process::exit(2);
        }
    }
}

/// Honor `OMNI_BENCH_N` for request-count scaling (CI vs full runs).
pub fn bench_n(default: usize) -> usize {
    std::env::var("OMNI_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts() {
        let mut n = 0;
        let s = measure(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn format_helpers() {
        assert_eq!(speedup(2.0, 1.0), "2.00x");
        assert_eq!(reduction_pct(10.0, 1.0), "90.0%");
        assert_eq!(speedup(1.0, 0.0), "-");
    }
}
