//! Timing helpers used by engines, benches, and the metrics recorder.

use std::time::{Duration, Instant};

/// A stopwatch with lap support.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Self { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Seconds since the previous `lap()` (or construction).
    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }

    pub fn reset(&mut self) {
        let now = Instant::now();
        self.start = now;
        self.last = now;
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lap_accumulates() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(5));
        let lap = sw.lap();
        assert!(lap >= 0.004, "lap {lap}");
        assert!(sw.elapsed_s() >= lap);
    }

    #[test]
    fn timed_returns_value() {
        let (v, dt) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
