//! Human-friendly formatting for benchmark tables and logs.

/// Format seconds adaptively: `1.23s`, `45.6ms`, `789us`.
pub fn dur(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.0}us", secs * 1e6)
    }
}

/// Format a byte count: `1.5 GiB`, `23.4 MiB`, ...
pub fn bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = n as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{x:.1} {}", UNITS[u])
    }
}

/// Render an aligned ASCII table (used by every bench binary so the
/// output mirrors the paper's tables).
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        for (i, c) in cells.iter().enumerate().take(ncol) {
            if i > 0 {
                out.push_str("  ");
            }
            out.push_str(&format!("{:<w$}", c, w = widths[i]));
        }
        out.push('\n');
    };
    line(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    line(&mut out, &sep);
    for row in rows {
        line(&mut out, row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dur_ranges() {
        assert_eq!(dur(2.5), "2.50s");
        assert_eq!(dur(0.0456), "45.60ms");
        assert_eq!(dur(0.000789), "789us");
    }

    #[test]
    fn bytes_ranges() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.0 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn table_alignment() {
        let t = table(&["a", "bb"], &[vec!["x".into(), "y".into()]]);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("a"));
        assert!(lines[1].starts_with("-"));
    }
}
