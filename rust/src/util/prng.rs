//! Deterministic PRNG (SplitMix64 core + helpers).
//!
//! Every stochastic component in the system (workload generators, samplers,
//! property tests) takes an explicit [`Prng`] so runs are reproducible from
//! a single seed — a requirement for the benchmark harness, which must
//! produce identical workloads for the baseline and the disaggregated
//! system.

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (for per-request / per-thread use).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    /// Raw generator state, for carrying a sampler's position across a
    /// serialization boundary (e.g. a KV handoff between prefill and
    /// decode engines).  Restore with [`Prng::from_state`].
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Resume a stream captured with [`Prng::state`] — NOT the same as
    /// `new(state)` (which re-seeds).
    pub fn from_state(state: u64) -> Self {
        Self { state }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Prng::below(0)");
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // use 128-bit multiply for negligible bias.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal sample clamped to `[lo, hi]` — the shape used for the
    /// synthetic length distributions (token counts are heavy-tailed).
    pub fn lognormal_clamped(&mut self, median: f64, sigma: f64, lo: f64, hi: f64) -> f64 {
        let x = (median.ln() + sigma * self.normal()).exp();
        x.clamp(lo, hi)
    }

    /// Exponential inter-arrival sample with the given rate (per second).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = Prng::new(7);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Prng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Prng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(4);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_respects_clamp() {
        let mut r = Prng::new(5);
        for _ in 0..1000 {
            let x = r.lognormal_clamped(100.0, 1.0, 10.0, 200.0);
            assert!((10.0..=200.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
