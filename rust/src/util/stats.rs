//! Streaming statistics: mean/stddev accumulators and exact percentiles
//! over recorded samples.  Used by [`crate::metrics`] and the bench
//! harness ([`crate::bench_util`]).

/// Exact-percentile sample collection (keeps all samples; fine at the
/// scale of a bench run).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    /// Merge another collection's samples into this one (replica
    /// rollups; order is not meaningful for any statistic here).
    pub fn extend(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    pub fn stddev(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile via nearest-rank on the sorted samples.
    /// `p` in `[0, 100]`.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = ((p / 100.0) * (self.xs.len() as f64 - 1.0)).round() as usize;
        self.xs[rank.min(self.xs.len() - 1)]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Welford online mean/variance — allocation-free, for hot-loop counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact_small() {
        let mut s = Samples::new();
        for x in [5.0, 1.0, 3.0, 2.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn welford_matches_naive() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) * 0.37).collect();
        let mut w = Welford::default();
        let mut s = Samples::new();
        for &x in &xs {
            w.push(x);
            s.push(x);
        }
        assert!((w.mean() - s.mean()).abs() < 1e-9);
        assert!((w.stddev() - s.stddev()).abs() < 1e-9);
    }

    #[test]
    fn empty_is_zero() {
        let mut s = Samples::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p50(), 0.0);
    }
}
