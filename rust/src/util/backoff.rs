//! Bounded-backoff idle sleeping for *external* poll loops.
//!
//! [`Backoff`] escalates an idle wait: a few busy spins for
//! sub-microsecond reaction to bursts, then sleeps that double from
//! [`Backoff::MIN_SLEEP`] up to a hard cap, reset to zero the moment
//! any work appears.
//!
//! **No internal loop uses this anymore.**  Stage threads, the routed
//! edges, and the serving collector used to drive their non-blocking
//! receivers under a `Backoff` sleep; they now park on an
//! [`crate::event_core::WakeSet`] mailbox and are woken by the sender,
//! so the first item after an idle spell pays no backoff latency at
//! all.  The type is kept for two reasons only:
//!
//! * it is the *measured baseline* the event-core bench gate compares
//!   against — [`crate::event_core::replay::record_polling`] charges a
//!   dequeue delay sampled from exactly the `[MIN_SLEEP, MAX_SLEEP]`
//!   bounds below, and the tests here pin those bounds;
//! * it remains the right tool for a genuine *external* poll — a
//!   resource with no wake hook to register (e.g. a non-blocking TCP
//!   accept loop).  Today every TCP path blocks with an OS read
//!   timeout, so no such caller exists in-tree.

use std::time::Duration;

/// Escalating idle-wait state for one poll loop.
#[derive(Debug, Default)]
pub struct Backoff {
    /// Consecutive idle iterations since the last piece of work.
    idle: u32,
}

impl Backoff {
    /// Idle iterations served by a spin hint before sleeping starts.
    const SPINS: u32 = 4;
    /// First sleep after the spin phase.
    const MIN_SLEEP: Duration = Duration::from_micros(50);
    /// Ceiling on the per-iteration sleep (bounds worst-case added
    /// latency for the first item after an idle spell).
    const MAX_SLEEP: Duration = Duration::from_millis(2);

    pub fn new() -> Self {
        Self::default()
    }

    /// Record a productive iteration: the next idle wait restarts from
    /// the spin phase.
    pub fn reset(&mut self) {
        self.idle = 0;
    }

    /// Record an idle iteration and wait the escalated amount.
    pub fn idle_wait(&mut self) {
        let d = self.next_wait();
        match d {
            None => std::hint::spin_loop(),
            Some(d) => std::thread::sleep(d),
        }
    }

    /// The wait the *next* idle iteration will use (`None` = spin hint).
    /// Split from [`Self::idle_wait`] so tests can observe the schedule
    /// without actually sleeping.
    pub fn next_wait(&mut self) -> Option<Duration> {
        let idle = self.idle;
        self.idle = self.idle.saturating_add(1);
        if idle < Self::SPINS {
            return None;
        }
        let exp = (idle - Self::SPINS).min(16);
        let d = Self::MIN_SLEEP.saturating_mul(1u32 << exp);
        Some(d.min(Self::MAX_SLEEP))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escalates_from_spins_to_capped_sleeps() {
        let mut b = Backoff::new();
        // Spin phase.
        for _ in 0..4 {
            assert_eq!(b.next_wait(), None);
        }
        // Doubling sleeps from MIN_SLEEP...
        assert_eq!(b.next_wait(), Some(Duration::from_micros(50)));
        assert_eq!(b.next_wait(), Some(Duration::from_micros(100)));
        assert_eq!(b.next_wait(), Some(Duration::from_micros(200)));
        // ...bounded by MAX_SLEEP no matter how long the idle spell.
        for _ in 0..40 {
            let d = b.next_wait().unwrap();
            assert!(d <= Duration::from_millis(2));
        }
        assert_eq!(b.next_wait(), Some(Duration::from_millis(2)));
    }

    #[test]
    fn reset_restarts_the_schedule() {
        let mut b = Backoff::new();
        for _ in 0..10 {
            let _ = b.next_wait();
        }
        b.reset();
        assert_eq!(b.next_wait(), None, "work resets to the spin phase");
    }
}
