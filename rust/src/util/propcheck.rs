//! Mini property-testing driver.
//!
//! The offline registry has no `proptest`/`quickcheck`, so this module
//! provides the subset we need: run a property over many deterministic
//! PRNG-seeded cases and, on failure, report the failing seed so the case
//! can be replayed under a debugger.  No shrinking — cases are generated
//! from a seed, so re-running with the printed seed reproduces exactly.

use super::prng::Prng;

/// Number of cases per property (kept moderate: properties run under
/// `cargo test` alongside integration tests).
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` over `cases` deterministic PRNG streams.  Panics with the
/// failing seed on the first violation.
pub fn check<F: FnMut(&mut Prng)>(name: &str, cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Prng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Shorthand with [`DEFAULT_CASES`].
pub fn quick<F: FnMut(&mut Prng)>(name: &str, prop: F) {
    check(name, DEFAULT_CASES, prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        quick("x_lt_n", |rng| {
            let n = rng.range(1, 100);
            let x = rng.below(n as u64);
            assert!((x as usize) < n);
        });
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn reports_failing_seed() {
        check("always_fails", 4, |_rng| {
            panic!("boom");
        });
    }

    #[test]
    fn is_deterministic() {
        let mut first: Vec<u64> = vec![];
        check("collect", 8, |rng| first.push(rng.next_u64()));
        let mut second: Vec<u64> = vec![];
        check("collect", 8, |rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }
}
