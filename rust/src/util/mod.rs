//! Small shared utilities: deterministic PRNG, timing, stats, and a
//! mini property-testing driver (the offline registry has no `proptest`,
//! so we ship our own — see [`propcheck`]).

pub mod backoff;
pub mod fmt;
pub mod propcheck;
pub mod prng;
pub mod stats;
pub mod timer;

pub use backoff::Backoff;
pub use prng::Prng;
pub use timer::Stopwatch;
