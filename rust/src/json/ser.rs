//! JSON serializer: compact (wire protocol) and pretty (configs, reports).

use super::Value;

pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v, None, 0);
    out
}

pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v, Some(2), 0);
    out
}

fn write_value(out: &mut String, v: &Value, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(out, *n),
        Value::Str(s) => write_str(out, s),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(out, item, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Value::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_str(out, k);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(out, val, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; degrade to null like serde_json's default.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;
    use crate::jobj;

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"a":[1,2.5,"x\n",true,null],"b":{"c":{}}}"#;
        let v = parse(src).unwrap();
        assert_eq!(to_string(&v), src);
    }

    #[test]
    fn roundtrip_pretty_reparses() {
        let v = jobj! { "k" => vec![1i64, 2, 3], "s" => "line\nbreak" };
        let pretty = to_string_pretty(&v);
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_stay_integral() {
        assert_eq!(to_string(&Value::Num(42.0)), "42");
        assert_eq!(to_string(&Value::Num(0.5)), "0.5");
        assert_eq!(to_string(&Value::Num(-3.0)), "-3");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
    }

    #[test]
    fn control_chars_escaped() {
        let s = to_string(&Value::Str("\u{0001}".into()));
        assert_eq!(s, "\"\\u0001\"");
    }
}
