//! Recursive-descent JSON parser (RFC 8259 subset we need; rejects
//! trailing garbage, reports line/column on error).

use super::Value;
use std::collections::BTreeMap;

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at line {}, col {}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after JSON value"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        let (mut line, mut col) = (1, 1);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        ParseError { line, col, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected `{word}`)")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err(&format!("invalid number `{s}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, {"b": [true, null]}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(1).get("b").idx(0), &Value::Bool(true));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\Aé");
    }

    #[test]
    fn surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("tru").is_err());
    }

    #[test]
    fn error_position() {
        let e = parse("{\n  \"a\": !\n}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.col >= 8, "{e}");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
        assert_eq!(parse(" [ ] ").unwrap(), Value::Arr(vec![]));
    }
}
