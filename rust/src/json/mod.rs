//! Minimal JSON substrate (the offline registry has no `serde_json`).
//!
//! Covers everything the system needs: the artifact manifest, config
//! files, the TCP serving protocol, and bench output.  Full RFC 8259
//! parsing (strings with escapes, nested containers, numbers, literals)
//! plus a compact/pretty serializer.

mod parse;
mod ser;

pub use parse::{parse, ParseError};
pub use ser::{to_string, to_string_pretty};

use std::collections::BTreeMap;

/// A JSON value.  Objects use `BTreeMap` for deterministic ordering
/// (reproducible serialization matters for config hashing).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Value::Null` for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; `Value::Null` out of range.
    pub fn idx(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Required-field helpers that produce good error messages.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Value]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field `{key}`"))
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}

impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}

impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Object construction macro used across configs and the server protocol.
#[macro_export]
macro_rules! jobj {
    ( $( $k:expr => $v:expr ),* $(,)? ) => {{
        let mut m = std::collections::BTreeMap::new();
        $( m.insert($k.to_string(), $crate::json::Value::from($v)); )*
        $crate::json::Value::Obj(m)
    }};
}

/// Parse a file as JSON.
pub fn from_file(path: &std::path::Path) -> anyhow::Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = parse(r#"{"a": [1, "two", true, null], "b": {"c": 3.5}}"#).unwrap();
        assert_eq!(v.get("a").idx(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").idx(1).as_str(), Some("two"));
        assert_eq!(v.get("a").idx(2).as_bool(), Some(true));
        assert!(v.get("a").idx(3).is_null());
        assert!(v.get("a").idx(9).is_null());
        assert_eq!(v.get("b").get("c").as_f64(), Some(3.5));
        assert!(v.get("zzz").is_null());
    }

    #[test]
    fn jobj_macro() {
        let v = jobj! { "x" => 1usize, "s" => "hi", "f" => 2.5f64 };
        assert_eq!(v.get("x").as_usize(), Some(1));
        assert_eq!(v.get("s").as_str(), Some("hi"));
    }

    #[test]
    fn req_errors_mention_key() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        let err = v.req_str("missing").unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");
    }
}
