//! Monolithic baseline (paper §4.1 "Baseline Systems").
//!
//! Reproduces the behaviour of the HF-Transformers / original-repo
//! implementations the paper compares against:
//! * request-at-a-time (no continuous batching, batch size 1),
//! * full stage barriers (the Talker waits for the complete Thinker
//!   output; the Vocoder for the complete Talker output),
//! * co-located execution in one thread (no per-stage devices),
//! * optional lazy compilation (the eager-mode analog: the paper notes
//!   the Qwen3 baseline "does not fully exploit ... execution graph
//!   compilation"), and
//! * no streaming, no chunked prefill, no step cache.
//!
//! It runs the SAME artifacts as the disaggregated system, so measured
//! gaps are attributable to serving policy, not model differences.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{PipelineConfig, StageKind};
use crate::engine::ar::{ArEngine, ArEngineOptions, Preprocess};
use crate::engine::diffusion::{DiffusionEngine, DiffusionJob, DiffusionOptions};
use crate::engine::vocoder::{VocoderEngine, VocoderJob, VocoderKind};
use crate::engine::StageItem;
use crate::metrics::{Event, Recorder, RunReport};
use crate::orchestrator::RunClock;
use crate::runtime::Artifacts;
use crate::stage_graph::transfers::codec_features;
use crate::trace::Workload;

/// Baseline knobs.
#[derive(Debug, Clone)]
pub struct BaselineOptions {
    /// Recompile executables per call (HF-eager analog).  The paper's
    /// Qwen2.5 baseline is closer to compiled (False); Qwen3's larger
    /// model is where the missing graph compilation hurts (True).
    pub lazy_compile: bool,
    /// Disable the KV cache: recompute the full prefix every decode step
    /// (worst-case naive implementation; ablation only).
    pub no_kv_cache: bool,
}

impl Default for BaselineOptions {
    fn default() -> Self {
        Self { lazy_compile: false, no_kv_cache: false }
    }
}

/// Serve `workload` through `config`'s stages strictly serially.
/// Returns the same [`RunReport`] shape as the disaggregated runner.
pub fn run_monolithic(
    artifacts: &Arc<Artifacts>,
    config: &PipelineConfig,
    workload: &Workload,
    opts: &BaselineOptions,
    audio_stage: Option<&'static str>,
) -> Result<RunReport> {
    let recorder = Recorder::new();
    let clock = RunClock::new();

    // Build batch-1, barrier-mode engines once (weights stay resident —
    // the baselines do keep weights on device).
    let mut ars: Vec<(usize, &'static str, ArEngine)> = vec![];
    let mut dits: Vec<(usize, &'static str, DiffusionEngine)> = vec![];
    let mut vocs: Vec<(usize, &'static str, VocoderEngine)> = vec![];
    for (i, s) in config.stages.iter().enumerate() {
        let sname: &'static str = Box::leak(s.name.clone().into_boxed_str());
        match s.kind {
            StageKind::Ar => {
                let model = artifacts.model(&s.model)?;
                let cond_dim = model.cfg_usize("cond_dim").unwrap_or(0);
                ars.push((
                    i,
                    sname,
                    ArEngine::new(
                        artifacts,
                        &s.model,
                        ArEngineOptions {
                            max_batch: 1,
                            chunked_prefill: false,
                            multi_step: 1,
                            stream_chunk: 0,
                            preprocess: if cond_dim > 0 {
                                Preprocess::UpstreamMean
                            } else {
                                Preprocess::None
                            },
                            kv_blocks: 64,
                            kv_block_size: 16,
                            lazy_compile: opts.lazy_compile,
                            emit_hiddens: true,
                            role: crate::config::StageRole::Fused,
                        },
                    )?,
                ));
            }
            StageKind::Dit => dits.push((
                i,
                sname,
                DiffusionEngine::new(
                    artifacts,
                    &s.model,
                    DiffusionOptions {
                        max_batch: 1,
                        steps: s.diffusion.steps,
                        cfg_scale: s.diffusion.cfg_scale,
                        stepcache_threshold: 0.0, // baselines have no step cache
                        lazy_compile: opts.lazy_compile,
                    },
                )?,
            )),
            StageKind::CnnVocoder => vocs.push((
                i,
                sname,
                VocoderEngine::new(artifacts, &s.model, VocoderKind::Cnn, 1, opts.lazy_compile)?,
            )),
            StageKind::PatchDecoder => vocs.push((
                i,
                sname,
                VocoderEngine::new(
                    artifacts,
                    &s.model,
                    VocoderKind::PatchDecoder,
                    1,
                    opts.lazy_compile,
                )?,
            )),
            // The monolithic baseline always fuses the encoder into the
            // first AR stage (that is exactly what the HF implementations
            // do); a standalone encoder stage is skipped here.
            StageKind::Encoder => {}
        }
    }
    // Entry encoder for multimodal requests.
    let entry_model = &config.stages[0].model;
    let mut encoder = crate::orchestrator::encoder_model_for(entry_model)
        .filter(|m| artifacts.models.contains_key(*m))
        .map(|m| crate::runtime::StageRuntime::new(artifacts, m))
        .transpose()?;

    // Engine construction/compilation is excluded from request timing
    // (matching the disaggregated runner's ready barrier).
    clock.reset();

    // Offline batch evaluation (paper §4): every request is submitted at
    // t=0, so serial processing makes later requests' JCT include the
    // time spent on earlier ones.
    for req in &workload.requests {
        recorder.emit(Event::Arrived { req: req.id, t: 0.0, deadline: None });
    }

    // Strictly serial: one request at a time through all stages.
    for req in &workload.requests {

        // ---- stage chain, in config order (barrier between stages) ----
        let mut carry_tokens: Vec<u32> = vec![];
        let mut carry_hiddens: Vec<f32> = vec![];
        let mut carry_dim = 0usize;

        for (si, s) in config.stages.iter().enumerate() {
            let s_cfg_model = s.model.clone();
            match s.kind {
                StageKind::Ar => {
                    let (_, sname, eng) =
                        ars.iter_mut().find(|(i, _, _)| *i == si).unwrap();
                    let model_cond = eng.cond_dim();
                    recorder.emit(Event::StageAdmit { req: req.id, stage: sname, t: clock.now() });
                    let job = if si == 0 {
                        let d = artifacts.model(&s_cfg_model)?.cfg_usize("d_model")?;
                        baseline_entry_job(encoder.as_mut(), d, req, opts)?
                    } else {
                        // Downstream AR (Talker): BOS prompt + upstream
                        // hiddens as conditioning.
                        crate::engine::ar::token_job(
                            req.id,
                            &[crate::tokenizer::BOS_ID],
                            crate::engine::SamplingParams {
                                max_new_tokens: req.max_audio_tokens.max(1),
                                temperature: 0.0,
                                top_k: 0,
                                ignore_eos: req.ignore_eos,
                                seed: req.seed,
                            },
                        )
                    };
                    eng.submit(job);
                    if si > 0 && model_cond > 0 {
                        eng.push_upstream(req.id, &carry_hiddens, carry_dim.max(1), true);
                    }
                    let mut first = true;
                    let items = eng.run_to_completion()?;
                    let mut toks = vec![];
                    let mut hid = vec![];
                    for item in items {
                        if first {
                            recorder.emit(Event::StageFirstOutput {
                                req: req.id,
                                stage: sname,
                                t: clock.now(),
                            });
                            first = false;
                        }
                        if let Some(t) = item.tensor("tokens") {
                            toks.extend(t.as_i32()?.iter().map(|&x| x as u32));
                        }
                        if let Some(h) = item.tensor("hiddens") {
                            carry_dim = *h.shape.last().unwrap_or(&0);
                            hid.extend_from_slice(h.as_f32()?);
                        }
                    }
                    recorder.emit(Event::StageDone {
                        req: req.id,
                        stage: sname,
                        t: clock.now(),
                        tokens: toks.len(),
                    });
                    carry_tokens = toks;
                    carry_hiddens = hid;
                }
                StageKind::Dit => {
                    let (_, sname, eng) =
                        dits.iter_mut().find(|(i, _, _)| *i == si).unwrap();
                    recorder.emit(Event::StageAdmit { req: req.id, stage: sname, t: clock.now() });
                    let ctd = eng.cond_tokens_dim();
                    let jobs = if ctd > 0 {
                        // Vocoder DiT: chunk the carried codec tokens.
                        let cap = eng.n_tokens();
                        let mut jobs = vec![];
                        let mut idx = 0;
                        let chunks = carry_tokens.chunks(cap).collect::<Vec<_>>();
                        let n = chunks.len().max(1);
                        for ci in 0..n {
                            let chunk: &[u32] =
                                chunks.get(ci).copied().unwrap_or(&[]);
                            let mut ct = Vec::with_capacity(cap * ctd);
                            for i in 0..cap {
                                let tok = chunk.get(i).copied().unwrap_or(0);
                                ct.extend(codec_features(tok, ctd));
                            }
                            jobs.push(DiffusionJob {
                                req_id: req.id,
                                chunk_idx: idx,
                                cond: vec![],
                                cond_tokens: ct,
                                seed: req.seed ^ idx as u64,
                                steps: 0,
                                final_chunk: ci + 1 == n,
                            });
                            idx += 1;
                        }
                        jobs
                    } else {
                        // Image generator: mean hidden as conditioning.
                        let n = (carry_hiddens.len() / carry_dim.max(1)).max(1);
                        let cond: Vec<f32> = (0..carry_dim)
                            .map(|j| {
                                carry_hiddens
                                    .iter()
                                    .skip(j)
                                    .step_by(carry_dim.max(1))
                                    .sum::<f32>()
                                    / n as f32
                            })
                            .collect();
                        vec![DiffusionJob {
                            req_id: req.id,
                            chunk_idx: 0,
                            cond,
                            cond_tokens: vec![],
                            seed: req.seed,
                            steps: req.diffusion_steps,
                            final_chunk: true,
                        }]
                    };
                    let mut first = true;
                    let mut chunks = 0usize;
                    for job in jobs {
                        eng.submit(job);
                        let items = eng.run_to_completion()?;
                        for _ in &items {
                            chunks += 1;
                        }
                        if first && chunks > 0 {
                            recorder.emit(Event::StageFirstOutput {
                                req: req.id,
                                stage: sname,
                                t: clock.now(),
                            });
                            first = false;
                        }
                        let _ = items;
                    }
                    recorder.emit(Event::StageDone {
                        req: req.id,
                        stage: sname,
                        t: clock.now(),
                        tokens: chunks,
                    });
                }
                StageKind::Encoder => { /* fused into the entry AR stage */ }
                StageKind::CnnVocoder | StageKind::PatchDecoder => {
                    let (_, sname, eng) =
                        vocs.iter_mut().find(|(i, _, _)| *i == si).unwrap();
                    recorder.emit(Event::StageAdmit { req: req.id, stage: sname, t: clock.now() });
                    let cap = eng.frames_per_chunk();
                    let chunks: Vec<&[u32]> = if carry_tokens.is_empty() {
                        vec![&[]]
                    } else {
                        carry_tokens.chunks(cap).collect()
                    };
                    let n = chunks.len();
                    let mut first = true;
                    for (ci, chunk) in chunks.into_iter().enumerate() {
                        eng.submit(VocoderJob {
                            req_id: req.id,
                            chunk_idx: ci,
                            tokens: chunk.to_vec(),
                            final_chunk: ci + 1 == n,
                        });
                        let _items: Vec<StageItem> = eng.run_to_completion()?;
                        if first {
                            recorder.emit(Event::StageFirstOutput {
                                req: req.id,
                                stage: sname,
                                t: clock.now(),
                            });
                            first = false;
                        }
                    }
                    recorder.emit(Event::StageDone {
                        req: req.id,
                        stage: sname,
                        t: clock.now(),
                        tokens: carry_tokens.len(),
                    });
                }
            }
        }
        recorder.emit(Event::Completed { req: req.id, t: clock.now() });

        if opts.lazy_compile {
            // No cross-request execution-graph reuse: every request pays
            // compilation again (the missing "graph compilation" the paper
            // attributes the Qwen3 baseline gap to).
            for (_, _, e) in ars.iter_mut() {
                e.evict_compiled();
            }
            for (_, _, e) in dits.iter_mut() {
                e.evict_compiled();
            }
            for (_, _, e) in vocs.iter_mut() {
                e.evict_compiled();
            }
        }
    }

    Ok(recorder.report(clock.now(), audio_stage))
}

fn baseline_entry_job(
    encoder: Option<&mut crate::runtime::StageRuntime>,
    entry_d_model: usize,
    req: &crate::trace::Request,
    _opts: &BaselineOptions,
) -> Result<crate::engine::ar::ArJob> {
    use crate::engine::ar::PromptItem;
    use crate::runtime::HostTensor;
    use crate::util::Prng;

    let mut prompt: Vec<PromptItem> =
        req.prompt_tokens.iter().map(|&t| PromptItem::Token(t)).collect();
    let mut mm_embeds: Vec<f32> = vec![];
    let mut emb_dim = 0usize;
    if req.mm_frames > 0 {
        let Some(enc) = encoder else {
            // No dedicated encoder (BAGEL-style): synthetic reference-image
            // embeddings at the stage's width (matches orchestrator path).
            let mut prng = Prng::new(req.seed ^ 0x77E1);
            emb_dim = entry_d_model;
            mm_embeds
                .extend((0..req.mm_frames * emb_dim).map(|_| prng.normal() as f32 * 0.1));
            prompt.extend((0..req.mm_frames).map(PromptItem::Embed));
            return Ok(crate::engine::ar::ArJob {
                req_id: req.id,
                prompt,
                mm_embeds,
                emb_dim,
                sampling: crate::engine::SamplingParams {
                    max_new_tokens: req.max_text_tokens.max(1),
                    temperature: 0.0,
                    top_k: 0,
                    ignore_eos: req.ignore_eos,
                    seed: req.seed,
                },
            });
        };
        let spec_m = enc.model().clone();
        let t_max = spec_m.cfg_usize("t_max")?;
        let feat_dim = spec_m.cfg_usize("feat_dim")?;
        let d_out = spec_m.cfg_usize("d_out")?;
        let frames = req.mm_frames.min(t_max);
        let mut prng = Prng::new(req.seed ^ 0x33C0DE);
        let mut feats = vec![0f32; t_max * feat_dim];
        for x in feats.iter_mut().take(frames * feat_dim) {
            *x = prng.normal() as f32 * 0.5;
        }
        let mut mask = vec![0f32; t_max];
        for m in mask.iter_mut().take(frames) {
            *m = 1.0;
        }
        let entry = spec_m.bucket_entry("encode", 1, "")?;
        let outs = enc.run(
            &entry,
            &[
                HostTensor::f32(vec![1, t_max, feat_dim], feats),
                HostTensor::f32(vec![1, t_max], mask),
            ],
        )?;
        let embeds = outs[0].as_f32()?;
        emb_dim = d_out;
        mm_embeds.extend_from_slice(&embeds[..frames * d_out]);
        prompt.extend((0..frames).map(PromptItem::Embed));
    }
    Ok(crate::engine::ar::ArJob {
        req_id: req.id,
        prompt,
        mm_embeds,
        emb_dim,
        sampling: crate::engine::SamplingParams {
            max_new_tokens: req.max_text_tokens.max(1),
            temperature: 0.0,
            top_k: 0,
            ignore_eos: req.ignore_eos,
            seed: req.seed,
        },
    })
}
