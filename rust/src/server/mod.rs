//! TCP JSON-lines serving frontend (`omni-serve serve`).
//!
//! Protocol: one JSON object per line.
//!
//! request:  {"op": "generate", "prompt": "...", "modality": "video",
//!            "mm_frames": 64, "max_text_tokens": 32,
//!            "max_audio_tokens": 96}
//! response: {"req_id": N, "text": "...", "audio_tokens": M,
//!            "jct_s": 1.23}
//! request:  {"op": "ping"} -> {"ok": true}
//!
//! The server accepts connections on a listener thread and serves each
//! connection by running the request through a fresh single-request
//! workload on the shared orchestrator configuration.  (Per-connection
//! pipelines keep the demo server simple; the bench harness exercises
//! the long-lived orchestrator path.)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::config::PipelineConfig;
use crate::jobj;
use crate::json::{self, Value};
use crate::orchestrator::{Orchestrator, RunOptions};
use crate::runtime::Artifacts;
use crate::stage_graph::transfers::Registry;
use crate::tokenizer::Tokenizer;
use crate::trace::{Modality, Request, Workload};

pub struct Server {
    listener: TcpListener,
    config: PipelineConfig,
    artifacts: Arc<Artifacts>,
}

static NEXT_REQ: AtomicU64 = AtomicU64::new(1);

impl Server {
    pub fn bind(addr: &str, config: PipelineConfig, artifacts: Arc<Artifacts>) -> Result<Self> {
        Ok(Self { listener: TcpListener::bind(addr)?, config, artifacts })
    }

    pub fn addr(&self) -> String {
        self.listener.local_addr().map(|a| a.to_string()).unwrap_or_default()
    }

    /// Serve forever (blocking).  Each connection handled in turn — the
    /// underlying pipeline batches *within* a connection's workload.
    pub fn serve(&self) -> Result<()> {
        eprintln!("omni-serve listening on {}", self.addr());
        for conn in self.listener.incoming() {
            let Ok(stream) = conn else { continue };
            if let Err(e) = self.handle(stream) {
                eprintln!("connection error: {e}");
            }
        }
        Ok(())
    }

    /// Serve exactly `n` connections, then return (tests).
    pub fn serve_n(&self, n: usize) -> Result<()> {
        for conn in self.listener.incoming().take(n) {
            self.handle(conn?)?;
        }
        Ok(())
    }

    fn handle(&self, stream: TcpStream) -> Result<()> {
        let peer = stream.peer_addr().ok();
        let mut writer = stream.try_clone()?;
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let resp = match self.dispatch(&line) {
                Ok(v) => v,
                Err(e) => jobj! { "error" => e.to_string() },
            };
            writer.write_all(json::to_string(&resp).as_bytes())?;
            writer.write_all(b"\n")?;
        }
        let _ = peer;
        Ok(())
    }

    fn dispatch(&self, line: &str) -> Result<Value> {
        let v = json::parse(line).map_err(|e| anyhow::anyhow!("bad request JSON: {e}"))?;
        match v.get("op").as_str().unwrap_or("generate") {
            "ping" => Ok(jobj! { "ok" => true }),
            "config" => Ok(crate::config::loader::to_value(&self.config)),
            "generate" => self.generate(&v),
            other => anyhow::bail!("unknown op `{other}`"),
        }
    }

    fn generate(&self, v: &Value) -> Result<Value> {
        let tokenizer = Tokenizer::new(4096);
        let id = NEXT_REQ.fetch_add(1, Ordering::SeqCst);
        let prompt = v.get("prompt").as_str().unwrap_or("hello world");
        let modality = match v.get("modality").as_str().unwrap_or("text") {
            "audio" => Modality::Audio,
            "image" => Modality::Image,
            "video" => Modality::Video,
            _ => Modality::Text,
        };
        let req = Request {
            id,
            arrival_s: 0.0,
            modality,
            prompt_tokens: tokenizer.encode(prompt),
            mm_frames: v.get("mm_frames").as_usize().unwrap_or(0),
            seed: v.get("seed").as_usize().unwrap_or(id as usize) as u64,
            max_text_tokens: v.get("max_text_tokens").as_usize().unwrap_or(24),
            max_audio_tokens: v.get("max_audio_tokens").as_usize().unwrap_or(64),
            diffusion_steps: v.get("diffusion_steps").as_usize().unwrap_or(0),
            ignore_eos: v.get("ignore_eos").as_bool().unwrap_or(true),
        };
        let workload = Workload { name: "server".into(), requests: vec![req] };
        let orch = Orchestrator::new(
            self.config.clone(),
            self.artifacts.clone(),
            Registry::builtin(),
            RunOptions::default(),
        )?;
        let audio_stage = if self.config.stage("talker").is_some() { Some("talker") } else { None };
        let summary = orch.run_workload(&workload, audio_stage)?;
        Ok(jobj! {
            "req_id" => id as usize,
            "jct_s" => summary.report.mean_jct(),
            "ttft_s" => summary.report.mean_ttft(),
            "rtf" => if summary.report.rtf.is_empty() { -1.0 } else { summary.report.mean_rtf() },
            "completed" => summary.report.completed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_roundtrip() {
        let dir = crate::runtime::Artifacts::default_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let artifacts = Arc::new(Artifacts::load(&dir).unwrap());
        let server = Server::bind(
            "127.0.0.1:0",
            crate::config::presets::mimo_audio(1),
            artifacts,
        )
        .unwrap();
        let addr = server.addr();
        let h = std::thread::spawn(move || server.serve_n(1));
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"{\"op\": \"ping\"}\n").unwrap();
        let mut line = String::new();
        BufReader::new(c.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains("true"), "{line}");
        drop(c);
        h.join().unwrap().unwrap();
    }
}
