//! TCP JSON-lines serving frontend (`omni-serve serve`).
//!
//! Protocol: one JSON object per line.
//!
//! request:  {"op": "generate", "prompt": "...", "modality": "video",
//!            "mm_frames": 64, "max_text_tokens": 32,
//!            "max_audio_tokens": 96}
//! response: {"req_id": N, "jct_s": 1.23, "completed": true}
//! request:  {"op": "ping"}   -> {"ok": true}
//! request:  {"op": "stats"}  -> {"live": true, "inflight": N,
//!            "stages": [{"stage": "talker", "replicas": 2,
//!                        "draining": 0, "queued": 3, "busy": 1}, ...]}
//! request:  {"op": "shutdown"} -> drains + stops the shared session
//!
//! All connections share ONE persistent [`ServingSession`]: the stage
//! graph is spawned on the first `generate` and stays up, and [`Server::serve`]
//! handles each connection on its own thread, so concurrent requests
//! from different connections batch together inside the per-stage
//! schedulers — and, when the pipeline config carries an `autoscaler`
//! block (or `--autoscale` is passed), stage replicas scale with load
//! while the server runs.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::config::{AutoscalerConfig, PipelineConfig};
use crate::jobj;
use crate::json::{self, Value};
use crate::orchestrator::{Orchestrator, RunOptions};
use crate::runtime::Artifacts;
use crate::scheduler::StageAllocator;
use crate::serving::{ServingSession, SessionOptions, WaitResult};
use crate::stage_graph::transfers::Registry;
use crate::tokenizer::Tokenizer;
use crate::trace::{Modality, Request};

/// Server-level options (CLI surface of `omni-serve serve`).
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    /// Elastic autoscaling for the shared session; `None` falls back to
    /// the pipeline config's `autoscaler` block (static if absent too).
    pub autoscaler: Option<AutoscalerConfig>,
}

pub struct Server {
    listener: TcpListener,
    config: PipelineConfig,
    artifacts: Arc<Artifacts>,
    opts: ServeOptions,
    /// The shared long-lived session, created on first `generate`.
    session: Mutex<Option<Arc<ServingSession>>>,
}

static NEXT_REQ: AtomicU64 = AtomicU64::new(1);

impl Server {
    pub fn bind(
        addr: &str,
        config: PipelineConfig,
        artifacts: Arc<Artifacts>,
        opts: ServeOptions,
    ) -> Result<Self> {
        Ok(Self {
            listener: TcpListener::bind(addr)?,
            config,
            artifacts,
            opts,
            session: Mutex::new(None),
        })
    }

    pub fn addr(&self) -> String {
        self.listener.local_addr().map(|a| a.to_string()).unwrap_or_default()
    }

    /// Serve forever (blocking).  Each connection gets its own handler
    /// thread; all of them submit into the one shared session, so
    /// concurrent requests from different connections batch together
    /// inside the per-stage schedulers.
    pub fn serve(&self) -> Result<()> {
        eprintln!("omni-serve listening on {}", self.addr());
        std::thread::scope(|scope| {
            for conn in self.listener.incoming() {
                let Ok(stream) = conn else { continue };
                scope.spawn(move || {
                    if let Err(e) = self.handle(stream) {
                        eprintln!("connection error: {e}");
                    }
                });
            }
        });
        Ok(())
    }

    /// Serve exactly `n` connections sequentially, then return (tests;
    /// deterministic teardown).
    pub fn serve_n(&self, n: usize) -> Result<()> {
        for conn in self.listener.incoming().take(n) {
            self.handle(conn?)?;
        }
        Ok(())
    }

    /// The shared session, started lazily on first use.
    fn session(&self) -> Result<Arc<ServingSession>> {
        let mut guard = self.session.lock().unwrap();
        if let Some(s) = guard.as_ref() {
            return Ok(s.clone());
        }
        let orch = Orchestrator::new(
            self.config.clone(),
            self.artifacts.clone(),
            Registry::builtin(),
            RunOptions::default(),
        )?;
        let autoscaler = self
            .opts
            .autoscaler
            .clone()
            .or_else(|| self.config.autoscaler.clone());
        let session =
            Arc::new(ServingSession::start(&orch, SessionOptions { autoscaler })?);
        *guard = Some(session.clone());
        Ok(session)
    }

    fn audio_stage(&self) -> Option<&'static str> {
        if self.config.stage("talker").is_some() {
            Some("talker")
        } else if self.config.stage("backbone").is_some() {
            Some("backbone")
        } else {
            None
        }
    }

    fn handle(&self, stream: TcpStream) -> Result<()> {
        let mut writer = stream.try_clone()?;
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let resp = match self.dispatch(&line) {
                Ok(v) => v,
                Err(e) => jobj! { "error" => e.to_string() },
            };
            writer.write_all(json::to_string(&resp).as_bytes())?;
            writer.write_all(b"\n")?;
        }
        Ok(())
    }

    fn dispatch(&self, line: &str) -> Result<Value> {
        let v = json::parse(line).map_err(|e| anyhow::anyhow!("bad request JSON: {e}"))?;
        match v.get("op").as_str().unwrap_or("generate") {
            "ping" => Ok(jobj! { "ok" => true }),
            "config" => Ok(crate::config::loader::to_value(&self.config)),
            "stats" => self.stats(),
            "generate" => self.generate(&v),
            "shutdown" => self.shutdown(),
            other => anyhow::bail!("unknown op `{other}`"),
        }
    }

    /// Live per-stage replica counts and queue depths from the running
    /// session; before the first `generate` this reports the static plan
    /// with `"live": false`.
    fn stats(&self) -> Result<Value> {
        let session = self.session.lock().unwrap().as_ref().cloned();
        if let Some(s) = session {
            let stages: Vec<Value> = s
                .stage_stats()
                .iter()
                .map(|st| {
                    jobj! {
                        "stage" => st.stage.clone(),
                        "replicas" => st.replicas,
                        "draining" => st.draining,
                        "queued" => st.queued,
                        "busy" => st.busy,
                    }
                })
                .collect();
            return Ok(jobj! {
                "live" => true,
                "inflight" => s.inflight(),
                "stages" => Value::Arr(stages),
            });
        }
        // No session yet: the resolved allocation plan's replica counts.
        let plan = StageAllocator::new(&self.config).plan(None)?;
        let stages: Vec<Value> = plan
            .assignments()
            .iter()
            .map(|a| {
                jobj! {
                    "stage" => a.stage.clone(),
                    "replicas" => a.replicas,
                    "draining" => 0usize,
                    "queued" => 0usize,
                    "busy" => 0usize,
                }
            })
            .collect();
        Ok(jobj! { "live" => false, "inflight" => 0usize, "stages" => Value::Arr(stages) })
    }

    fn generate(&self, v: &Value) -> Result<Value> {
        let tokenizer = Tokenizer::new(4096);
        let id = NEXT_REQ.fetch_add(1, Ordering::SeqCst);
        let prompt = v.get("prompt").as_str().unwrap_or("hello world");
        let modality = match v.get("modality").as_str().unwrap_or("text") {
            "audio" => Modality::Audio,
            "image" => Modality::Image,
            "video" => Modality::Video,
            _ => Modality::Text,
        };
        let req = Request {
            id,
            arrival_s: 0.0,
            modality,
            prompt_tokens: tokenizer.encode(prompt),
            mm_frames: v.get("mm_frames").as_usize().unwrap_or(0),
            seed: v.get("seed").as_usize().unwrap_or(id as usize) as u64,
            max_text_tokens: v.get("max_text_tokens").as_usize().unwrap_or(24),
            max_audio_tokens: v.get("max_audio_tokens").as_usize().unwrap_or(64),
            diffusion_steps: v.get("diffusion_steps").as_usize().unwrap_or(0),
            ignore_eos: v.get("ignore_eos").as_bool().unwrap_or(true),
        };
        let session = self.session()?;
        let handle = session.submit(req)?;
        loop {
            match handle.wait_timeout(Duration::from_millis(100)) {
                WaitResult::Done(c) => {
                    return Ok(jobj! {
                        "req_id" => id as usize,
                        "jct_s" => c.completed_t - handle.submitted_t(),
                        "completed" => true,
                    });
                }
                WaitResult::Timeout => {
                    anyhow::ensure!(!session.failed(), "pipeline failed serving request {id}");
                }
                WaitResult::Closed => anyhow::bail!("serving session closed"),
            }
        }
    }

    /// Drain and stop the shared session (no-op when none was started).
    fn shutdown(&self) -> Result<Value> {
        let session = self.session.lock().unwrap().take();
        match session {
            Some(s) => {
                s.drain(Duration::from_secs(30));
                let summary = s.shutdown(self.audio_stage())?;
                Ok(jobj! {
                    "ok" => true,
                    "completed" => summary.report.completed,
                    "mean_jct_s" => summary.report.mean_jct(),
                })
            }
            None => Ok(jobj! { "ok" => true, "completed" => 0usize }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_roundtrip() {
        let dir = crate::runtime::Artifacts::default_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let artifacts = Arc::new(Artifacts::load(&dir).unwrap());
        let server = Server::bind(
            "127.0.0.1:0",
            crate::config::presets::mimo_audio(1),
            artifacts,
            ServeOptions::default(),
        )
        .unwrap();
        let addr = server.addr();
        let h = std::thread::spawn(move || server.serve_n(1));
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"{\"op\": \"ping\"}\n").unwrap();
        let mut line = String::new();
        BufReader::new(c.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains("true"), "{line}");
        drop(c);
        h.join().unwrap().unwrap();
    }
}
