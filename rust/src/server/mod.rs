//! TCP JSON-lines serving frontend (`omni-serve serve`), protocol v2.
//!
//! One JSON object per line, each answered by one or more frames:
//!
//! ```text
//! # v1 one-shot (unchanged shape, now a blocking wait — no polling):
//! -> {"op": "generate", "prompt": "...", "modality": "video",
//!     "mm_frames": 64, "max_text_tokens": 32, "max_audio_tokens": 96}
//! <- {"req_id": N, "jct_s": 1.23, "completed": true}
//!
//! # v2 streaming: one delta frame per typed chunk, then a terminal done.
//! -> {"op": "generate", "stream": true, "prompt": "...",
//!     "max_audio_tokens": 96, "deadline_s": 5.0, "priority": "high"}
//! <- {"event": "accepted", "req_id": N}
//! <- {"event": "delta", "req_id": N, "kind": "audio", "samples": 256, "t": 0.41}
//! <- {"event": "delta", "req_id": N, "kind": "stage_done", "stage": "talker", "t": 0.9}
//! <- {"event": "done", "req_id": N, "jct_s": 1.1, "cancelled": false, ...}
//!
//! # lifecycle control (usually from a second connection, since a
//! # streaming generate occupies its own):
//! -> {"op": "cancel", "req_id": N}   <- {"ok": true, "req_id": N, "cancelled": true}
//!
//! -> {"op": "ping"}     <- {"ok": true}
//! -> {"op": "stats"}    <- {"live": true, "inflight": N, "stages": [...], "edges": [...]}
//! -> {"op": "shutdown"} <- drains + stops the shared session
//! ```
//!
//! Malformed JSON, unknown ops, and per-op failures all get a structured
//! `{"error": "..."}` frame on the same connection — a bad line never
//! kills the connection or vanishes silently.
//!
//! All connections share ONE persistent [`ServingSession`]: the stage
//! graph is spawned on the first `generate` and stays up, and
//! [`Server::serve`] handles each connection on its own thread, so
//! concurrent requests from different connections batch together inside
//! the per-stage schedulers — and, when the pipeline config carries an
//! `autoscaler` block (or `--autoscale` is passed), stage replicas scale
//! with load while the server runs.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::config::{AdmissionConfig, AutoscalerConfig, CacheConfig, PipelineConfig};
use crate::jobj;
use crate::json::{self, Value};
use crate::orchestrator::{Orchestrator, RunOptions};
use crate::runtime::Artifacts;
use crate::scheduler::StageAllocator;
use crate::serving::{OmniRequest, OutputDelta, Priority, ServingSession, SessionOptions};
use crate::stage_graph::transfers::Registry;
use crate::tokenizer::Tokenizer;
use crate::trace::{Modality, Request};

/// Server-level options (CLI surface of `omni-serve serve`).
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    /// Elastic autoscaling for the shared session; `None` falls back to
    /// the pipeline config's `autoscaler` block (static if absent too).
    pub autoscaler: Option<AutoscalerConfig>,
    /// SLO-aware admission control; `None` falls back to the pipeline
    /// config's `admission` block (admit-everything if absent too).
    pub admission: Option<AdmissionConfig>,
    /// Prefix / encoder caching knobs; `None` falls back to the pipeline
    /// config's `cache` block, then to the defaults (both caches on).
    pub cache: Option<CacheConfig>,
}

pub struct Server {
    listener: TcpListener,
    config: PipelineConfig,
    artifacts: Arc<Artifacts>,
    opts: ServeOptions,
    /// The shared long-lived session, created on first `generate`.
    session: Mutex<Option<Arc<ServingSession>>>,
}

static NEXT_REQ: AtomicU64 = AtomicU64::new(1);

fn write_frame(w: &mut TcpStream, v: &Value) -> Result<()> {
    w.write_all(json::to_string(v).as_bytes())?;
    w.write_all(b"\n")?;
    Ok(())
}

impl Server {
    pub fn bind(
        addr: &str,
        config: PipelineConfig,
        artifacts: Arc<Artifacts>,
        opts: ServeOptions,
    ) -> Result<Self> {
        Ok(Self {
            listener: TcpListener::bind(addr)?,
            config,
            artifacts,
            opts,
            session: Mutex::new(None),
        })
    }

    pub fn addr(&self) -> String {
        self.listener.local_addr().map(|a| a.to_string()).unwrap_or_default()
    }

    /// Serve forever (blocking).  Each connection gets its own handler
    /// thread; all of them submit into the one shared session, so
    /// concurrent requests from different connections batch together
    /// inside the per-stage schedulers.
    pub fn serve(&self) -> Result<()> {
        eprintln!("omni-serve listening on {}", self.addr());
        std::thread::scope(|scope| {
            for conn in self.listener.incoming() {
                let Ok(stream) = conn else { continue };
                scope.spawn(move || {
                    if let Err(e) = self.handle(stream) {
                        eprintln!("connection error: {e}");
                    }
                });
            }
        });
        Ok(())
    }

    /// Serve exactly `n` connections sequentially, then return (tests;
    /// deterministic teardown).
    pub fn serve_n(&self, n: usize) -> Result<()> {
        for conn in self.listener.incoming().take(n) {
            self.handle(conn?)?;
        }
        Ok(())
    }

    /// Serve exactly `n` connections, each on its own handler thread
    /// (unlike [`Self::serve_n`] they run concurrently — required for
    /// cancelling a streaming generate from a second connection), then
    /// return once all are closed.
    pub fn serve_concurrent(&self, n: usize) -> Result<()> {
        std::thread::scope(|scope| {
            let mut joins = Vec::with_capacity(n);
            for conn in self.listener.incoming().take(n) {
                let Ok(stream) = conn else { continue };
                joins.push(scope.spawn(move || {
                    if let Err(e) = self.handle(stream) {
                        eprintln!("connection error: {e}");
                    }
                }));
            }
            for j in joins {
                let _ = j.join();
            }
        });
        Ok(())
    }

    /// The shared session, started lazily on first use.
    fn session(&self) -> Result<Arc<ServingSession>> {
        let mut guard = self.session.lock().unwrap();
        if let Some(s) = guard.as_ref() {
            return Ok(s.clone());
        }
        let orch = Orchestrator::new(
            self.config.clone(),
            self.artifacts.clone(),
            Registry::builtin(),
            RunOptions::default(),
        )?;
        let autoscaler = self
            .opts
            .autoscaler
            .clone()
            .or_else(|| self.config.autoscaler.clone());
        let admission = self
            .opts
            .admission
            .clone()
            .or_else(|| self.config.admission.clone());
        // CacheConfig / RuntimeConfig resolution to the pipeline
        // config's `cache` / `runtime` blocks happens inside
        // ServingSession::start; no CLI override passes through here.
        let cache = self.opts.cache.clone();
        let session = Arc::new(ServingSession::start(
            &orch,
            SessionOptions { autoscaler, admission, cache, runtime: None },
        )?);
        *guard = Some(session.clone());
        Ok(session)
    }

    fn audio_stage(&self) -> Option<&'static str> {
        if self.config.stage("talker").is_some() {
            Some("talker")
        } else if self.config.stage("backbone").is_some() {
            Some("backbone")
        } else {
            None
        }
    }

    fn handle(&self, stream: TcpStream) -> Result<()> {
        let mut writer = stream.try_clone()?;
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            // A read error means the peer is gone (or sent non-UTF-8
            // garbage a JSON protocol cannot recover from): close this
            // connection without taking the server down.
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            match json::parse(&line) {
                Ok(v) => self.dispatch(&v, &mut writer)?,
                Err(e) => write_frame(
                    &mut writer,
                    &jobj! { "error" => format!("bad request JSON: {e}") },
                )?,
            }
        }
        Ok(())
    }

    /// Route one parsed request line.  Every op failure is answered with
    /// a structured `{"error": ...}` frame; only transport failures
    /// (the peer vanished mid-write) propagate.
    fn dispatch(&self, v: &Value, w: &mut TcpStream) -> Result<()> {
        let reply = match v.get("op").as_str().unwrap_or("generate") {
            "ping" => Ok(jobj! { "ok" => true }),
            "config" => Ok(crate::config::loader::to_value(&self.config)),
            "stats" => self.stats(),
            "cancel" => self.cancel(v),
            "shutdown" => self.shutdown(),
            // Writes its own frame(s) — one-shot or a delta stream.
            "generate" => return self.generate(v, w),
            other => Err(anyhow::anyhow!("unknown op `{other}`")),
        };
        write_frame(w, &reply.unwrap_or_else(|e| jobj! { "error" => e.to_string() }))
    }

    /// Live per-stage replica counts and queue depths from the running
    /// session; before the first `generate` this reports the static plan
    /// with `"live": false`.
    fn stats(&self) -> Result<Value> {
        let session = self.session.lock().unwrap().as_ref().cloned();
        if let Some(s) = session {
            let live = s.stage_stats();
            // Session-wide cache rollup for the headline fields; the
            // per-stage frames carry the split-out counters.
            let mut cache = crate::metrics::CacheCounters::default();
            for st in &live {
                cache.absorb(&st.cache);
            }
            let stages: Vec<Value> = live
                .iter()
                .map(|st| {
                    jobj! {
                        "stage" => st.stage.clone(),
                        "replicas" => st.replicas,
                        "draining" => st.draining,
                        "queued" => st.queued,
                        "busy" => st.busy,
                        "prefix_hits" => st.cache.prefix_hits as usize,
                        "prefix_misses" => st.cache.prefix_misses as usize,
                        "evictions" => st.cache.evictions as usize,
                        "encoder_hits" => st.cache.encoder_hits as usize,
                        "encoder_misses" => st.cache.encoder_misses as usize,
                        "wakeups" => st.wakeups as usize,
                        "spurious_wakeups" => st.spurious_wakeups as usize,
                        "idle_ms" => st.idle_ms,
                    }
                })
                .collect();
            let rep = s.live_report();
            let shed = s.admission_stats().map(|a| a.shed as usize).unwrap_or(0);
            // Per-edge transfer counters: what each connector edge moved
            // (bytes/frames) and its send→resolve latency percentiles.
            let edges: Vec<Value> = s
                .edge_stats()
                .iter()
                .map(|e| {
                    jobj! {
                        "edge" => e.label.clone(),
                        "bytes" => e.bytes as usize,
                        "frames" => e.frames as usize,
                        "p50_ms" => e.p50_ms,
                        "p95_ms" => e.p95_ms,
                    }
                })
                .collect();
            return Ok(jobj! {
                "live" => true,
                "inflight" => s.inflight(),
                "offered" => rep.offered,
                "in_slo" => rep.in_slo,
                "rejected" => rep.rejected,
                "shed" => shed,
                "goodput" => rep.goodput(),
                "prefix_hits" => cache.prefix_hits as usize,
                "prefix_hit_rate" => cache.prefix_hit_rate(),
                "encoder_hits" => cache.encoder_hits as usize,
                "encoder_hit_rate" => cache.encoder_hit_rate(),
                "stages" => Value::Arr(stages),
                "edges" => Value::Arr(edges),
            });
        }
        // No session yet: the resolved allocation plan's replica counts.
        let plan = StageAllocator::new(&self.config).plan(None)?;
        let stages: Vec<Value> = plan
            .assignments()
            .iter()
            .map(|a| {
                jobj! {
                    "stage" => a.stage.clone(),
                    "replicas" => a.replicas,
                    "draining" => 0usize,
                    "queued" => 0usize,
                    "busy" => 0usize,
                }
            })
            .collect();
        Ok(jobj! {
            "live" => false,
            "inflight" => 0usize,
            "offered" => 0usize,
            "in_slo" => 0usize,
            "rejected" => 0usize,
            "shed" => 0usize,
            "goodput" => 0.0,
            "prefix_hits" => 0usize,
            "prefix_hit_rate" => 0.0,
            "encoder_hits" => 0usize,
            "encoder_hit_rate" => 0.0,
            "stages" => Value::Arr(stages),
            "edges" => Value::Arr(Vec::new()),
        })
    }

    /// Cancel an in-flight request by id (no-op before the session
    /// exists; `cancelled: false` when the request already resolved).
    fn cancel(&self, v: &Value) -> Result<Value> {
        let id = v.req_usize("req_id")? as u64;
        let session = self.session.lock().unwrap().as_ref().cloned();
        let hit = session.map(|s| s.cancel(id)).unwrap_or(false);
        Ok(jobj! { "ok" => true, "req_id" => id as usize, "cancelled" => hit })
    }

    /// Build the typed request from a `generate` line.
    fn parse_request(&self, v: &Value, id: u64) -> OmniRequest {
        let tokenizer = Tokenizer::new(4096);
        let prompt = v.get("prompt").as_str().unwrap_or("hello world");
        let modality = match v.get("modality").as_str().unwrap_or("text") {
            "audio" => Modality::Audio,
            "image" => Modality::Image,
            "video" => Modality::Video,
            _ => Modality::Text,
        };
        let req = Request {
            id,
            arrival_s: 0.0,
            modality,
            prompt_tokens: tokenizer.encode(prompt),
            mm_frames: v.get("mm_frames").as_usize().unwrap_or(0),
            seed: v.get("seed").as_usize().unwrap_or(id as usize) as u64,
            max_text_tokens: v.get("max_text_tokens").as_usize().unwrap_or(24),
            max_audio_tokens: v.get("max_audio_tokens").as_usize().unwrap_or(64),
            diffusion_steps: v.get("diffusion_steps").as_usize().unwrap_or(0),
            ignore_eos: v.get("ignore_eos").as_bool().unwrap_or(true),
        };
        let mut oreq = OmniRequest::from(req)
            .streaming(v.get("stream").as_bool().unwrap_or(false))
            .priority(match v.get("priority").as_str().unwrap_or("normal") {
                "low" => Priority::Low,
                "high" => Priority::High,
                _ => Priority::Normal,
            });
        if let Some(d) = v.get("deadline_s").as_f64() {
            oreq = oreq.deadline_s(d);
        }
        if let Some(t) = v.get("tenant").as_str() {
            oreq = oreq.tenant(t);
        }
        oreq
    }

    fn generate(&self, v: &Value, w: &mut TcpStream) -> Result<()> {
        match self.generate_inner(v, w) {
            Ok(()) => Ok(()),
            // Setup/stream failures become a terminal error frame on the
            // still-open connection (whether or not deltas already went
            // out, `{"error"}` is a valid terminal event).
            Err(e) => write_frame(w, &jobj! { "error" => e.to_string() }),
        }
    }

    fn generate_inner(&self, v: &Value, w: &mut TcpStream) -> Result<()> {
        let id = NEXT_REQ.fetch_add(1, Ordering::SeqCst);
        let oreq = self.parse_request(v, id);
        let streaming = oreq.is_streaming();
        let session = self.session()?;
        let mut rs = session.submit_request(oreq)?;

        if !streaming {
            // v1 one-shot path: BLOCK on the stream — the collector
            // closes it on session failure/shutdown, so there is no
            // wait_timeout polling loop (and none of its up-to-100 ms
            // artificial tail latency) anymore.  A completed request
            // keeps the exact PR-4 response shape; a cancelled one
            // (deadline, or a cross-connection `cancel` op) must not
            // claim completion.
            loop {
                match rs.recv() {
                    Some(OutputDelta::Done { t, cancelled, .. }) => {
                        let frame = if cancelled {
                            jobj! {
                                "req_id" => id as usize,
                                "jct_s" => t - rs.submitted_t(),
                                "completed" => false,
                                "cancelled" => true,
                            }
                        } else {
                            jobj! {
                                "req_id" => id as usize,
                                "jct_s" => t - rs.submitted_t(),
                                "completed" => true,
                            }
                        };
                        return write_frame(w, &frame);
                    }
                    // Admission refusal / overload shed: a structured
                    // terminal frame, never a bare connection drop.
                    Some(OutputDelta::Rejected { reason, retry_after_s, .. }) => {
                        return write_frame(w, &jobj! {
                            "error" => "rejected",
                            "req_id" => id as usize,
                            "reason" => reason,
                            "retry_after_s" => retry_after_s,
                        });
                    }
                    Some(_) => {}
                    None => anyhow::bail!("pipeline failed serving request {id}"),
                }
            }
        }

        // v2 streaming path: accepted header (carries the req_id a
        // second connection needs for `cancel`), then delta frames.
        // Any write failure means the client is gone — cancel so the
        // pipeline stops generating into the void.
        if let Err(e) = write_frame(w, &jobj! { "event" => "accepted", "req_id" => id as usize }) {
            rs.cancel();
            return Err(e);
        }
        loop {
            let delta = match rs.recv() {
                Some(d) => d,
                None => anyhow::bail!("pipeline failed serving request {id}"),
            };
            let frame = match &delta {
                OutputDelta::TextDelta { tokens, t } => jobj! {
                    "event" => "delta", "req_id" => id as usize,
                    "kind" => "text", "tokens" => tokens.len(), "t" => *t,
                },
                OutputDelta::AudioChunk { wave, t } => jobj! {
                    "event" => "delta", "req_id" => id as usize,
                    "kind" => "audio", "samples" => wave.len(), "t" => *t,
                },
                OutputDelta::ImageFrame { tokens, t } => jobj! {
                    "event" => "delta", "req_id" => id as usize,
                    "kind" => "image", "tokens" => *tokens, "t" => *t,
                },
                OutputDelta::StageDone { stage, t } => jobj! {
                    "event" => "delta", "req_id" => id as usize,
                    "kind" => "stage_done", "stage" => *stage, "t" => *t,
                },
                OutputDelta::Done { jct_s, cancelled, usage, .. } => {
                    return write_frame(w, &jobj! {
                        "event" => "done", "req_id" => id as usize,
                        "jct_s" => *jct_s, "cancelled" => *cancelled,
                        "deltas" => usage.deltas,
                        "text_tokens" => usage.text_tokens,
                        "audio_samples" => usage.audio_samples,
                    });
                }
                // Terminal: shed mid-queue (or refused at submit) — the
                // stream ends with a structured rejection, never a drop.
                OutputDelta::Rejected { reason, retry_after_s, .. } => {
                    return write_frame(w, &jobj! {
                        "error" => "rejected", "event" => "rejected",
                        "req_id" => id as usize,
                        "reason" => reason.clone(),
                        "retry_after_s" => *retry_after_s,
                    });
                }
            };
            if let Err(e) = write_frame(w, &frame) {
                // The client hung up mid-stream: release the pipeline's
                // resources instead of generating into the void.
                rs.cancel();
                return Err(e);
            }
        }
    }

    /// Drain and stop the shared session (no-op when none was started).
    fn shutdown(&self) -> Result<Value> {
        let session = self.session.lock().unwrap().take();
        match session {
            Some(s) => {
                s.drain(Duration::from_secs(30));
                let summary = s.shutdown(self.audio_stage())?;
                Ok(jobj! {
                    "ok" => true,
                    "completed" => summary.report.completed,
                    "cancelled" => summary.report.cancelled,
                    "rejected" => summary.report.rejected,
                    "goodput" => summary.report.goodput(),
                    "mean_jct_s" => summary.report.mean_jct(),
                })
            }
            None => Ok(jobj! { "ok" => true, "completed" => 0usize }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_roundtrip() {
        let dir = crate::runtime::Artifacts::default_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let artifacts = Arc::new(Artifacts::load(&dir).unwrap());
        let server = Server::bind(
            "127.0.0.1:0",
            crate::config::presets::mimo_audio(1),
            artifacts,
            ServeOptions::default(),
        )
        .unwrap();
        let addr = server.addr();
        let h = std::thread::spawn(move || server.serve_n(1));
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"{\"op\": \"ping\"}\n").unwrap();
        let mut line = String::new();
        BufReader::new(c.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains("true"), "{line}");
        drop(c);
        h.join().unwrap().unwrap();
    }
}
