//! The orchestrator (paper §3.1/§3.3): launches one engine per stage,
//! wires connectors along the stage-graph edges, routes requests, and
//! tracks per-request lifecycle metrics.
//!
//! Threading model: engines own non-`Send` PJRT state, so each stage runs
//! on its own thread, constructed in-thread.  Data crosses threads only
//! as [`StageItem`]s through [`crate::connector`]s — the disaggregation
//! boundary.  Transfers run consumer-side (the downstream thread turns
//! upstream items into engine commands).

pub mod stage;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::PipelineConfig;
use crate::connector;
use crate::engine::StageItem;
use crate::metrics::{Event, Recorder, RunReport};
use crate::scheduler::{AllocationPlan, StageAllocator};
use crate::stage_graph::transfers::{ReqMeta, ReqTable, Registry, TransferCtx};
use crate::stage_graph::StageGraph;
use crate::trace::{Request, Workload};
use crate::runtime::Artifacts;

/// Run-wide options.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Stream partial stage outputs (paper §3.3 "streaming stage
    /// output"); false = stage barriers (full output before transfer).
    pub streaming: bool,
    /// Baseline knob: recompile executables per call (eager analog).
    pub lazy_compile: bool,
    /// Honor request arrival times (online serving); false = offline
    /// batch (all requests available at t=0, the paper's eval mode).
    pub realtime_arrivals: bool,
    /// External Mooncake store address (spawned automatically if any
    /// edge uses the TCP connector and this is None).
    pub store_addr: Option<String>,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self { streaming: true, lazy_compile: false, realtime_arrivals: false, store_addr: None }
    }
}

/// Wall clock shared across stage threads (run-relative seconds).
/// Resettable so engine construction/compilation is excluded from
/// request timing.
#[derive(Debug, Clone)]
pub struct RunClock(Arc<Mutex<Instant>>);

impl RunClock {
    pub fn new() -> Self {
        Self(Arc::new(Mutex::new(Instant::now())))
    }

    pub fn now(&self) -> f64 {
        self.0.lock().unwrap().elapsed().as_secs_f64()
    }

    /// Restart the clock (after all engines report ready).
    pub fn reset(&self) {
        *self.0.lock().unwrap() = Instant::now();
    }
}

impl Default for RunClock {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-stage summary returned after a run.
#[derive(Debug, Default, Clone)]
pub struct StageSummary {
    pub name: String,
    pub ar: Option<crate::engine::ar::EngineStats>,
    pub diffusion: Option<crate::engine::diffusion::DiffusionStats>,
    pub vocoder: Option<crate::engine::vocoder::VocoderStats>,
    /// Admission-queue counters from the stage's [`crate::scheduler::StageScheduler`].
    pub sched: Option<crate::scheduler::SchedStats>,
    pub bytes_sent: u64,
}

/// Everything a finished run reports.
#[derive(Debug)]
pub struct RunSummary {
    pub report: RunReport,
    pub stages: Vec<StageSummary>,
    pub wall_s: f64,
}

/// The disaggregated pipeline runner.
pub struct Orchestrator {
    graph: StageGraph,
    registry: Registry,
    artifacts: Arc<Artifacts>,
    opts: RunOptions,
    plan: AllocationPlan,
}

impl Orchestrator {
    pub fn new(
        config: PipelineConfig,
        artifacts: Arc<Artifacts>,
        registry: Registry,
        opts: RunOptions,
    ) -> Result<Self> {
        let graph = StageGraph::build(config, &registry)?;
        // Device-memory admission for the paper's testbed model.
        let pool = crate::device::DevicePool::new(
            graph.config.n_devices,
            graph.config.device_bytes,
        );
        graph
            .reserve_memory(&pool, &artifacts)
            .with_context(|| format!("placing pipeline `{}`", graph.config.name))?;
        // Scheduling/allocation admission: resolve each stage's batching
        // policy and device assignment, rejecting invalid combinations
        // before any engine thread spawns.
        let plan = StageAllocator::new(&graph.config)
            .plan(Some(artifacts.as_ref()))
            .with_context(|| format!("allocating pipeline `{}`", graph.config.name))?;
        Ok(Self { graph, registry, artifacts, opts, plan })
    }

    pub fn graph(&self) -> &StageGraph {
        &self.graph
    }

    /// The resolved per-stage scheduling/placement plan.
    pub fn plan(&self) -> &AllocationPlan {
        &self.plan
    }

    /// Serve a whole workload to completion and report metrics.
    /// `audio_stage` names the stage whose generated tokens measure audio
    /// duration for RTF (e.g. "talker"), if any.
    pub fn run_workload(&self, workload: &Workload, audio_stage: Option<&'static str>) -> Result<RunSummary> {
        let n_stages = self.graph.n_stages();
        let recorder = Arc::new(Recorder::new());
        let clock = RunClock::new();
        let reqs: ReqTable = Arc::new(Mutex::new(Default::default()));
        let stop = Arc::new(AtomicBool::new(false));

        // Spawn a Mooncake store if any edge wants TCP.
        let needs_tcp = self
            .graph
            .config
            .edges
            .iter()
            .any(|e| e.connector == crate::config::ConnectorKind::Tcp);
        let _store;
        let store_addr: Option<String> = if needs_tcp {
            match &self.opts.store_addr {
                Some(a) => Some(a.clone()),
                None => {
                    let s = connector::tcp::MooncakeStore::spawn("127.0.0.1:0")?;
                    let a = s.addr().to_string();
                    _store = s;
                    Some(a)
                }
            }
        } else {
            None
        };

        // Wire connectors: for each edge, tx to producer, (rx, transfer) to
        // consumer.
        let mut stage_rxs: Vec<Vec<(connector::ConnectorRx, String)>> =
            (0..n_stages).map(|_| vec![]).collect();
        let mut stage_txs: Vec<Vec<connector::ConnectorTx>> =
            (0..n_stages).map(|_| vec![]).collect();
        for e in &self.graph.config.edges {
            let from = self.graph.stage_index(&e.from).unwrap();
            let to = self.graph.stage_index(&e.to).unwrap();
            let label = format!("{}2{}", e.from, e.to);
            let (tx, rx) = connector::pair(e.connector, &label, store_addr.as_deref())?;
            stage_txs[from].push(tx);
            stage_rxs[to].push((rx, e.transfer.clone()));
        }

        // Entry channel + exit collector.
        let (front_tx, front_rx) = mpsc::channel::<Request>();
        let (sink_tx, sink_rx) = mpsc::channel::<StageItem>();

        // Spawn stage threads; they build engines (PJRT clients, compiled
        // executables, weight upload) and then rendezvous on this barrier
        // so compilation time is excluded from request metrics.
        let ready = Arc::new(std::sync::Barrier::new(n_stages + 1));
        let inflight = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        let mut front_rx_opt = Some(front_rx);
        for i in 0..n_stages {
            let spec = stage::StageSpec {
                index: i,
                cfg: self.graph.stage(i).clone(),
                assignment: self.plan.assignment(i).clone(),
                artifacts: self.artifacts.clone(),
                rxs: std::mem::take(&mut stage_rxs[i]),
                txs: std::mem::take(&mut stage_txs[i]),
                registry: self.registry.clone(),
                reqs: reqs.clone(),
                recorder: recorder.clone(),
                clock: clock.clone(),
                stop: stop.clone(),
                front_rx: if i == self.graph.entry { front_rx_opt.take() } else { None },
                sink: if self.graph.exits.contains(&i) { Some(sink_tx.clone()) } else { None },
                streaming: self.opts.streaming,
                lazy_compile: self.opts.lazy_compile,
                device_bytes: self.graph.config.device_bytes,
                downstream_hint: self.downstream_hint(i),
                ready: ready.clone(),
            };
            handles.push(stage::spawn(spec)?);
        }
        drop(sink_tx);
        ready.wait();
        clock.reset();

        // Feed requests.
        let n_requests = workload.requests.len();
        inflight.store(n_requests, Ordering::SeqCst);
        {
            let mut table = reqs.lock().unwrap();
            for r in &workload.requests {
                table.insert(
                    r.id,
                    ReqMeta {
                        seed: r.seed,
                        max_audio_tokens: r.max_audio_tokens,
                        diffusion_steps: r.diffusion_steps,
                        ignore_eos: r.ignore_eos,
                        prompt_tokens: r.prompt_tokens.clone(),
                        max_text_tokens: r.max_text_tokens,
                    },
                );
            }
        }
        let feeder = {
            let clock = clock.clone();
            let recorder = recorder.clone();
            let realtime = self.opts.realtime_arrivals;
            let mut sorted = workload.requests.clone();
            sorted.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
            std::thread::spawn(move || {
                for r in sorted {
                    if realtime {
                        let wait = r.arrival_s - clock.now();
                        if wait > 0.0 {
                            std::thread::sleep(std::time::Duration::from_secs_f64(wait));
                        }
                    }
                    recorder.emit(Event::Arrived { req: r.id, t: clock.now() });
                    if front_tx.send(r).is_err() {
                        break;
                    }
                }
            })
        };

        // Collect completions from exit stages.
        let mut remaining = n_requests;
        let mut done: std::collections::HashSet<u64> = Default::default();
        while remaining > 0 {
            match sink_rx.recv() {
                Ok(item) => {
                    if item.finished && done.insert(item.req_id) {
                        recorder.emit(Event::Completed { req: item.req_id, t: clock.now() });
                        remaining -= 1;
                    }
                }
                Err(_) => break,
            }
        }
        feeder.join().ok();
        stop.store(true, Ordering::SeqCst);

        let mut stages = Vec::new();
        for h in handles {
            match h.join() {
                Ok(Ok(summary)) => stages.push(summary),
                Ok(Err(e)) => return Err(e),
                Err(_) => anyhow::bail!("stage thread panicked"),
            }
        }
        let wall = clock.now();
        let report = recorder.report(wall, audio_stage);
        Ok(RunSummary { report, stages, wall_s: wall })
    }

    /// Chunking/conditioning hints a consumer stage's transfers need
    /// (derived from ITS model manifest, passed to incoming transfers).
    fn downstream_hint(&self, i: usize) -> TransferCtx {
        let s = self.graph.stage(i);
        let (chunk, ctd) = match self.artifacts.model(&s.model) {
            Ok(m) => match m.kind.as_str() {
                "dit" => (
                    m.cfg_usize("n_tokens").unwrap_or(64),
                    m.cfg_usize("cond_tokens_dim").unwrap_or(0),
                ),
                "cnn_vocoder" => (m.cfg_usize("t_frames").unwrap_or(64), 0),
                "patch_codec" => (m.cfg_usize("t_max").unwrap_or(64), 0),
                _ => (0, 0),
            },
            Err(_) => (0, 0),
        };
        TransferCtx {
            reqs: Arc::new(Mutex::new(Default::default())), // replaced in stage
            chunk_frames: chunk,
            cond_tokens_dim: ctd,
        }
    }
}

/// Which multimodal encoder serves a given thinker model (encoder output
/// width must match the thinker width).
pub fn encoder_model_for(stage_model: &str) -> Option<&'static str> {
    match stage_model {
        "thinker25" => Some("enc25"),
        "thinker3" => Some("enc3"),
        _ => None,
    }
}
