//! The orchestrator (paper §3.1/§3.3): launches `replicas` engines per
//! stage, wires routed connectors along the stage-graph edges
//! ([`crate::connector::router`]), routes requests, and tracks
//! per-request lifecycle metrics.
//!
//! Threading model: engines own non-`Send` PJRT state, so each engine
//! replica runs on its own thread, constructed in-thread.  Data crosses
//! threads only as [`StageItem`]s through [`crate::connector`]s — the
//! disaggregation boundary.  Transfers run consumer-side (the downstream
//! thread turns upstream items into engine commands).

pub mod stage;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::PipelineConfig;
use crate::connector;
use crate::engine::StageItem;
use crate::metrics::{Event, Recorder, RunReport};
use crate::scheduler::{AllocationPlan, StageAllocator};
use crate::stage_graph::transfers::{ReqMeta, ReqTable, Registry, TransferCtx};
use crate::stage_graph::StageGraph;
use crate::trace::{Request, Workload};
use crate::runtime::Artifacts;

/// Run-wide options.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Stream partial stage outputs (paper §3.3 "streaming stage
    /// output"); false = stage barriers (full output before transfer).
    pub streaming: bool,
    /// Baseline knob: recompile executables per call (eager analog).
    pub lazy_compile: bool,
    /// Honor request arrival times (online serving); false = offline
    /// batch (all requests available at t=0, the paper's eval mode).
    pub realtime_arrivals: bool,
    /// External Mooncake store address (spawned automatically if any
    /// edge uses the TCP connector and this is None).
    pub store_addr: Option<String>,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self { streaming: true, lazy_compile: false, realtime_arrivals: false, store_addr: None }
    }
}

/// Wall clock shared across stage threads (run-relative seconds).
/// Resettable so engine construction/compilation is excluded from
/// request timing.
///
/// Every stage thread reads this per event, so the hot path must not
/// take a lock: the epoch is a fixed `Instant` plus an atomic
/// nanosecond offset that [`RunClock::reset`] swaps — `now()` is one
/// monotonic-clock read and one relaxed atomic load.
#[derive(Debug, Clone)]
pub struct RunClock(Arc<ClockInner>);

#[derive(Debug)]
struct ClockInner {
    base: Instant,
    /// Nanoseconds from `base` to the current epoch start.
    offset_ns: std::sync::atomic::AtomicU64,
}

impl RunClock {
    pub fn new() -> Self {
        Self(Arc::new(ClockInner {
            base: Instant::now(),
            offset_ns: std::sync::atomic::AtomicU64::new(0),
        }))
    }

    pub fn now(&self) -> f64 {
        let elapsed = self.0.base.elapsed().as_nanos() as u64;
        let offset = self.0.offset_ns.load(Ordering::Relaxed);
        // A read racing a concurrent reset() could see the new offset
        // before its own clock sample — clamp instead of underflowing.
        elapsed.saturating_sub(offset) as f64 / 1e9
    }

    /// Restart the clock (after all engines report ready).
    pub fn reset(&self) {
        let elapsed = self.0.base.elapsed().as_nanos() as u64;
        self.0.offset_ns.store(elapsed, Ordering::Relaxed);
    }
}

impl Default for RunClock {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-engine-replica summary returned after a run (one entry per
/// replica; `replica` is 0 for unreplicated stages, making
/// single-replica runs identical to the pre-replication output).
#[derive(Debug, Default, Clone)]
pub struct StageSummary {
    pub name: String,
    /// Which engine replica of the stage this summary describes.
    pub replica: usize,
    pub ar: Option<crate::engine::ar::EngineStats>,
    pub diffusion: Option<crate::engine::diffusion::DiffusionStats>,
    pub vocoder: Option<crate::engine::vocoder::VocoderStats>,
    /// Admission-queue counters from the replica's [`crate::scheduler::StageScheduler`].
    pub sched: Option<crate::scheduler::SchedStats>,
    pub bytes_sent: u64,
}

impl StageSummary {
    /// Fold another replica's summary into this one (stage-level rollup).
    pub fn absorb(&mut self, other: &StageSummary) {
        self.bytes_sent += other.bytes_sent;
        match (&mut self.ar, &other.ar) {
            (Some(a), Some(b)) => {
                a.iterations += b.iterations;
                a.prefill_tokens += b.prefill_tokens;
                a.decode_tokens += b.decode_tokens;
                a.prefill_calls += b.prefill_calls;
                a.decode_calls += b.decode_calls;
                a.scan_calls += b.scan_calls;
                a.preemptions += b.preemptions;
                a.exec_seconds += b.exec_seconds;
                a.marshal_seconds += b.marshal_seconds;
            }
            (slot @ None, Some(b)) => *slot = Some(b.clone()),
            _ => {}
        }
        match (&mut self.diffusion, &other.diffusion) {
            (Some(a), Some(b)) => {
                a.jobs_done += b.jobs_done;
                a.steps_run += b.steps_run;
                a.steps_skipped += b.steps_skipped;
                a.calls += b.calls;
                a.exec_seconds += b.exec_seconds;
            }
            (slot @ None, Some(b)) => *slot = Some(b.clone()),
            _ => {}
        }
        match (&mut self.vocoder, &other.vocoder) {
            (Some(a), Some(b)) => {
                a.chunks_done += b.chunks_done;
                a.calls += b.calls;
                a.exec_seconds += b.exec_seconds;
            }
            (slot @ None, Some(b)) => *slot = Some(b.clone()),
            _ => {}
        }
        match (&mut self.sched, &other.sched) {
            (Some(a), Some(b)) => {
                a.admitted += b.admitted;
                a.passthrough += b.passthrough;
                a.max_queue_depth = a.max_queue_depth.max(b.max_queue_depth);
                a.queue_wait.extend(&b.queue_wait);
            }
            (slot @ None, Some(b)) => *slot = Some(b.clone()),
            _ => {}
        }
    }
}

/// Everything a finished run reports.
#[derive(Debug)]
pub struct RunSummary {
    pub report: RunReport,
    /// One entry per engine replica, in (stage, replica) order.
    pub stages: Vec<StageSummary>,
    pub wall_s: f64,
}

impl RunSummary {
    /// All replica summaries of `stage`.
    pub fn stage_replicas(&self, stage: &str) -> Vec<&StageSummary> {
        self.stages.iter().filter(|s| s.name == stage).collect()
    }

    /// Merge the per-replica summaries of `stage` into one stage-level
    /// rollup (counters summed, queue waits pooled).
    pub fn stage_rollup(&self, stage: &str) -> Option<StageSummary> {
        let mut it = self.stages.iter().filter(|s| s.name == stage);
        let mut acc = it.next()?.clone();
        for s in it {
            acc.absorb(s);
        }
        Some(acc)
    }
}

/// The disaggregated pipeline runner.
pub struct Orchestrator {
    graph: StageGraph,
    registry: Registry,
    artifacts: Arc<Artifacts>,
    opts: RunOptions,
    plan: AllocationPlan,
}

impl Orchestrator {
    pub fn new(
        config: PipelineConfig,
        artifacts: Arc<Artifacts>,
        registry: Registry,
        opts: RunOptions,
    ) -> Result<Self> {
        let graph = StageGraph::build(config, &registry)?;
        // Scheduling/allocation admission: resolve each stage's batching
        // policy and pack a device group per engine replica, rejecting
        // invalid combinations before any engine thread spawns.
        let plan = StageAllocator::new(&graph.config)
            .plan(Some(artifacts.as_ref()))
            .with_context(|| format!("allocating pipeline `{}`", graph.config.name))?;
        // Device-memory admission for the paper's testbed model: every
        // replica's weights must fit on its packed device group.
        let pool = crate::device::DevicePool::new(
            graph.config.n_devices,
            graph.config.device_bytes,
        );
        graph
            .reserve_memory(&pool, &artifacts, &plan)
            .with_context(|| format!("placing pipeline `{}`", graph.config.name))?;
        Ok(Self { graph, registry, artifacts, opts, plan })
    }

    pub fn graph(&self) -> &StageGraph {
        &self.graph
    }

    /// The resolved per-stage scheduling/placement plan.
    pub fn plan(&self) -> &AllocationPlan {
        &self.plan
    }

    /// Serve a whole workload to completion and report metrics.
    /// `audio_stage` names the stage whose generated tokens measure audio
    /// duration for RTF (e.g. "talker"), if any.
    pub fn run_workload(&self, workload: &Workload, audio_stage: Option<&'static str>) -> Result<RunSummary> {
        let n_stages = self.graph.n_stages();
        let recorder = Arc::new(Recorder::new());
        let clock = RunClock::new();
        let reqs: ReqTable = Arc::new(Mutex::new(Default::default()));
        let stop = Arc::new(AtomicBool::new(false));
        let failed = Arc::new(AtomicBool::new(false));

        // Spawn a Mooncake store if any edge wants TCP.
        let needs_tcp = self
            .graph
            .config
            .edges
            .iter()
            .any(|e| e.connector == crate::config::ConnectorKind::Tcp);
        let _store;
        let store_addr: Option<String> = if needs_tcp {
            match &self.opts.store_addr {
                Some(a) => Some(a.clone()),
                None => {
                    let s = connector::tcp::MooncakeStore::spawn("127.0.0.1:0")?;
                    let a = s.addr().to_string();
                    _store = s;
                    Some(a)
                }
            }
        } else {
            None
        };

        // Wire routed edges: an edge between an m-replica producer and an
        // n-replica consumer becomes m RouterTx / n RouterRx over m×n
        // point-to-point connectors, with the edge's routing policy
        // picking the consumer replica per item (connector::router).
        let replicas: Vec<usize> =
            (0..n_stages).map(|i| self.plan.assignment(i).replicas).collect();
        let mut stage_rxs: Vec<Vec<Vec<(connector::router::RouterRx, String)>>> =
            replicas.iter().map(|&r| (0..r).map(|_| vec![]).collect()).collect();
        let mut stage_txs: Vec<Vec<Vec<connector::router::RouterTx>>> =
            replicas.iter().map(|&r| (0..r).map(|_| vec![]).collect()).collect();
        for e in &self.graph.config.edges {
            let from = self.graph.stage_index(&e.from).unwrap();
            let to = self.graph.stage_index(&e.to).unwrap();
            let label = format!("{}2{}", e.from, e.to);
            let (txs, rxs) = connector::router::wire(
                e.connector,
                e.routing,
                &label,
                store_addr.as_deref(),
                replicas[from],
                replicas[to],
            )?;
            for (f, tx) in txs.into_iter().enumerate() {
                stage_txs[from][f].push(tx);
            }
            for (t, rx) in rxs.into_iter().enumerate() {
                stage_rxs[to][t].push((rx, e.transfer.clone()));
            }
        }

        // Entry channels (one per entry-stage replica; whole requests are
        // round-robined across them by the feeder) + exit collector.
        let entry = self.graph.entry;
        let mut front_txs = Vec::with_capacity(replicas[entry]);
        let mut front_rx_opts = Vec::with_capacity(replicas[entry]);
        for _ in 0..replicas[entry] {
            let (tx, rx) = mpsc::channel::<Request>();
            front_txs.push(tx);
            front_rx_opts.push(Some(rx));
        }
        let (sink_tx, sink_rx) = mpsc::channel::<StageItem>();

        // Spawn one thread per engine replica; they build engines (PJRT
        // clients, compiled executables, weight upload) and then
        // rendezvous on this barrier so compilation time is excluded from
        // request metrics.
        let total_replicas: usize = replicas.iter().sum();
        let ready = Arc::new(std::sync::Barrier::new(total_replicas + 1));
        let inflight = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for i in 0..n_stages {
            for r in 0..replicas[i] {
                let spec = stage::StageSpec {
                    index: i,
                    replica: r,
                    cfg: self.graph.stage(i).clone(),
                    assignment: self.plan.assignment(i).clone(),
                    artifacts: self.artifacts.clone(),
                    rxs: std::mem::take(&mut stage_rxs[i][r]),
                    txs: std::mem::take(&mut stage_txs[i][r]),
                    registry: self.registry.clone(),
                    reqs: reqs.clone(),
                    recorder: recorder.clone(),
                    clock: clock.clone(),
                    stop: stop.clone(),
                    failed: failed.clone(),
                    front_rx: if i == entry { front_rx_opts[r].take() } else { None },
                    sink: if self.graph.exits.contains(&i) {
                        Some(sink_tx.clone())
                    } else {
                        None
                    },
                    streaming: self.opts.streaming,
                    lazy_compile: self.opts.lazy_compile,
                    device_bytes: self.graph.config.device_bytes,
                    downstream_hint: self.downstream_hint(i),
                    ready: ready.clone(),
                };
                handles.push(stage::spawn(spec)?);
            }
        }
        drop(sink_tx);
        ready.wait();
        clock.reset();

        // Feed requests.
        let n_requests = workload.requests.len();
        inflight.store(n_requests, Ordering::SeqCst);
        {
            let mut table = reqs.lock().unwrap();
            for r in &workload.requests {
                table.insert(
                    r.id,
                    ReqMeta {
                        seed: r.seed,
                        max_audio_tokens: r.max_audio_tokens,
                        diffusion_steps: r.diffusion_steps,
                        ignore_eos: r.ignore_eos,
                        prompt_tokens: r.prompt_tokens.clone(),
                        max_text_tokens: r.max_text_tokens,
                    },
                );
            }
        }
        let feeder = {
            let clock = clock.clone();
            let recorder = recorder.clone();
            let realtime = self.opts.realtime_arrivals;
            let mut sorted = workload.requests.clone();
            sorted.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
            std::thread::spawn(move || {
                // Replicated entry stages: whole requests round-robin
                // across the replicas' channels (a request is a single
                // message, so any spread policy is state-safe here).
                let mut next = 0usize;
                'feed: for r in sorted {
                    if realtime {
                        let wait = r.arrival_s - clock.now();
                        if wait > 0.0 {
                            std::thread::sleep(std::time::Duration::from_secs_f64(wait));
                        }
                    }
                    recorder.emit(Event::Arrived { req: r.id, t: clock.now() });
                    // Try each replica's channel once, moving the request
                    // every time: a failed send hands it back through
                    // `SendError`, so a dead replica costs a retry, never
                    // a clone.
                    let n = front_txs.len();
                    let mut req = Some(r);
                    for k in 0..n {
                        let i = (next + k) % n;
                        match front_txs[i].send(req.take().expect("requeued on failure")) {
                            Ok(()) => {
                                next = (i + 1) % n;
                                continue 'feed;
                            }
                            Err(mpsc::SendError(bounced)) => req = Some(bounced),
                        }
                    }
                    break; // every entry replica is gone
                }
            })
        };

        // Collect completions from exit stages.  Poll with a timeout so a
        // failed stage replica (its error surfaces at join below) breaks
        // the loop instead of leaving the run waiting on completions that
        // can never arrive.
        let mut remaining = n_requests;
        let mut done: std::collections::HashSet<u64> = Default::default();
        while remaining > 0 {
            match sink_rx.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(item) => {
                    if item.finished && done.insert(item.req_id) {
                        recorder.emit(Event::Completed { req: item.req_id, t: clock.now() });
                        remaining -= 1;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if failed.load(Ordering::SeqCst) {
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        feeder.join().ok();
        stop.store(true, Ordering::SeqCst);

        let mut stages = Vec::new();
        for h in handles {
            match h.join() {
                Ok(Ok(summary)) => stages.push(summary),
                Ok(Err(e)) => return Err(e),
                Err(_) => anyhow::bail!("stage thread panicked"),
            }
        }
        let wall = clock.now();
        let report = recorder.report(wall, audio_stage);
        Ok(RunSummary { report, stages, wall_s: wall })
    }

    /// Chunking/conditioning hints a consumer stage's transfers need
    /// (derived from ITS model manifest, passed to incoming transfers).
    fn downstream_hint(&self, i: usize) -> TransferCtx {
        let s = self.graph.stage(i);
        let (chunk, ctd) = match self.artifacts.model(&s.model) {
            Ok(m) => match m.kind.as_str() {
                "dit" => (
                    m.cfg_usize("n_tokens").unwrap_or(64),
                    m.cfg_usize("cond_tokens_dim").unwrap_or(0),
                ),
                "cnn_vocoder" => (m.cfg_usize("t_frames").unwrap_or(64), 0),
                "patch_codec" => (m.cfg_usize("t_max").unwrap_or(64), 0),
                _ => (0, 0),
            },
            Err(_) => (0, 0),
        };
        TransferCtx {
            reqs: Arc::new(Mutex::new(Default::default())), // replaced in stage
            chunk_frames: chunk,
            cond_tokens_dim: ctd,
        }
    }
}

/// Which multimodal encoder serves a given thinker model (encoder output
/// width must match the thinker width).
pub fn encoder_model_for(stage_model: &str) -> Option<&'static str> {
    match stage_model {
        "thinker25" => Some("enc25"),
        "thinker3" => Some("enc3"),
        _ => None,
    }
}
