//! The orchestrator (paper §3.1/§3.3): launches `replicas` engines per
//! stage, wires routed connectors along the stage-graph edges
//! ([`crate::connector::router`]), routes requests, and tracks
//! per-request lifecycle metrics.
//!
//! Threading model: engines own non-`Send` PJRT state, so each engine
//! replica runs on its own thread, constructed in-thread.  Data crosses
//! threads only as [`StageItem`]s through [`crate::connector`]s — the
//! disaggregation boundary.  Transfers run consumer-side (the downstream
//! thread turns upstream items into engine commands).

pub mod stage;

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::PipelineConfig;
use crate::metrics::RunReport;
use crate::scheduler::{AllocationPlan, StageAllocator};
use crate::stage_graph::transfers::{Registry, TransferCtx};
use crate::stage_graph::StageGraph;
use crate::trace::Workload;
use crate::runtime::Artifacts;

/// Run-wide options.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Stream partial stage outputs (paper §3.3 "streaming stage
    /// output"); false = stage barriers (full output before transfer).
    pub streaming: bool,
    /// Baseline knob: recompile executables per call (eager analog).
    pub lazy_compile: bool,
    /// Honor request arrival times (online serving); false = offline
    /// batch (all requests available at t=0, the paper's eval mode).
    pub realtime_arrivals: bool,
    /// External Mooncake store address (spawned automatically if any
    /// edge uses the TCP connector and this is None).
    pub store_addr: Option<String>,
    /// Per-request deadline for [`Orchestrator::run_workload`]: every
    /// submitted request is cancelled end-to-end this many seconds
    /// after submission (`omni-serve run --deadline`).  `None` = no
    /// deadline (the default).
    pub deadline_s: Option<f64>,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self {
            streaming: true,
            lazy_compile: false,
            realtime_arrivals: false,
            store_addr: None,
            deadline_s: None,
        }
    }
}

/// Wall clock shared across stage threads (run-relative seconds).
/// Resettable so engine construction/compilation is excluded from
/// request timing.
///
/// Every stage thread reads this per event, so the hot path must not
/// take a lock: the epoch is a fixed `Instant` plus an atomic
/// nanosecond offset that [`RunClock::reset`] swaps — `now()` is one
/// monotonic-clock read and one relaxed atomic load.
#[derive(Debug, Clone)]
pub struct RunClock(Arc<ClockInner>);

#[derive(Debug)]
struct ClockInner {
    base: Instant,
    /// Nanoseconds from `base` to the current epoch start.
    offset_ns: std::sync::atomic::AtomicU64,
}

impl RunClock {
    pub fn new() -> Self {
        Self(Arc::new(ClockInner {
            base: Instant::now(),
            offset_ns: std::sync::atomic::AtomicU64::new(0),
        }))
    }

    pub fn now(&self) -> f64 {
        let elapsed = self.0.base.elapsed().as_nanos() as u64;
        let offset = self.0.offset_ns.load(Ordering::Relaxed);
        // A read racing a concurrent reset() could see the new offset
        // before its own clock sample — clamp instead of underflowing.
        elapsed.saturating_sub(offset) as f64 / 1e9
    }

    /// Restart the clock (after all engines report ready).
    pub fn reset(&self) {
        let elapsed = self.0.base.elapsed().as_nanos() as u64;
        self.0.offset_ns.store(elapsed, Ordering::Relaxed);
    }
}

impl Default for RunClock {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-engine-replica summary returned after a run (one entry per
/// replica; `replica` is 0 for unreplicated stages, making
/// single-replica runs identical to the pre-replication output).
#[derive(Debug, Default, Clone)]
pub struct StageSummary {
    pub name: String,
    /// Which engine replica of the stage this summary describes.
    pub replica: usize,
    pub ar: Option<crate::engine::ar::EngineStats>,
    pub diffusion: Option<crate::engine::diffusion::DiffusionStats>,
    pub vocoder: Option<crate::engine::vocoder::VocoderStats>,
    /// Admission-queue counters from the replica's [`crate::scheduler::StageScheduler`].
    pub sched: Option<crate::scheduler::SchedStats>,
    /// Cross-request cache counters (prefix cache for AR replicas,
    /// output cache for encoder replicas; `None` for engine kinds that
    /// hold no cache).
    pub cache: Option<crate::metrics::CacheCounters>,
    pub bytes_sent: u64,
    /// Event-core wake counters: how often the replica's parked thread
    /// was woken with at least one event pending…
    pub wakeups: u64,
    /// …how often a park ended with nothing pending (timeout or liveness
    /// backstop — a hot value here means a missing wake hook)…
    pub spurious_wakeups: u64,
    /// …and how long the thread spent parked, in milliseconds.
    pub idle_ms: f64,
}

impl StageSummary {
    /// Fold another replica's summary into this one (stage-level rollup).
    pub fn absorb(&mut self, other: &StageSummary) {
        self.bytes_sent += other.bytes_sent;
        self.wakeups += other.wakeups;
        self.spurious_wakeups += other.spurious_wakeups;
        self.idle_ms += other.idle_ms;
        match (&mut self.ar, &other.ar) {
            (Some(a), Some(b)) => {
                a.iterations += b.iterations;
                a.prefill_tokens += b.prefill_tokens;
                a.decode_tokens += b.decode_tokens;
                a.prefill_calls += b.prefill_calls;
                a.decode_calls += b.decode_calls;
                a.scan_calls += b.scan_calls;
                a.preemptions += b.preemptions;
                a.exec_seconds += b.exec_seconds;
                a.marshal_seconds += b.marshal_seconds;
                a.kv_exports += b.kv_exports;
                a.kv_imports += b.kv_imports;
                a.kv_export_bytes += b.kv_export_bytes;
                a.kv_reused_blocks += b.kv_reused_blocks;
                a.cancelled += b.cancelled;
                a.prefix_tokens_skipped += b.prefix_tokens_skipped;
                a.prefix_restored_seqs += b.prefix_restored_seqs;
            }
            (slot @ None, Some(b)) => *slot = Some(b.clone()),
            _ => {}
        }
        match (&mut self.diffusion, &other.diffusion) {
            (Some(a), Some(b)) => {
                a.jobs_done += b.jobs_done;
                a.steps_run += b.steps_run;
                a.steps_skipped += b.steps_skipped;
                a.calls += b.calls;
                a.exec_seconds += b.exec_seconds;
            }
            (slot @ None, Some(b)) => *slot = Some(b.clone()),
            _ => {}
        }
        match (&mut self.vocoder, &other.vocoder) {
            (Some(a), Some(b)) => {
                a.chunks_done += b.chunks_done;
                a.calls += b.calls;
                a.exec_seconds += b.exec_seconds;
            }
            (slot @ None, Some(b)) => *slot = Some(b.clone()),
            _ => {}
        }
        match (&mut self.cache, &other.cache) {
            (Some(a), Some(b)) => a.absorb(b),
            (slot @ None, Some(b)) => *slot = Some(*b),
            _ => {}
        }
        match (&mut self.sched, &other.sched) {
            (Some(a), Some(b)) => {
                a.admitted += b.admitted;
                a.passthrough += b.passthrough;
                a.cancelled += b.cancelled;
                a.max_queue_depth = a.max_queue_depth.max(b.max_queue_depth);
                a.queue_wait.extend(&b.queue_wait);
            }
            (slot @ None, Some(b)) => *slot = Some(b.clone()),
            _ => {}
        }
    }
}

/// Everything a finished run reports.
#[derive(Debug)]
pub struct RunSummary {
    pub report: RunReport,
    /// One entry per engine replica, in (stage, replica) order.
    pub stages: Vec<StageSummary>,
    pub wall_s: f64,
}

impl RunSummary {
    /// All replica summaries of `stage`.
    pub fn stage_replicas(&self, stage: &str) -> Vec<&StageSummary> {
        self.stages.iter().filter(|s| s.name == stage).collect()
    }

    /// Merge the per-replica summaries of `stage` into one stage-level
    /// rollup (counters summed, queue waits pooled).
    pub fn stage_rollup(&self, stage: &str) -> Option<StageSummary> {
        let mut it = self.stages.iter().filter(|s| s.name == stage);
        let mut acc = it.next()?.clone();
        for s in it {
            acc.absorb(s);
        }
        Some(acc)
    }
}

/// The disaggregated pipeline runner.
pub struct Orchestrator {
    pub(crate) graph: StageGraph,
    pub(crate) registry: Registry,
    pub(crate) artifacts: Arc<Artifacts>,
    pub(crate) opts: RunOptions,
    pub(crate) plan: AllocationPlan,
}

impl Orchestrator {
    pub fn new(
        config: PipelineConfig,
        artifacts: Arc<Artifacts>,
        registry: Registry,
        opts: RunOptions,
    ) -> Result<Self> {
        let graph = StageGraph::build(config, &registry)?;
        // Scheduling/allocation admission: resolve each stage's batching
        // policy and pack a device group per engine replica, rejecting
        // invalid combinations before any engine thread spawns.
        let plan = StageAllocator::new(&graph.config)
            .plan(Some(artifacts.as_ref()))
            .with_context(|| format!("allocating pipeline `{}`", graph.config.name))?;
        // Device-memory admission for the paper's testbed model: every
        // replica's weights must fit on its packed device group.
        let pool = crate::device::DevicePool::new(
            graph.config.n_devices,
            graph.config.device_bytes,
        );
        graph
            .reserve_memory(&pool, &artifacts, &plan)
            .with_context(|| format!("placing pipeline `{}`", graph.config.name))?;
        Ok(Self { graph, registry, artifacts, opts, plan })
    }

    pub fn graph(&self) -> &StageGraph {
        &self.graph
    }

    /// The resolved per-stage scheduling/placement plan.
    pub fn plan(&self) -> &AllocationPlan {
        &self.plan
    }

    /// Serve a whole workload to completion and report metrics — a thin
    /// open-loop wrapper over the persistent serving runtime: it starts a
    /// [`crate::serving::ServingSession`], submits the trace by
    /// `arrival_s` (honoring [`RunOptions::realtime_arrivals`]), waits
    /// for every completion, and shuts the session down.
    /// `audio_stage` names the stage whose generated tokens measure audio
    /// duration for RTF (e.g. "talker"), if any.
    pub fn run_workload(&self, workload: &Workload, audio_stage: Option<&'static str>) -> Result<RunSummary> {
        let session =
            crate::serving::ServingSession::start(self, crate::serving::SessionOptions::default())?;
        let realtime = self.opts.realtime_arrivals;
        let mut sorted = workload.requests.clone();
        sorted.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let mut handles = Vec::with_capacity(sorted.len());
        for r in sorted {
            if realtime {
                let wait = r.arrival_s - session.now();
                if wait > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(wait));
                }
            }
            // Open-loop submission through the typed request path (the
            // deprecated CompletionHandle shim preserves the old
            // submit-and-block contract over the ResponseStream).
            let mut oreq = crate::serving::OmniRequest::from(r);
            if let Some(d) = self.opts.deadline_s {
                oreq = oreq.deadline_s(d);
            }
            match session.submit_request(oreq) {
                Ok(rs) => handles.push(crate::serving::CompletionHandle::from_stream(rs)),
                Err(_) => break, // every entry replica is gone
            }
        }
        // Wait for completions.  The collector closes every stream when
        // the session fails, so `Closed` breaks the wait (the failed
        // replica's error surfaces when shutdown joins its thread); the
        // timeout arm is belt-and-suspenders, not a polling interval.
        'wait: for h in &handles {
            loop {
                match h.wait_timeout(std::time::Duration::from_secs(60)) {
                    crate::serving::WaitResult::Done(_) => break,
                    // run_workload sessions have no admission controller,
                    // but a rejection is terminal all the same.
                    crate::serving::WaitResult::Rejected { .. } => break,
                    crate::serving::WaitResult::Timeout => {
                        if session.failed() {
                            break 'wait;
                        }
                    }
                    crate::serving::WaitResult::Closed => break 'wait,
                }
            }
        }
        session.shutdown(audio_stage)
    }
}

/// Chunking/conditioning hints a consumer stage's transfers need
/// (derived from its model manifest, passed to incoming transfers).
pub(crate) fn downstream_hint(
    graph: &StageGraph,
    artifacts: &Artifacts,
    i: usize,
) -> TransferCtx {
    let s = graph.stage(i);
    let (chunk, ctd) = match artifacts.model(&s.model) {
        Ok(m) => match m.kind.as_str() {
            "dit" => (
                m.cfg_usize("n_tokens").unwrap_or(64),
                m.cfg_usize("cond_tokens_dim").unwrap_or(0),
            ),
            "cnn_vocoder" => (m.cfg_usize("t_frames").unwrap_or(64), 0),
            "patch_codec" => (m.cfg_usize("t_max").unwrap_or(64), 0),
            _ => (0, 0),
        },
        Err(_) => (0, 0),
    };
    TransferCtx {
        reqs: Arc::new(Mutex::new(Default::default())), // replaced in stage
        chunk_frames: chunk,
        cond_tokens_dim: ctd,
    }
}

/// Which multimodal encoder serves a given thinker model (encoder output
/// width must match the thinker width).
pub fn encoder_model_for(stage_model: &str) -> Option<&'static str> {
    match stage_model {
        "thinker25" => Some("enc25"),
        "thinker3" => Some("enc3"),
        _ => None,
    }
}
